"""W8 quantized inference path: round-trip bounds, the fused
dequant-matmul kernel vs its oracle, per-family parity of the quantized
model along every serving path (prefill / chunked decode / decode_step),
and the serve engines' compile-once + donation discipline on quantized
weights.

Greedy parity vs fp32 is asserted TEACHER-FORCED with a margin-aware
tolerance: random-init logits sit in near-ties, so free-running greedy
trivially diverges on any perturbation; the meaningful invariant is that
wherever the quantized argmax disagrees, the fp32 top-2 margin is within
the quantization's logit error (i.e. only coin-flips move).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import pwl
from repro.core.xamba import XambaConfig
from repro.kernels import ops as kops, ref
from repro.models import ModelConfig, build_model
from repro.nn import quant
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, ServeConfig

FAMILIES = ("mamba2-130m", "mamba-130m", "recurrentgemma-2b", "gemma-2b")

V = 64
SMALL_MAMBA2 = ModelConfig(name="m2", family="mamba2", vocab_size=V,
                           d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                           chunk_size=8, param_dtype="float32")
SMALL_RGLRU = ModelConfig(name="rg", family="recurrentgemma", vocab_size=V,
                          d_model=32, n_layers=3, n_heads=4, n_kv_heads=1,
                          head_dim=8, d_ff=96, mlp_type="geglu", lru_width=32,
                          sliding_window=8, scan_layers=True,
                          param_dtype="float32")


def _reduced(arch):
    return get_config(arch, reduced=True).replace(param_dtype="float32")


def _params(cfg, seed=0):
    return init_params(build_model(cfg).param_specs(),
                       jax.random.PRNGKey(seed), jnp.float32)


# ---------------------------------------------------------------------------
# quantize / dequantize round trip
# ---------------------------------------------------------------------------
def test_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(96, 130)), jnp.float32)
    # outlier channel: per-channel scales must keep the others tight
    w = w.at[:, 7].mul(100.0)
    qt = quant.quantize_tensor(w)
    assert qt.q.dtype == jnp.int8 and qt.scale.shape == (1, 130)
    err = jnp.abs(quant.dequantize(qt) - w)
    assert bool(jnp.all(err <= quant.roundtrip_error_bound(qt)))
    # outlier confinement: other channels unaffected by channel 7's range —
    # each stays within half a step of ITS OWN amax, not the outlier's
    clean_err = jnp.delete(err, 7, axis=1)
    clean_amax = jnp.abs(jnp.delete(w, 7, axis=1)).max()
    assert float(clean_err.max()) <= float(clean_amax) * 0.5 / 127 * 1.01


def test_roundtrip_stacked_layer_axis():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(3, 40, 72)), jnp.float32)
    qt = quant.quantize_tensor(w)
    assert qt.scale.shape == (3, 1, 72)
    sl = jax.tree.map(lambda a: a[1], qt)          # decode_view-style slice
    assert isinstance(sl, quant.QuantTensor) and sl.shape == (40, 72)
    np.testing.assert_allclose(np.asarray(quant.dequantize(sl)),
                               np.asarray(quant.dequantize(qt)[1]),
                               rtol=0, atol=0)


def test_quantize_params_respects_skip_list():
    cfg = _reduced("mamba-130m")
    params = _params(cfg)
    qp = quant.quantize_params(params)
    mixer = qp["layers"]["mixer"]
    assert quant.is_quantized(mixer["in_proj"]["w"])
    assert quant.is_quantized(mixer["out_proj"]["w"])
    # skip-list: small SSM params, convs, projections the fused decode
    # kernels consume raw, embeddings and norms all stay fp
    assert not quant.is_quantized(mixer["x_proj"]["w"])
    assert not quant.is_quantized(mixer["dt_proj"]["w"])
    assert not quant.is_quantized(mixer["conv"]["w"])
    assert not quant.is_quantized(mixer["A_log"])
    assert not quant.is_quantized(qp["embed"]["table"])
    assert not quant.is_quantized(qp["final_norm"]["scale"])
    s = quant.quant_summary(qp)
    assert s["quantized_tensors"] == 2 and s["compression"] > 1.5


def test_quantize_params_for_mode():
    cfg = _reduced("mamba2-130m")
    params = _params(cfg)
    assert quant.quantize_params_for_mode(params, "none") is params
    qp = quant.quantize_params_for_mode(params, "w8_pallas_interpret")
    leaf = qp["layers"]["mixer"]["in_proj"]["w"]
    assert leaf.backend == "pallas_interpret"
    with pytest.raises(ValueError):
        quant.quantize_params_for_mode(params, "w9")
    with pytest.raises(ValueError):
        XambaConfig(quant="w9")
    assert cfg.with_quant("w8").xamba.quant == "w8"


# ---------------------------------------------------------------------------
# kernel vs oracle (pallas_interpret on CPU) and XLA fallback
# ---------------------------------------------------------------------------
def test_qdot_matches_dequantized_matmul():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 56)), jnp.float32)
    qt = quant.quantize_tensor(
        jnp.asarray(rng.normal(size=(56, 88)), jnp.float32))
    want = jnp.dot(x, quant.dequantize(qt))
    np.testing.assert_allclose(np.asarray(quant.qdot(x, qt)),
                               np.asarray(want), rtol=1e-5, atol=1e-4)
    # bf16 activations: int8 weight x bf16 activation upconverts cleanly
    got16 = quant.qdot(x.astype(jnp.bfloat16), qt)
    np.testing.assert_allclose(np.asarray(got16), np.asarray(want),
                               rtol=2e-2, atol=2e-1)


@pytest.mark.parametrize("shape", [(5, 96, 130), (8, 256, 64)])
@pytest.mark.parametrize("variant", ["plain", "pwl", "gated"])
def test_qmatmul_kernel_ties_oracle(shape, variant):
    m, k, n = shape
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    qt = quant.quantize_tensor(jnp.asarray(rng.normal(size=(k, n)),
                                           jnp.float32))
    table = (pwl.get_table("silu", segments=16)
             if variant in ("pwl", "gated") else None)
    kw = {}
    if variant == "gated":
        qv = quant.quantize_tensor(jnp.asarray(rng.normal(size=(k, n)),
                                               jnp.float32))
        kw = dict(qv=qv.q, vscale=qv.scale)
    got = kops.qmatmul(x, qt.q, qt.scale, table=table, interpret=True, **kw)
    want = ref.qmatmul_ref(x, qt.q, qt.scale, table, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_qdot_pallas_backend_ties_xla_backend():
    """The same QuantTensor executed on both backends agrees (this is the
    whole-model dispatch path, not just the kernel)."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(3, 48)), jnp.float32)
    qt = quant.quantize_tensor(jnp.asarray(rng.normal(size=(48, 64)),
                                           jnp.float32))
    y_xla = quant.qdot(x, qt)
    y_pl = quant.qdot(x, qt.with_backend("pallas_interpret"))
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_xla),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# per-family parity: quantized model along every serving path
# ---------------------------------------------------------------------------
def _forced_decode_logits(model, params, toks, stream):
    """Prefill logits + teacher-forced decode logits along ``stream``."""
    b, L = toks.shape
    n = stream.shape[1]
    cache = model.init_cache(b, L + n, jnp.float32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    out = [np.asarray(logits)]
    dv = model.decode_view(params)
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for t in range(n - 1):
        logits, cache = step(dv, stream[:, t][:, None], cache,
                             jnp.int32(L + t))
        out.append(np.asarray(logits))
    return np.stack(out, 1)                        # (b, n, vocab)


@pytest.mark.parametrize("arch", FAMILIES)
def test_greedy_parity_vs_fp32(arch):
    """64-token teacher-forced parity vs fp32: logit error stays small and
    every argmax disagreement is a near-tie (fp32 top-2 margin below the
    quantization's own logit error)."""
    cfg = _reduced(arch)
    model = build_model(cfg)
    params = _params(cfg)
    qp = quant.quantize_params(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1,
                              cfg.vocab_size)
    stream = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 1,
                                cfg.vocab_size)
    lf = _forced_decode_logits(model, params, toks, stream)
    lq = _forced_decode_logits(model, qp, toks, stream)
    err = float(np.abs(lf - lq).max())
    assert err < 1.0, f"{arch}: w8 logit error {err}"
    af, aq = lf.argmax(-1), lq.argmax(-1)
    agree = float((af == aq).mean())
    assert agree >= 0.7, f"{arch}: forced greedy agreement {agree}"
    top2 = np.sort(lf, -1)
    margin = top2[..., -1] - top2[..., -2]
    dis = af != aq
    if dis.any():
        assert float(margin[dis].max()) <= 2.0 * err, \
            f"{arch}: confident argmax flipped under w8"


@pytest.mark.parametrize("arch", ("mamba2-130m", "mamba-130m"))
def test_w8_chunked_prefill_matches_whole_sequence(arch):
    """Quantized chunked prefill == quantized whole-sequence prefill (the
    same invariant test_prefill_chunk pins for fp params)."""
    cfg = _reduced(arch)
    model = build_model(cfg)
    qp = quant.quantize_params(_params(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 1,
                              cfg.vocab_size)
    whole, _ = model.prefill(qp, {"tokens": toks},
                             model.init_cache(2, 32, jnp.float32))
    cache = model.init_cache(2, 32, jnp.float32)
    for off in range(0, 24, 8):
        logits, cache = model.prefill_chunk(qp, toks[:, off:off + 8], cache,
                                            jnp.int32(off))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(whole),
                               rtol=1e-5, atol=1e-4)


def test_whisper_accepts_quantized_params():
    """The fifth family: encoder-decoder prefill + decode_step run on a
    quantized pytree and stay close to fp32 (whisper's batch dict carries
    frames, so it is exercised separately from the token-only loop)."""
    cfg = get_config("whisper-tiny", reduced=True).replace(
        param_dtype="float32")
    model = build_model(cfg)
    params = _params(cfg)
    qp = quant.quantize_params(params)
    assert quant.quant_summary(qp)["quantized_tensors"] > 0
    b = 2
    toks = jax.random.randint(jax.random.PRNGKey(6), (b, 8), 1,
                              cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(7),
                               (b, cfg.encoder_seq, cfg.d_model),
                               jnp.float32)
    batch = {"tokens": toks, "frames": frames}
    lf, cf = model.prefill(params, batch,
                           model.init_cache(b, 12, jnp.float32))
    lq, cq = model.prefill(qp, batch, model.init_cache(b, 12, jnp.float32))
    assert float(np.abs(np.asarray(lf) - np.asarray(lq)).max()) < 1.0
    tok = jnp.argmax(lq, -1).astype(jnp.int32)[:, None]
    logits, _ = model.decode_step(qp, tok, cq, jnp.int32(8))
    assert np.isfinite(np.asarray(logits)).all()


def test_rglru_pallas_decode_accepts_quantized_params():
    """The fused RG-LRU step kernel consumes the (quantized) rg/ig gate
    weights via in-program dequant; pallas_interpret ties the cumba mode
    on the same quantized params."""
    qp = quant.quantize_params(_params(SMALL_RGLRU))
    tok = jnp.asarray([[3], [41]], jnp.int32)
    outs = {}
    for mode in ("cumba", "pallas_interpret"):
        cfg = dataclasses.replace(SMALL_RGLRU,
                                  xamba=XambaConfig(decode=mode))
        model = build_model(cfg)
        cache = model.init_cache(2, 16, jnp.float32)
        toks = jnp.asarray([[5, 6, 7, 8], [9, 10, 11, 12]], jnp.int32)
        _, cache = model.prefill(qp, {"tokens": toks}, cache)
        logits, _ = model.decode_step(qp, tok, cache, jnp.int32(4))
        outs[mode] = np.asarray(logits)
    np.testing.assert_allclose(outs["pallas_interpret"], outs["cumba"],
                               rtol=1e-4, atol=1e-4)


def test_w8_pallas_backend_model_ties_xla_backend_model():
    """End-to-end: the w8_pallas_interpret params produce the same logits
    as the w8 (XLA dot_general) params — backend choice is numerics-free
    up to accumulation order."""
    model = build_model(SMALL_MAMBA2)
    params = _params(SMALL_MAMBA2)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 1, V)
    lx, cx = model.prefill(quant.quantize_params_for_mode(params, "w8"),
                           {"tokens": toks},
                           model.init_cache(2, 12, jnp.float32))
    lp, cp = model.prefill(
        quant.quantize_params_for_mode(params, "w8_pallas_interpret"),
        {"tokens": toks}, model.init_cache(2, 12, jnp.float32))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=1e-4, atol=1e-4)
    tok = jnp.argmax(lx, -1).astype(jnp.int32)[:, None]
    dx, _ = model.decode_step(quant.quantize_params_for_mode(params, "w8"),
                              tok, cx, jnp.int32(8))
    dp, _ = model.decode_step(
        quant.quantize_params_for_mode(params, "w8_pallas_interpret"),
        tok, cp, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dx),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# serve: compile-once + donation + greedy identity on quantized weights
# ---------------------------------------------------------------------------
def test_serve_w8_compile_once_and_greedy_identity():
    """Continuous engine (chunked prefill on) over quantized params: zero
    decode recompiles across slot turnover, donated pool survives, and the
    emitted tokens tie a manual quantized prefill + decode loop."""
    model = build_model(SMALL_MAMBA2)
    qp = quant.quantize_params(_params(SMALL_MAMBA2))
    prompts = [list(range(1, 9)), list(range(9, 17)), list(range(17, 23))]
    max_new = 4
    eng = ContinuousEngine(model, qp, ServeConfig(
        max_batch=2, prefill_buckets=(8,), max_new_tokens=max_new,
        prefill_chunk=4))
    for p in prompts:
        eng.submit(p)
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert len(done) == 3
    assert eng.counters["decode_compiles"] in (1, "unavailable")
    assert eng.counters["prefill_chunk_compiles"] in (1, "unavailable")

    # manual quantized loop, one request at a time (slot-order agnostic)
    from repro.serve.scheduler import chunk_span
    for uid, prompt in zip(sorted(done), prompts):
        span = chunk_span((8,), 4, len(prompt))
        toks = np.zeros((1, span), np.int32)
        toks[0, span - len(prompt):] = prompt
        cache = model.init_cache(1, 8 + max_new, jnp.float32)
        logits, cache = model.prefill(qp, {"tokens": jnp.asarray(toks)},
                                      cache)
        cur = jnp.argmax(logits, -1)
        manual = [int(cur[0])]
        for t in range(1, max_new):
            logits, cache = model.decode_step(qp, cur[:, None], cache,
                                              jnp.int32(span + t - 1))
            cur = jnp.argmax(logits, -1)
            manual.append(int(cur[0]))
        assert done[uid] == manual, f"uid={uid}"
