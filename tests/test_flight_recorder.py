"""Flight recorder (docs/observability.md): bounded ring, fault-event
JSONL dumps, ``load_flight`` round-trip, engine dump-on-fault via the
fault injector, and the ``trace_report --flight`` reader."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import trace_report
from repro.models import ModelConfig, build_model
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, ServeConfig
from repro.serve.flight_recorder import FlightRecorder, load_flight

V = 64

CFG = ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                  d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                  chunk_size=8, param_dtype="float32")


class FakeReq:
    def __init__(self, uid, **stamps):
        self.uid = uid
        self.prompt = [1, 2, 3]
        self.out_tokens = [4, 5]
        self.retries = 0
        for k, v in stamps.items():
            setattr(self, k, v)


def _model_params():
    model = build_model(CFG)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


# ---------------------------------------------------------------------------
# unit: ring + dumps + loader
# ---------------------------------------------------------------------------
def test_ring_is_bounded_and_counts_all():
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record_request(FakeReq(i))
    assert len(fr) == 4
    assert fr.recorded == 10
    assert [e["uid"] for e in fr.entries()] == [6, 7, 8, 9]


def test_record_request_segments():
    now = time.time()
    pc = time.perf_counter()
    fr = FlightRecorder(capacity=2)
    fr.record_request(FakeReq(7, arrival_s=now - 1.0, admit_pc=pc - 0.5,
                              first_token_s=now - 0.3,
                              finish_s=now - 0.1),
                      slot=1, status="ok")
    (e,) = fr.entries()
    assert e["uid"] == 7 and e["slot"] == 1 and e["status"] == "ok"
    assert e["prompt_tokens"] == 3 and e["tokens"] == 2
    assert e["queue_s"] == pytest.approx(0.5, abs=0.05)
    assert e["staging_s"] == pytest.approx(0.2, abs=0.05)
    assert e["decode_s"] == pytest.approx(0.2, abs=0.05)
    assert e["latency_s"] == pytest.approx(0.9, abs=0.05)


def test_record_request_tolerates_missing_stamps():
    fr = FlightRecorder(capacity=2)
    fr.record_request(FakeReq(1), status="shed")
    (e,) = fr.entries()
    assert e["status"] == "shed"
    assert e["queue_s"] is None and e["decode_s"] is None


def test_dump_and_load_round_trip(tmp_path):
    path = tmp_path / "flight.jsonl"
    fr = FlightRecorder(capacity=3, path=str(path))
    for i in range(5):
        fr.record_request(FakeReq(i))
    h = fr.record_fault("quarantine", uid=4, slot=0)
    assert h["entries"] == 3 and h["kind"] == "quarantine"
    fr.record_request(FakeReq(99), status="poisoned")
    fr.record_fault("watchdog_hang", deadline_s=1.0)
    assert fr.dumps == 2

    dumps = load_flight(str(path))
    assert len(dumps) == 2
    assert dumps[0]["fault"] == {"kind": "quarantine", "uid": 4, "slot": 0}
    assert [r["uid"] for r in dumps[0]["requests"]] == [2, 3, 4]
    assert dumps[1]["fault"]["kind"] == "watchdog_hang"
    assert dumps[1]["requests"][-1]["uid"] == 99
    assert dumps[1]["header"]["recorded_total"] == 6


def test_load_flight_skips_foreign_lines(tmp_path):
    path = tmp_path / "mixed.jsonl"
    fr = FlightRecorder(capacity=2, path=str(path))
    fr.record_request(FakeReq(0))
    with open(path, "a") as f:
        f.write(json.dumps({"unrelated": "line"}) + "\n")
    fr.record_fault("shed", uid=0, reason="queue_full")
    dumps = load_flight(str(path))
    assert len(dumps) == 1
    assert dumps[0]["fault"]["reason"] == "queue_full"


def test_memory_only_recorder_never_writes(tmp_path):
    fr = FlightRecorder(capacity=2, path=None)
    fr.record_request(FakeReq(0))
    fr.record_fault("quarantine")
    assert fr.dumps == 1 and fr.last_fault["kind"] == "quarantine"
    assert list(tmp_path.iterdir()) == []


# ---------------------------------------------------------------------------
# engine integration: injected fault -> dump; CLI reader parses it
# ---------------------------------------------------------------------------
def test_engine_dumps_on_quarantine_and_reader_parses(tmp_path, capsys):
    path = tmp_path / "flight.jsonl"
    model, params = _model_params()
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=4,
        poison_probe="logits", fault_plan="poison@3:slot=0",
        flight_records=8, flight_path=str(path)))
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.submit(rng.integers(1, V, 8).tolist())
    done = eng.run()
    eng.close()

    assert len(done) == 4
    statuses = sorted(r.status for r in done)
    assert "poisoned" in statuses
    assert eng.flight.dumps >= 1
    dumps = load_flight(str(path))
    kinds = [d["fault"]["kind"] for d in dumps]
    assert "quarantine" in kinds
    qd = dumps[kinds.index("quarantine")]
    assert any(r["status"] == "poisoned" for r in qd["requests"])
    # completed requests keep flowing into the ring after the fault
    assert eng.flight.recorded == 4

    # the CLI reader renders the same file and --check accepts it
    rc = trace_report.main(["--flight", str(path), "--check"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "quarantine" in out

    rc = trace_report.main(["--flight", str(path), "--json"])
    assert rc == 0
    parsed = json.loads(capsys.readouterr().out)
    assert len(parsed) == len(dumps)


def test_engine_without_flight_config_has_no_recorder():
    model, params = _model_params()
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=4))
    try:
        assert eng.flight is None
    finally:
        eng.close()


def test_flight_check_fails_on_empty_file(tmp_path, capsys):
    path = tmp_path / "empty.jsonl"
    path.write_text("")
    assert trace_report.main(["--flight", str(path), "--check"]) == 1
