"""Observability stack: tracer schema, streaming metrics, recompile
sentinels, trace-report analysis, and null-tracer identity."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import trace_report
from repro.models import ModelConfig, build_model
from repro.nn.params import init_params
from repro.serve import (ContinuousEngine, Engine, ServeConfig,
                         StreamingHistogram)
from repro.serve.metrics import RateMeter, ServeMetrics, WindowedGauge, \
    _percentile
from repro.serve.tracing import (NULL_TRACER, TID_ENGINE, TID_HOST,
                                 TID_QUEUE, TID_SLOT0, NullTracer,
                                 RecompileError, RecompileSentinel, Tracer)

V = 64

CFG = ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                  d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                  chunk_size=8, param_dtype="float32")


def _model_params():
    model = build_model(CFG)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


# ---------------------------------------------------------------------------
# percentiles: linear interpolation + streaming histogram vs exact
# ---------------------------------------------------------------------------
def test_percentile_matches_numpy_quantile():
    rng = np.random.default_rng(0)
    for n in (1, 2, 5, 20, 101):
        xs = rng.uniform(0.0, 10.0, n).tolist()
        for q in (0.0, 0.5, 0.9, 0.95, 0.99, 1.0):
            assert _percentile(xs, q) == pytest.approx(
                float(np.quantile(xs, q)), abs=1e-12), (n, q)


def test_percentile_no_nearest_rank_bias():
    # The old round() nearest-rank picked the MAX of 20 samples as p95;
    # linear interpolation lands between ranks 18 and 19.
    xs = list(range(20))
    p95 = _percentile([float(x) for x in xs], 0.95)
    assert p95 == pytest.approx(18.05)
    assert p95 < 19.0


def test_percentile_empty():
    assert _percentile([], 0.95) == 0.0


def test_streaming_histogram_vs_exact_quantiles():
    rng = np.random.default_rng(1)
    xs = rng.lognormal(mean=-3.0, sigma=1.0, size=5000)
    h = StreamingHistogram()
    for x in xs:
        h.add(float(x))
    assert h.count == len(xs)
    assert h.mean == pytest.approx(float(xs.mean()))
    assert h.vmin == pytest.approx(float(xs.min()))
    assert h.vmax == pytest.approx(float(xs.max()))
    # 32 bins/decade -> interpolated percentiles within ~7.5% relative.
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        assert h.percentile(q) == pytest.approx(exact, rel=0.08), q


def test_streaming_histogram_edges():
    h = StreamingHistogram()
    assert h.percentile(0.5) == 0.0
    assert h.summary()["count"] == 0
    h.add(0.25)
    assert h.percentile(0.99) == pytest.approx(0.25)
    # out-of-range samples clamp into edge buckets, stats stay exact
    h.add(1e-9)
    h.add(1e9)
    assert h.count == 3
    assert h.vmin == pytest.approx(1e-9)
    assert h.vmax == pytest.approx(1e9)
    assert h.percentile(0.0) >= h.vmin
    assert h.percentile(1.0) <= h.vmax


def test_windowed_gauge_and_rate_meter():
    g = WindowedGauge(window_s=10.0)
    for t, v in ((0.0, 2.0), (5.0, 4.0), (9.0, 6.0)):
        g.record(v, now=t)
    s = g.snapshot(now=9.0)
    assert s == {"last": 6.0, "mean": 4.0, "max": 6.0, "n": 3}
    s = g.snapshot(now=12.0)      # first point aged out of the window
    assert s["n"] == 2 and s["mean"] == 5.0 and s["last"] == 6.0

    r = RateMeter(window_s=10.0)
    for t in (0.0, 1.0, 2.0, 3.0, 4.0):
        r.record(4, now=t)
    assert r.rate(now=4.0) == pytest.approx(20 / 4.0)
    assert r.rate(now=100.0) == 0.0


# ---------------------------------------------------------------------------
# tracer: schema, round-trips, null identity
# ---------------------------------------------------------------------------
def test_tracer_schema_and_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("poll") as sp:
        sp.args["admitted"] = 2
        with tr.span("decode_step", live=3):
            pass
    tr.instant("finish", tid=TID_SLOT0 + 1, uid=7, tokens=4)
    tr.counter("serve_gauges", {"queue_depth": 1.0})

    spans = [e for e in tr.events if e["ph"] == "X"]
    assert [e["name"] for e in spans] == ["decode_step", "poll"]  # exit order
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] == 0
        assert e["cat"] == "serve"
    assert spans[1]["args"] == {"admitted": 2}
    # nesting: child inside parent
    child, parent = spans
    assert parent["ts"] <= child["ts"]
    assert child["ts"] + child["dur"] <= parent["ts"] + parent["dur"] + 1e-6
    # every used tid got exactly one thread_name metadata record
    metas = [e for e in tr.events if e["ph"] == "M"]
    assert {m["tid"] for m in metas} == {TID_ENGINE, TID_SLOT0 + 1}
    assert all(m["name"] == "thread_name" for m in metas)

    chrome, jsonl = tmp_path / "t.json", tmp_path / "t.jsonl"
    tr.save(str(chrome))
    tr.save_jsonl(str(jsonl))
    assert json.loads(chrome.read_text())["traceEvents"] == tr.events
    assert trace_report.load_events(str(chrome)) == tr.events
    assert trace_report.load_events(str(jsonl)) == tr.events


def test_tracer_walltime_conversion():
    import time
    tr = Tracer()
    t_wall = time.time()
    t_pc = tr.pc_from_walltime(t_wall)
    assert abs(t_pc - time.perf_counter()) < 0.5


def test_tracer_reset():
    tr = Tracer()
    with tr.span("poll"):
        pass
    assert tr.events
    tr.reset()
    assert tr.events == []
    with tr.span("poll"):
        pass   # track names re-emit after reset
    assert any(e["ph"] == "M" for e in tr.events)


def test_null_tracer_is_inert():
    nt = NullTracer()
    assert not nt.enabled
    s1 = nt.span("poll", tid=TID_QUEUE, x=1)
    s2 = nt.span("decode_step")
    assert s1 is s2                       # one shared do-nothing span
    with s1 as sp:
        sp.args["admitted"] = 3
        assert sp.args["admitted"] == 3   # readable inside the span
    assert sp.args == {}                  # cleared on exit
    nt.instant("finish", uid=1)
    nt.counter("g", {"a": 1.0})
    nt.reset()
    assert NULL_TRACER.span("x") is NULL_TRACER.span("y")


# ---------------------------------------------------------------------------
# recompile sentinel
# ---------------------------------------------------------------------------
def test_recompile_sentinel_trips_on_real_retrace():
    f = jax.jit(lambda x: x * 2)
    s = RecompileSentinel("f", f)
    assert s.supported
    assert s.check() == 0          # nothing compiled yet
    f(jnp.ones((2,)))
    assert s.check() == 0          # first compile lazy-arms, not a trip
    f(jnp.ones((2,)))
    assert s.check() == 0          # cache hit
    f(jnp.ones((3,)))              # new shape -> retrace
    tr = Tracer()
    assert s.check(tr) == 1
    assert [e["name"] for e in tr.events if e["ph"] == "i"] == ["recompile"]
    ev = next(e for e in tr.events if e["ph"] == "i")
    assert ev["args"] == {"program": "f", "new_traces": 1, "trips": 1}
    s.arm()                        # re-baseline zeroes the count
    assert s.trips == 0 and s.check() == 0


def test_recompile_sentinel_strict_raises():
    f = jax.jit(lambda x: x + 1)
    f(jnp.ones((2,)))
    s = RecompileSentinel("f", f, strict=True)
    s.arm()
    f(jnp.ones((4,)))
    with pytest.raises(RecompileError, match="retraced after warmup"):
        s.check()
    assert s.trips == 1            # counted even when raising


def test_recompile_sentinel_unsupported_fn_is_inert():
    s = RecompileSentinel("plain", lambda x: x)
    assert not s.supported
    assert s.check() == 0 and s.trips == 0


# ---------------------------------------------------------------------------
# trace_report: golden event stream -> summary
# ---------------------------------------------------------------------------
def _ev(name, ts, dur, tid=TID_ENGINE, **args):
    return {"name": name, "cat": "serve", "ph": "X", "pid": 0, "tid": tid,
            "ts": float(ts), "dur": float(dur), "args": args}


def _golden_events():
    """Hand-built 1000us trace with exactly known self-times.

    engine: serve.run[0,1000] { poll[0,400] { admit[0,100] {
    prefix_lookup[20,60], snapshot_restore[60,80] }, prefill_chunk
    [100,300], decode_step[300,400] }, poll[500,1000] {
    decode_step[500,950], pool_reset[950,980] } }
    host:   host_gap[400,500]
    """
    return [
        _ev("serve.run", 0, 1000),
        _ev("poll", 0, 400),
        _ev("admit", 0, 100, admitted=2),
        _ev("prefix_lookup", 20, 40, uid=1, matched_tokens=8),
        _ev("snapshot_restore", 60, 20, slot=0),
        _ev("prefill_chunk", 100, 200, rows=2, tokens=16),
        _ev("decode_step", 300, 100, live=2),
        _ev("host_gap", 400, 100, tid=TID_HOST),
        _ev("poll", 500, 500),
        _ev("decode_step", 500, 450, live=2),
        _ev("pool_reset", 950, 30, rows=1),
        # per-request tracks
        _ev("queue", 0, 50, tid=TID_QUEUE, uid=1),
        _ev("queue", 10, 60, tid=TID_QUEUE, uid=2),
        _ev("staging", 50, 250, tid=TID_SLOT0, uid=1),
        _ev("staging", 70, 230, tid=TID_SLOT0 + 1, uid=2),
        _ev("decode", 300, 650, tid=TID_SLOT0, uid=1),
        _ev("decode", 300, 150, tid=TID_SLOT0 + 1, uid=2),
        {"name": "finish", "cat": "serve", "ph": "i", "s": "t", "pid": 0,
         "tid": TID_ENGINE, "ts": 450.0,
         "args": {"uid": 2, "tokens": 3, "latency_s": 0.00045}},
        {"name": "recompile", "cat": "serve", "ph": "i", "s": "t", "pid": 0,
         "tid": TID_ENGINE, "ts": 960.0,
         "args": {"program": "decode", "new_traces": 1, "trips": 1}},
    ]


def test_golden_phase_breakdown():
    pb = trace_report.phase_breakdown(_golden_events())
    us = 1e-6
    assert pb["wall_s"] == pytest.approx(1000 * us)
    assert pb["phases_s"]["decode"] == pytest.approx(550 * us)
    assert pb["phases_s"]["prefill"] == pytest.approx(200 * us)
    # admit self (40) + prefix_lookup (40)
    assert pb["phases_s"]["admission"] == pytest.approx(80 * us)
    # snapshot_restore (20) + pool_reset (30)
    assert pb["phases_s"]["snapshot"] == pytest.approx(50 * us)
    # poll selves (0 + 20) + serve.run self (1000-400-500-100gap = 0)
    assert pb["phases_s"]["host_other"] == pytest.approx(20 * us)
    assert pb["phases_s"]["idle"] == pytest.approx(100 * us)
    assert pb["phase_total_s"] == pytest.approx(pb["wall_s"])
    assert pb["coverage"] == pytest.approx(1.0)


def test_golden_requests_ttft_slots_and_check():
    rep = trace_report.analyze(_golden_events())

    table = rep["requests"]
    assert [r["uid"] for r in table] == [1, 2]    # arrival order
    assert table[0]["queue_s"] == pytest.approx(50e-6)
    assert table[0]["staging_s"] == pytest.approx(250e-6)
    assert table[0]["decode_s"] == pytest.approx(650e-6)
    assert table[0]["slot"] == 0 and table[1]["slot"] == 1
    assert table[1]["tokens"] == 3
    assert table[1]["latency_s"] == pytest.approx(0.00045)

    td = rep["ttft_decomposition"]
    assert td["requests"] == 2
    # uid1: 50+250, uid2: 60+230 (us)
    assert td["ttft_mean_s"] == pytest.approx((300e-6 + 290e-6) / 2)
    assert td["queue_frac"] + td["prefill_frac"] == pytest.approx(1.0)
    assert td["first_decode_frac"] == 0.0

    su = rep["slot_utilization"]
    assert su["slots"]["0"]["busy_frac"] == pytest.approx(0.9)
    assert su["slots"]["1"]["busy_frac"] == pytest.approx(0.38)

    assert rep["recompile_trips"] == {"decode": 1}
    problems = trace_report.check(rep)
    assert len(problems) == 1 and "decode" in problems[0]

    # drop the recompile instant -> clean check
    clean = [e for e in _golden_events() if e["name"] != "recompile"]
    assert trace_report.check(trace_report.analyze(clean)) == []


def test_check_flags_bad_coverage():
    # one poll covering a third of the wall extent -> phases can't
    # reconcile with wall
    events = [_ev("poll", 0, 100), _ev("poll", 2900, 100)]
    rep = trace_report.analyze(events)
    problems = trace_report.check(rep)
    assert problems and "reconcile" in problems[0]


def test_trace_report_cli(tmp_path, capsys):
    path = tmp_path / "t.json"
    path.write_text(json.dumps({"traceEvents": _golden_events()}))
    rc = trace_report.main([str(path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "per-phase wall breakdown" in out
    assert "TTFT decomposition" in out
    assert "slot-timeline utilization" in out
    # --check fails on the golden trace's planted decode recompile
    assert trace_report.main([str(path), "--check"]) == 1
    rc = trace_report.main([str(path), "--json"])
    assert rc == 0


# ---------------------------------------------------------------------------
# engine integration: null-tracer identity + live trace validity
# ---------------------------------------------------------------------------
def _run_engine(model, params, trace, **cfg_kw):
    scfg = ServeConfig(max_batch=2, prefill_buckets=(16,), max_new_tokens=4,
                       trace=trace, **cfg_kw)
    eng = ContinuousEngine(model, params, scfg)
    rng = np.random.default_rng(7)
    for n in (6, 12, 5, 9):
        eng.submit(rng.integers(1, V, n).tolist())
    done = eng.run()
    eng.close()
    return eng, {r.uid: r.out_tokens for r in done}


def test_null_tracer_identity_greedy():
    """Tracing must not change behavior: greedy outputs and compile
    counts identical with tracing on and off (monolithic AND chunked)."""
    model, params = _model_params()
    eng_off, out_off = _run_engine(model, params, trace=None)
    eng_plain, out_plain = _run_engine(model, params, trace=True)
    assert out_plain == out_off
    _, out_ch_off = _run_engine(model, params, trace=None, prefill_chunk=8)
    eng_on, out_ch_on = _run_engine(model, params, trace=True,
                                    metrics_every=2, prefill_chunk=8)
    assert out_ch_on == out_ch_off
    assert eng_plain.counters["decode_compiles"] == \
        eng_off.counters["decode_compiles"]
    assert isinstance(eng_off.tracer, NullTracer)
    assert not eng_off.tracer.enabled
    assert eng_off.tracer.span("x") is eng_off.tracer.span("y")
    assert eng_on.tracer.enabled and eng_on.tracer.events


def test_live_trace_validates_and_reconciles():
    model, params = _model_params()
    eng, out = _run_engine(model, params, trace=True, metrics_every=2,
                           prefill_chunk=8)
    events = eng.tracer.events
    names = {e["name"] for e in events}
    assert {"serve.run", "poll", "decode_step", "prefill_chunk", "admit",
            "queue", "staging", "decode", "finish"} <= names
    # every request has its per-request spans
    for kind in ("queue", "staging", "decode"):
        uids = {e["args"]["uid"] for e in events
                if e.get("ph") == "X" and e["name"] == kind}
        assert uids == set(out), kind

    rep = trace_report.analyze(events)
    assert trace_report.check(rep) == [], trace_report.check(rep)
    assert rep["recompile_trips"] == {}
    assert rep["ttft_decomposition"]["requests"] == len(out)
    assert rep["metrics_snapshots"] == len(eng.metrics.snapshots) > 0

    # sentinels saw the live run and never tripped
    assert all(s.trips == 0 for s in eng.sentinels.values())
    assert eng.counters["recompile_trips"]["decode"] == 0


def test_wave_engine_traced():
    model, params = _model_params()
    scfg = ServeConfig(max_batch=2, prefill_buckets=(16,), max_new_tokens=3,
                       trace=True)
    eng = Engine(model, params, scfg)
    rng = np.random.default_rng(5)
    for n in (6, 9, 12):
        eng.submit(rng.integers(1, V, n).tolist())
    done = eng.run()
    names = {e["name"] for e in eng.tracer.events}
    assert {"poll", "prefill_bucket", "decode_step", "queue",
            "staging", "decode"} <= names
    rep = trace_report.analyze(eng.tracer.events)
    assert rep["ttft_decomposition"]["requests"] == len(done) == 3
    assert trace_report.check(rep) == [], trace_report.check(rep)


def test_strict_recompile_config_plumbed():
    model, params = _model_params()
    scfg = ServeConfig(max_batch=2, prefill_buckets=(16,),
                       max_new_tokens=3, strict_recompile=True)
    eng = ContinuousEngine(model, params, scfg)
    assert all(s.strict for s in eng.sentinels.values())
    eng.submit([1, 2, 3])
    eng.run()                       # warmup compiles must not raise
    assert all(s.trips == 0 for s in eng.sentinels.values())


# ---------------------------------------------------------------------------
# metrics: snapshots, wall_source, health counters
# ---------------------------------------------------------------------------
def test_metrics_snapshot_cadence_and_content():
    tr = Tracer()
    m = ServeMetrics(slots=2, tracer=tr, metrics_every=2)
    m.record_arrival()
    m.record_first_token(0.010)
    m.record_step(2, 0.004)
    m.observe_gauges(queue_depth=3, live_slots=2)
    for _ in range(5):
        m.maybe_snapshot(extra_fn=lambda: {"extra": 1})
    assert len(m.snapshots) == 2     # polls 2 and 4
    snap = m.snapshots[-1]
    assert snap["extra"] == 1
    assert snap["gauges"]["queue_depth"]["last"] == 3.0
    assert snap["ttft"]["count"] == 1
    assert any(e["ph"] == "C" and e["name"] == "serve_gauges"
               for e in tr.events)
    assert sum(e["ph"] == "i" and e["name"] == "metrics_snapshot"
               for e in tr.events) == 2


def test_metrics_wall_source():
    m = ServeMetrics(slots=2)
    assert m.summary()["wall_source"] == "none"
    m.record_step(1, 0.5)
    s = m.summary()
    assert s["wall_source"] == "decode_time"
    assert s["wall_s"] == pytest.approx(0.5)
    m.record_wall(2.0)
    s = m.summary()
    assert s["wall_source"] == "measured"
    assert s["wall_s"] == pytest.approx(2.0)


def test_metrics_summary_percentiles_and_health():
    m = ServeMetrics(slots=2)
    for t in (0.010, 0.020, 0.030, 0.100):
        m.record_first_token(t)
    s = m.summary()
    # histogram percentiles resolve to the bucket holding the rank (tight
    # for large samples, tested above); with 4 samples just pin the order
    assert 0.018 <= s["ttft_p50_s"] <= 0.031
    assert s["ttft_p50_s"] <= s["ttft_p95_s"] <= s["ttft_p99_s"] <= 0.100
    assert s["ttft_p99_s"] >= 0.030
    m.record_straggler("decode")
    m.watchdog_fires += 1
    s = m.summary()
    assert s["stragglers_decode"] == 1 and s["watchdog_fires"] == 1


def test_reset_stats_resets_observability():
    model, params = _model_params()
    eng, _ = _run_engine(model, params, trace=True)
    assert eng.tracer.events and eng.metrics.completed == 4
    eng.reset_stats()
    assert eng.tracer.events == []
    assert eng.metrics.completed == 0
    assert all(s.trips == 0 for s in eng.sentinels.values())
    assert eng.monitor_decode.records == []
