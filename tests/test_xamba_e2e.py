"""End-to-end XAMBA behaviour on the paper's models (reduced configs):

* CumBA + ReduBA are *exact* remaps — logits must match the naive baseline.
* ActiBA is the accuracy/performance trade — logit divergence must be small
  and shrink as PLU segment count grows (Table 1's mechanism).
* The Pallas (interpret) kernel path must agree with the XLA path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn.params import init_params


def _logits(arch, xamba, tokens, params=None):
    cfg = get_config(arch, reduced=True).replace(
        param_dtype="float32", xamba=xamba)
    model = build_model(cfg)
    if params is None:
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             jnp.float32)
    return np.asarray(model.forward(params, tokens)), params


@pytest.mark.parametrize("arch", ["mamba2-130m", "mamba-130m"])
def test_cumba_reduba_exactness(arch):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, 512)
    base, params = _logits(arch, XambaConfig.baseline(), tokens)
    opt, _ = _logits(arch, XambaConfig.optimized(), tokens, params)
    np.testing.assert_allclose(base, opt, rtol=1e-3, atol=1e-3)


def test_pallas_kernel_path_matches_xla(rng):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, 512)
    base, params = _logits("mamba2-130m", XambaConfig.optimized(), tokens)
    # pallas path requires chunk_size % 128 == 0; reduced cfg uses 32, so
    # the SSD falls back to cumba for segsum but rg/actiba kernels engage.
    pal, _ = _logits("mamba2-130m",
                     XambaConfig(cumba="pallas_interpret",
                                 reduba="pallas_interpret"),
                     tokens, params)
    np.testing.assert_allclose(base, pal, rtol=2e-3, atol=2e-3)


def test_actiba_divergence_small_and_shrinks():
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 48), 0, 512)
    exact, params = _logits("mamba2-130m", XambaConfig.optimized(), tokens)

    divs = []
    # (random-init logits are nearly flat, so argmax is sensitive; trained
    # models in Table 1 show ~no change.  Thresholds scale with segments.)
    for segments, min_agree in ((8, 0.8), (32, 0.9)):
        approx, _ = _logits(
            "mamba2-130m",
            XambaConfig(cumba="cumba", reduba="reduba", actiba=True,
                        actiba_segments=segments),
            tokens, params)
        # top-1 agreement (the Table-1 quality proxy)
        agree = (exact.argmax(-1) == approx.argmax(-1)).mean()
        divs.append(np.abs(exact - approx).mean())
        assert agree > min_agree, (segments, agree)
    assert divs[1] <= divs[0] * 1.5  # more segments -> no worse


def test_actiba_applies_to_attention_archs_too():
    """ActiBA touches SwiGLU/GeGLU models (the applicable technique)."""
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0, 512)
    cfg = get_config("gemma-2b", reduced=True).replace(param_dtype="float32")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    batch = {"tokens": tokens, "labels": tokens}
    loss_exact = float(model.loss(params, batch)[0])

    cfg2 = cfg.replace(xamba=XambaConfig.full(segments=32))
    model2 = build_model(cfg2)
    loss_pwl = float(model2.loss(params, batch)[0])
    assert abs(loss_exact - loss_pwl) < 0.05, (loss_exact, loss_pwl)
