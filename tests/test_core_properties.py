"""Property tests for the XAMBA core invariants and the speculative
accept rule — hypothesis when available (CI), else the deterministic
fallback shim in ``tests/_propcheck.py`` so the properties always run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: run the shim
    from _propcheck import given, settings, strategies as st

from repro.configs import get_config
from repro.core import pwl, reduce as xreduce, segsum, selective_scan, ssd
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn.params import init_params
from repro.serve.speculative import (accept_lengths, emit_counts,
                                     needs_rollback)

SET = dict(deadline=None, max_examples=15)


# ---------------------------------------------------------------------------
# CumBA: the matmul remap is numerically the same op
# ---------------------------------------------------------------------------

@settings(**SET)
@given(t=st.integers(2, 96), rows=st.integers(1, 5),
       seed=st.integers(0, 2**31 - 1))
def test_cumsum_modes_agree(t, rows, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, t)), jnp.float32)
    naive = segsum.cumsum(x, axis=-1, mode="naive")
    cumba = segsum.cumsum(x, axis=-1, mode="cumba")
    np.testing.assert_allclose(np.asarray(naive), np.asarray(cumba),
                               rtol=1e-4, atol=1e-4 * t)


@settings(**SET)
@given(t=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
def test_segsum_modes_agree_on_lower_triangle(t, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((2, t)) * 0.1, jnp.float32)
    s_naive = segsum.segsum(a, mode="naive")
    s_cumba = segsum.segsum(a, mode="cumba")
    tril = np.tril(np.ones((t, t), bool))
    np.testing.assert_allclose(np.asarray(s_naive)[..., tril],
                               np.asarray(s_cumba)[..., tril],
                               rtol=1e-4, atol=1e-4)
    # above the diagonal both must be "-inf" (large negative)
    assert (np.asarray(s_naive)[..., ~tril] < -1e20).all()
    assert (np.asarray(s_cumba)[..., ~tril] < -1e20).all()


# ---------------------------------------------------------------------------
# ReduBA: contraction remap is numerically the same op
# ---------------------------------------------------------------------------

@settings(**SET)
@given(m=st.integers(1, 64), n=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_reduce_modes_agree(m, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(xreduce.reduce_sum(x, 0, "naive")),
        np.asarray(xreduce.reduce_sum(x, 0, "reduba")),
        rtol=1e-4, atol=1e-4 * m)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_contract_modes_agree(seed):
    rng = np.random.default_rng(seed)
    l = jnp.asarray(rng.standard_normal((2, 3, 8, 2, 5)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((2, 3, 6, 2, 5)), jnp.float32)
    a = xreduce.contract("bclgn,bcsgn->bcgls", l, r, mode="reduba")
    b = xreduce.contract("bclgn,bcsgn->bcgls", l, r, mode="naive")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# SSD: chunked == exact sequential recurrence, all mode combinations
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(l=st.sampled_from([32, 48, 96]), chunk=st.sampled_from([16, 32]),
       cs=st.sampled_from(["naive", "cumba"]),
       rd=st.sampled_from(["naive", "reduba"]),
       seed=st.integers(0, 2**31 - 1))
def test_ssd_matches_sequential(l, chunk, cs, rd, seed):
    rng = np.random.default_rng(seed)
    b, h, p, g, n = 2, 4, 8, 2, 4
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    y_ref, h_ref = ssd.ssd_reference(x, dt, A, B, C)
    y, hT = ssd.ssd(x, dt, A, B, C, chunk_size=chunk,
                    xamba=XambaConfig(cumba=cs, reduba=rd),
                    return_final_state=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)


@settings(deadline=None, max_examples=6)
@given(l=st.sampled_from([16, 40]), seed=st.integers(0, 2**31 - 1))
def test_ssd_prefill_then_decode_matches_full(l, seed):
    """State handoff: prefill half, decode rest == one full pass."""
    rng = np.random.default_rng(seed)
    b, h, p, g, n = 1, 2, 4, 1, 4
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    y_full, _ = ssd.ssd_reference(x, dt, A, B, C)
    half = l // 2
    _, state = ssd.ssd(x[:, :half], dt[:, :half], A, B[:, :half],
                       C[:, :half], chunk_size=8, return_final_state=True)
    ys = []
    for t in range(half, l):
        state, yt = ssd.ssd_decode_step(state, x[:, t], dt[:, t], A,
                                        B[:, t], C[:, t])
        ys.append(yt)
    got = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full[:, half:]),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Selective scan (Mamba-1)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(l=st.sampled_from([32, 64]),
       mode=st.sampled_from(["associative", "chunked"]),
       seed=st.integers(0, 2**31 - 1))
def test_selective_scan_modes_match_sequential(l, mode, seed):
    rng = np.random.default_rng(seed)
    b, d, n = 2, 6, 4
    u = jnp.asarray(rng.standard_normal((b, l, d)), jnp.float32)
    delta = jnp.asarray(rng.uniform(0.001, 0.1, (b, l, d)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (d, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    y_seq = selective_scan.selective_scan(u, delta, A, B, C, D,
                                          mode="sequential")
    y = selective_scan.selective_scan(u, delta, A, B, C, D, mode=mode,
                                      chunk_size=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# ActiBA / PWL invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["silu", "softplus", "gelu", "sigmoid"])
def test_pwl_error_decreases_with_segments(name):
    errs = [pwl.pwl_error(pwl.numpy_fn(name),
                          pwl.get_table(name, segments=k))["max_abs"]
            for k in (4, 8, 16, 32, 64)]
    assert all(errs[i + 1] <= errs[i] * 1.01 for i in range(len(errs) - 1))
    assert errs[-1] < 5e-3  # the paper's "negligible loss" regime


@pytest.mark.parametrize("name", ["silu", "softplus", "gelu", "sigmoid"])
def test_pwl_adaptive_beats_uniform(name):
    ad = pwl.pwl_error(pwl.numpy_fn(name),
                       pwl.get_table(name, segments=16, adaptive=True))
    un = pwl.pwl_error(pwl.numpy_fn(name),
                       pwl.get_table(name, segments=16, adaptive=False))
    assert ad["max_abs"] <= un["max_abs"] * 1.05


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), segments=st.sampled_from([8, 16, 32]))
def test_pwl_basis_equals_lut_form(seed, segments):
    """The gather-free basis evaluation (TPU) == the LUT evaluation (NPU)."""
    rng = np.random.default_rng(seed)
    t = pwl.get_table("silu", segments=segments)
    xs = rng.uniform(-15, 15, 257).astype(np.float32)
    basis = np.asarray(pwl.eval_pwl(t, jnp.asarray(xs)))
    lut = pwl.eval_pwl_reference(t, xs.astype(np.float64))
    np.testing.assert_allclose(basis, lut, rtol=1e-4, atol=1e-4)


def test_pwl_continuity():
    """PLU tables must be continuous at every breakpoint."""
    for name in ("silu", "softplus", "gelu", "sigmoid"):
        t = pwl.get_table(name, segments=32)
        for k, b in enumerate(t.breakpoints):
            left = t.slopes[k] * b + t.intercepts[k]
            right = t.slopes[k + 1] * b + t.intercepts[k + 1]
            assert abs(left - right) < 1e-6, (name, k)


# ---------------------------------------------------------------------------
# Speculative decoding: accept rule + state rollback
# ---------------------------------------------------------------------------

@settings(**SET)
@given(b=st.integers(1, 5), k=st.integers(1, 8),
       vocab=st.integers(2, 4), seed=st.integers(0, 2**31 - 1))
def test_accept_lengths_is_longest_common_prefix(b, k, vocab, seed):
    """m == lcp(draft, verify); the emit count is m+1 capped at k; rows
    that don't roll back consumed exactly their emitted window."""
    rng = np.random.default_rng(seed)
    # Tiny vocab so matches and mismatches both occur often.
    draft = rng.integers(0, vocab, (b, k))
    verify = rng.integers(0, vocab, (b, k))
    m = accept_lengths(draft, verify)
    for i in range(b):
        ref = 0
        while ref < k and draft[i, ref] == verify[i, ref]:
            ref += 1
        assert m[i] == ref, (i, draft[i], verify[i])
    n = emit_counts(m, k)
    assert ((n >= 1) & (n <= k)).all()        # always progress, never > k
    assert (n >= m).all() and (n <= m + 1).all()
    rb = needs_rollback(m, k)
    if k == 1:
        assert not rb.any()                   # k=1 never rolls back
    # No-rollback rows emitted the full window: their post-verify state
    # (which consumed all k inputs) is exactly the post-emission state.
    assert (n[~rb] == k).all()
    # Full matches emit no correction; everyone else emits exactly one.
    assert (n[m == k] == k).all()
    assert (n[m < k] == np.minimum(m[m < k] + 1, k)).all()


@settings(deadline=None, max_examples=4)
@given(arch=st.sampled_from(["mamba-130m", "mamba2-130m",
                             "recurrentgemma-2b", "gemma-2b"]),
       seed=st.integers(0, 2**31 - 1))
def test_rollback_state_roundtrip(arch, seed):
    """export_state -> import_state is an exact (bitwise) state round
    trip for every family — the property speculative rollback rests on."""
    rng = np.random.default_rng(seed)
    cfg = get_config(arch, reduced=True).replace(param_dtype="float32")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         cfg.dtype)
    cache = model.init_cache(3, 24, cfg.dtype)
    toks = rng.integers(1, cfg.vocab_size, (3, 8))
    _, cache = model.prefill(params, {"tokens": jnp.asarray(toks)}, cache)
    snap = model.export_state(cache, None, [1])
    restored = model.import_state(model.init_cache(3, 24, cfg.dtype),
                                  None, [2], snap)
    back = model.export_state(restored, None, [2])
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        snap, back)
