"""Fused SSD prefill pipeline (``XambaConfig.prefill``): kernel-vs-oracle
parity, carried-state resumability, ActiBA / W8 composition, and the
engine-level contract that the fused backend changes NOTHING observable —
chunked == whole-sequence prefill and greedy outputs identical to the
unfused chain (fp32 configs; see ``kernels/prefill_chunk.py``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.xamba import XambaConfig
from repro.kernels import ops, prefill_chunk, ref
from repro.models import ModelConfig, build_model
from repro.nn import quant
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, Engine, ServeConfig

V = 64


def _inputs(rng, b, l, di, h, g, n, w):
    """Random fused-prefill operands with a nonzero carried state."""
    dxbc = di + 2 * g * n
    r = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    return dict(
        z=r(b, l, di), xbc=r(b, l, dxbc), dt=r(b, l, h),
        conv_state=r(b, w - 1, dxbc), ssm_state=r(b, h, di // h, n) * 0.1,
        conv_w=r(w, dxbc) * 0.3, conv_b=r(dxbc) * 0.1, dt_bias=r(h) * 0.1,
        A=-jnp.exp(r(h) * 0.3), D=r(h) * 0.2,
        norm_scale=jnp.abs(r(di)) + 0.5)


# ---------------------------------------------------------------------------
# kernel-vs-oracle parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [64, 128, 256])
@pytest.mark.parametrize("g", [1, 2])
def test_kernel_matches_oracle(chunk, g):
    """Both fused backends match the exact sequential-scan oracle across
    chunk sizes (64-multiples, satellite of the relaxed ssd gate) and
    grouped-head layouts, with a carried initial state."""
    b, l, h, p, n, w = 2, 256, 4, 8, 8, 4
    ops_in = _inputs(np.random.default_rng(chunk + g), b, l, h * p, h, g,
                     n, w)
    kw = dict(ngroups=g, head_dim=p, silu=jax.nn.silu,
              softplus=jax.nn.softplus)
    ry, rc, rs = ref.mamba2_prefill_ref(**ops_in, **kw)
    for name, got in [
        ("xla", prefill_chunk.mamba2_prefill_xla(**ops_in, chunk=chunk,
                                                 **kw)),
        ("pallas", prefill_chunk.mamba2_prefill_pallas(
            **ops_in, chunk=chunk, interpret=True, **kw)),
    ]:
        y, c, s = got
        np.testing.assert_allclose(np.asarray(y), np.asarray(ry),
                                   atol=2e-4, err_msg=f"{name} y")
        np.testing.assert_allclose(np.asarray(c), np.asarray(rc),
                                   atol=1e-5, err_msg=f"{name} conv")
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs),
                                   atol=2e-4, err_msg=f"{name} ssm")


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_initial_state_carry_resumes(backend):
    """Splitting a sequence in two fused calls, threading (conv, ssm)
    state through, reproduces the single whole-sequence call — the
    serve engines' carried-state ``prefill_chunk`` contract."""
    b, l, h, p, g, n, w = 1, 32, 4, 8, 2, 8, 4
    ops_in = _inputs(np.random.default_rng(3), b, l, h * p, h, g, n, w)
    kw = dict(ngroups=g, head_dim=p, chunk=16, silu=jax.nn.silu,
              softplus=jax.nn.softplus)
    fn = (prefill_chunk.mamba2_prefill_xla if backend == "xla" else
          lambda **k: prefill_chunk.mamba2_prefill_pallas(interpret=True,
                                                          **k))
    y_all, c_all, s_all = fn(**ops_in, **kw)
    half = {k: (v[:, :16] if k in ("z", "xbc", "dt") else v)
            for k, v in ops_in.items()}
    y1, c1, s1 = fn(**half, **kw)
    half2 = dict(ops_in, z=ops_in["z"][:, 16:], xbc=ops_in["xbc"][:, 16:],
                 dt=ops_in["dt"][:, 16:], conv_state=c1, ssm_state=s1)
    y2, c2, s2 = fn(**half2, **kw)
    np.testing.assert_allclose(np.concatenate([y1, y2], axis=1),
                               np.asarray(y_all), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c_all), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all), atol=1e-4)


@pytest.mark.parametrize("segments", [0, 16])
def test_actiba_tables_compose(segments):
    """The wrapper bakes ActiBA PWL activations into both backends; each
    must match the oracle evaluated with the same (exact or PWL)
    activation callables."""
    from repro.core import pwl
    b, l, dm, h, p, g, n, w = 1, 16, 24, 2, 8, 1, 4, 4
    di = h * p
    xa = XambaConfig.full(segments=segments) if segments else \
        XambaConfig.optimized()
    rng = np.random.default_rng(segments)
    ops_in = _inputs(rng, b, l, di, h, g, n, w)
    d_proj = 2 * di + 2 * g * n + h
    x = jnp.asarray(rng.normal(size=(b, l, dm)), jnp.float32)
    in_w = jnp.asarray(rng.normal(size=(dm, d_proj)) * 0.2, jnp.float32)
    zxbcdt = jnp.dot(x, in_w)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    okw = dict(ngroups=g, head_dim=p, silu=pwl.activation("silu", xa),
               softplus=pwl.activation("softplus", xa))
    ops_ref = dict(ops_in, z=z, xbc=xbc, dt=dt)
    ry, rc, rs = ref.mamba2_prefill_ref(**ops_ref, **okw)
    common = {k: v for k, v in ops_in.items() if k not in ("z", "xbc", "dt")}
    for mode in ("cumba", "pallas_interpret"):
        y, c, s = ops.mamba2_prefill(x, in_w, **common, ngroups=g,
                                     head_dim=p, chunk=8, xamba=xa,
                                     mode=mode)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ry), atol=1e-4,
                                   err_msg=f"{mode} segments={segments}")
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=1e-4)


def test_w8_epilogue_on_quantized_in_proj():
    """A ``QuantTensor`` in-projection dispatches through the fused
    dequant path inside the pipeline; parity against the oracle fed the
    identical quantized projection output."""
    b, l, dm, h, p, g, n, w = 2, 16, 32, 2, 8, 1, 4, 4
    di = h * p
    rng = np.random.default_rng(9)
    ops_in = _inputs(rng, b, l, di, h, g, n, w)
    d_proj = 2 * di + 2 * g * n + h
    x = jnp.asarray(rng.normal(size=(b, l, dm)), jnp.float32)
    in_w = jnp.asarray(rng.normal(size=(dm, d_proj)) * 0.2, jnp.float32)
    qw = quant.quantize_tensor(in_w)
    zxbcdt = quant.qdot(x, qw)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    kw = dict(ngroups=g, head_dim=p, silu=jax.nn.silu,
              softplus=jax.nn.softplus)
    ops_ref = dict(ops_in, z=z, xbc=xbc, dt=dt)
    ry, _, rs = ref.mamba2_prefill_ref(**ops_ref, **kw)
    common = {k: v for k, v in ops_in.items() if k not in ("z", "xbc", "dt")}
    for mode in ("cumba", "pallas_interpret"):
        y, _, s = ops.mamba2_prefill(x, qw, **common, ngroups=g, head_dim=p,
                                     chunk=8, mode=mode)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ry), atol=1e-4,
                                   err_msg=mode)
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=1e-4)


# ---------------------------------------------------------------------------
# model / engine level
# ---------------------------------------------------------------------------
CFG = ModelConfig(name="mamba2", family="mamba2", vocab_size=V, d_model=32,
                  n_layers=2, d_state=8, ssm_head_dim=8, chunk_size=8,
                  param_dtype="float32")


def _model_params(cfg):
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


@pytest.mark.parametrize("mode", ["cumba", "pallas_interpret"])
def test_fused_matches_naive_whole_sequence(mode):
    """Whole-sequence prefill under the fused backend: logits close to
    the unfused chain and greedy next-token identical (fp32)."""
    model_n, params = _model_params(CFG.with_prefill_mode("naive"))
    model_f, _ = _model_params(CFG.with_prefill_mode(mode))
    toks = jnp.asarray(np.random.default_rng(1).integers(1, V, (2, 16)),
                       jnp.int32)
    cache = model_n.init_cache(2, 0, jnp.float32)
    ln, _ = model_n.prefill(params, {"tokens": toks}, cache)
    cache = model_f.init_cache(2, 0, jnp.float32)
    lf, _ = model_f.prefill(params, {"tokens": toks}, cache)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ln), atol=1e-4)
    np.testing.assert_array_equal(np.argmax(np.asarray(lf), -1),
                                  np.argmax(np.asarray(ln), -1))


def test_fused_falls_back_on_odd_length(caplog):
    """A seqlen that is not a chunk multiple runs the unfused chain (the
    fused kernel consumes raw dt and the live conv tail, so padding is
    not an option) — with a logged one-line reason."""
    import logging
    model_n, params = _model_params(CFG.with_prefill_mode("naive"))
    model_f, _ = _model_params(CFG.with_prefill_mode("cumba"))
    toks = jnp.asarray(np.random.default_rng(2).integers(1, V, (1, 13)),
                       jnp.int32)
    cache = model_n.init_cache(1, 0, jnp.float32)
    ln, _ = model_n.prefill(params, {"tokens": toks}, cache)
    cache = model_f.init_cache(1, 0, jnp.float32)
    with caplog.at_level(logging.INFO, logger="repro.ssm"):
        lf, _ = model_f.prefill(params, {"tokens": toks}, cache)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ln), atol=1e-5)
    assert any("fused prefill" in r.message and "skipped" in r.message
               for r in caplog.records)


def test_engine_fused_chunked_matches_whole_greedy():
    """Continuous engine with chunked admission under the fused backend:
    outputs identical to the wave engine's monolithic prefill AND to the
    unfused chain, one compiled chunk program, one decode program."""
    prompts = [np.random.default_rng(5).integers(1, V, 16).tolist()
               for _ in range(4)]

    def run(cfg, engine_cls, **scfg_kw):
        model, params = _model_params(cfg)
        eng = engine_cls(model, params, ServeConfig(
            max_batch=2, prefill_buckets=(16,), max_new_tokens=6,
            **scfg_kw))
        for p in prompts:
            eng.submit(p)
        return {r.uid: r.out_tokens for r in eng.run()}, eng

    fused = CFG.with_prefill_mode("cumba")
    naive = CFG.with_prefill_mode("naive")
    whole_f, _ = run(fused, Engine)
    whole_n, _ = run(naive, Engine)
    chunk_f, eng = run(fused, ContinuousEngine, prefill_chunk=8)
    assert whole_f == whole_n          # fused backend: greedy-identical
    assert chunk_f == whole_f          # chunked == monolithic prefill
    assert eng.counters["prefill_chunk_compiles"] == 1
    assert eng.counters["decode_compiles"] == 1


def test_prefill_mode_validation():
    with pytest.raises(ValueError):
        dataclasses.replace(XambaConfig(), prefill="nope")
    assert CFG.with_prefill_mode("pallas").xamba.prefill == "pallas"
