"""Training substrate: optimizer math, microbatching, loss-goes-down."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.nn.params import init_params
from repro.optim import AdamWConfig, ScheduleConfig, adamw, lr_at
from repro.train import TrainConfig, make_train_step


def test_adamw_matches_reference_math(rng):
    cfg = AdamWConfig(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                      grad_clip=0.0)
    p = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    state = adamw.init(p, cfg)
    new_p, new_state, _ = adamw.update(g, state, p, jnp.float32(0.1), cfg)
    # reference numpy step 1
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.asarray(p["w"]) - 0.1 * (
        mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5,
                               atol=1e-6)
    assert int(new_state["step"]) == 1


def test_grad_clip_bounds_update(rng):
    cfg = AdamWConfig(grad_clip=1e-3, weight_decay=0.0)
    p = {"w": jnp.zeros((8,), jnp.float32)}
    g = {"w": jnp.full((8,), 100.0, jnp.float32)}
    state = adamw.init(p, cfg)
    _, _, stats = adamw.update(g, state, p, jnp.float32(1.0), cfg)
    assert float(stats["grad_norm"]) > 100.0  # reported pre-clip


def test_schedule_shapes():
    cfg = ScheduleConfig(base_lr=1.0, warmup_steps=10, total_steps=100,
                         min_ratio=0.1)
    assert float(lr_at(0, cfg)) == 0.0
    assert abs(float(lr_at(10, cfg)) - 1.0) < 1e-6
    assert float(lr_at(100, cfg)) == pytest.approx(0.1, rel=1e-3)
    assert float(lr_at(55, cfg)) < float(lr_at(20, cfg))


def _tiny_setup(microbatches=1):
    cfg = get_config("mamba2-130m", reduced=True).replace(
        param_dtype="float32")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    state = {"params": params,
             "opt": adamw.init(params, AdamWConfig())}
    tc = TrainConfig(optimizer=AdamWConfig(),
                     schedule=ScheduleConfig(base_lr=1e-3, warmup_steps=2,
                                             total_steps=50),
                     microbatches=microbatches)
    return model, state, tc


def test_microbatch_accumulation_matches_full_batch():
    model, state, tc1 = _tiny_setup(1)
    _, _, tc4 = _tiny_setup(4)
    data = SyntheticLM(DataConfig(vocab_size=512, seq_len=32,
                                  global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.next().items()}
    s1, m1 = jax.jit(make_train_step(model, tc1))(state, batch)
    s4, m4 = jax.jit(make_train_step(model, tc4))(state, batch)
    # same data, same params -> same update up to accumulation order
    l1 = jax.tree.leaves(s1["params"])
    l4 = jax.tree.leaves(s4["params"])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


@pytest.mark.slow
def test_loss_decreases_on_synthetic_data():
    model, state, tc = _tiny_setup()
    data = SyntheticLM(DataConfig(vocab_size=512, seq_len=64,
                                  global_batch=8, seed=1))
    step = jax.jit(make_train_step(model, tc))
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.next().items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
