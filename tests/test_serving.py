"""Serving: prefill+decode == full forward per family; engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.nn.params import init_params
from repro.serve import Engine, ServeConfig

V = 64


def _full_logits(model, cfg, params, batch):
    if cfg.family == "whisper":
        enc = model.encode(params, batch["frames"])
        from repro.nn import layers
        tokens = batch["tokens"]
        pos = layers.sinusoidal_positions(tokens.shape[1], cfg.d_model)
        x = jnp.take(params["embed"]["table"], tokens, axis=0) + \
            pos[None].astype(cfg.dtype)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
        h, _ = model._dec_trunk(params, x, positions, enc)
        return model._logits(params, h)
    if cfg.family in ("mamba", "mamba2"):
        return model.forward(params, batch["tokens"])
    if cfg.family == "recurrentgemma":
        x = model._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        h, _ = model._trunk(params, x, positions)
        return model._logits(params, h)
    x, positions, _ = model._embed_inputs(params, batch)
    if cfg.scan_layers:
        h, _, _ = model._trunk_train(params, x, positions)
    else:
        h, _, _ = model._trunk(params, x, positions)
    return model._logits(params, h)


CFGS = [
    ModelConfig(name="dense", family="transformer", vocab_size=V, d_model=32,
                n_layers=2, n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                param_dtype="float32"),
    ModelConfig(name="moe", family="transformer", vocab_size=V, d_model=32,
                n_layers=2, n_heads=4, n_kv_heads=2, head_dim=8, moe=True,
                n_experts=4, n_experts_per_token=2, moe_d_ff=48,
                capacity_factor=8.0, param_dtype="float32"),
    ModelConfig(name="mamba2", family="mamba2", vocab_size=V, d_model=32,
                n_layers=2, d_state=8, ssm_head_dim=8, chunk_size=8,
                param_dtype="float32"),
    ModelConfig(name="mamba1", family="mamba", vocab_size=V, d_model=32,
                n_layers=2, d_state=8, param_dtype="float32"),
    ModelConfig(name="rgemma", family="recurrentgemma", vocab_size=V,
                d_model=32, n_layers=3, n_heads=4, n_kv_heads=1, head_dim=8,
                d_ff=96, mlp_type="geglu", lru_width=32, sliding_window=8,
                scan_layers=False, param_dtype="float32"),
    ModelConfig(name="whisper", family="whisper", vocab_size=V, d_model=32,
                n_layers=2, encoder_layers=1, n_heads=4, n_kv_heads=4,
                head_dim=8, d_ff=64, mlp_type="mlp", norm_type="layernorm",
                encoder_seq=16, scan_layers=False, param_dtype="float32"),
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_prefill_decode_equals_full_forward(cfg):
    S = 24
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = init_params(model.param_specs(), rng, jnp.float32)
    tokens = jax.random.randint(rng, (2, S), 0, V)
    batch = {"tokens": tokens}
    if cfg.family == "whisper":
        batch["frames"] = jnp.ones((2, cfg.encoder_seq, cfg.d_model),
                                   jnp.float32)
    full = _full_logits(model, cfg, params, batch)

    P = S - 4
    cache = model.init_cache(2, S, jnp.float32)
    pb = dict(batch, tokens=tokens[:, :P])
    logits, cache = model.prefill(params, pb, cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(P, S):
        logits, cache = model.decode_step(params, tokens[:, t:t + 1], cache,
                                          jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=5e-4, atol=5e-4, err_msg=f"t={t}")


def test_sliding_window_ring_cache_long_decode():
    """Decode far past the window: ring cache must equal full forward."""
    cfg = ModelConfig(name="win", family="transformer", vocab_size=V,
                      d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                      head_dim=8, d_ff=64, sliding_window=8,
                      param_dtype="float32")
    S = 40
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = init_params(model.param_specs(), rng, jnp.float32)
    tokens = jax.random.randint(rng, (1, S), 0, V)
    full = _full_logits(model, cfg, params, {"tokens": tokens})

    cache = model.init_cache(1, S, jnp.float32)  # clamps to window
    P = 16
    logits, cache = model.prefill(params, {"tokens": tokens[:, :P]}, cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1]),
                               rtol=5e-4, atol=5e-4)
    for t in range(P, S):
        logits, cache = model.decode_step(params, tokens[:, t:t + 1], cache,
                                          jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=1e-3, atol=1e-3, err_msg=f"t={t}")


def test_engine_greedy_matches_manual_decode():
    cfg = CFGS[2]  # mamba2
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    prompts = [list(range(1, 17)) for _ in range(2)]  # equal lengths
    engine = Engine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=6))
    for p in prompts:
        engine.submit(p)
    done = engine.run()

    # manual greedy
    cache = model.init_cache(2, 16 + 6, jnp.float32)
    toks = jnp.asarray(prompts, jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    cur = jnp.argmax(logits, -1)
    outs = [[int(c)] for c in cur]
    for t in range(1, 6):
        logits, cache = model.decode_step(params, cur[:, None], cache,
                                          jnp.int32(16 + t - 1))
        cur = jnp.argmax(logits, -1)
        for i in range(2):
            outs[i].append(int(cur[i]))
    for r, manual in zip(done, outs):
        assert r.out_tokens == manual


def test_engine_eos_and_stats():
    cfg = CFGS[0]
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    engine = Engine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(8, 16), max_new_tokens=4))
    engine.submit([1, 2, 3])
    engine.submit([4, 5, 6, 7, 8, 9])
    done = engine.run()
    assert len(done) == 2 and all(r.done for r in done)
    stats = engine.stats(done)
    assert stats["generated_tokens"] == sum(len(r.out_tokens) for r in done)
