"""Prefix-state radix cache: trie semantics (longest-prefix match, LRU
eviction under a byte budget, refcount pinning), model-level snapshot
export/import parity (incl. sliding-window KV clipping), and engine-level
greedy identity with the cache on vs off for every decode family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.nn import attention
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, PrefixCache, ServeConfig
from repro.serve.prefix_cache import chunk_key, snapshot_nbytes

V = 64

CFGS = {
    "mamba2": ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                          chunk_size=8, param_dtype="float32"),
    "mamba1": ModelConfig(name="mamba1", family="mamba", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8,
                          param_dtype="float32"),
    "dense": ModelConfig(name="dense", family="transformer", vocab_size=V,
                         d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                         head_dim=8, d_ff=64, param_dtype="float32"),
    "rgemma": ModelConfig(name="rgemma", family="recurrentgemma",
                          vocab_size=V, d_model=32, n_layers=3, n_heads=4,
                          n_kv_heads=1, head_dim=8, d_ff=96,
                          mlp_type="geglu", lru_width=32, sliding_window=8,
                          scan_layers=False, param_dtype="float32"),
}
FAMILIES = list(CFGS)


def _model_params(name):
    cfg = CFGS[name]
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


def _snap(nbytes):
    """Fake snapshot pytree of a known host size."""
    return {"s": np.zeros(nbytes, np.uint8)}


# ---------------------------------------------------------------------------
# trie semantics
# ---------------------------------------------------------------------------
def test_chunk_key_splits_full_chunks_only():
    assert chunk_key([1, 2, 3, 4, 5, 6, 7], 3) == [(1, 2, 3), (4, 5, 6)]
    assert chunk_key([1, 2], 3) == []
    assert chunk_key(np.arange(4), 2) == [(0, 1), (2, 3)]


def test_trie_longest_prefix_match_and_depth_cap():
    cache = PrefixCache(1 << 20, chunk=2)
    key = chunk_key([1, 2, 3, 4, 5, 6], 2)
    node = None
    for i, c in enumerate(key):
        node = cache.insert(node, c, _snap(8))
        assert node is not None and node.depth == i + 1
    # full-depth match
    got, depth = cache.match(key, pin=False)
    assert depth == 3 and got.depth == 3
    # diverging suffix matches the shared prefix only
    got, depth = cache.match(chunk_key([1, 2, 3, 4, 9, 9], 2), pin=False)
    assert depth == 2 and got.depth == 2
    # depth cap (engine: always leave one chunk to recompute)
    got, depth = cache.match(key, max_depth=1, pin=False)
    assert depth == 1
    # unrelated stream: miss
    got, depth = cache.match(chunk_key([9, 9], 2), pin=False)
    assert got is None and depth == 0
    s = cache.stats()
    assert s["hits"] == 3 and s["misses"] == 1
    assert s["hit_tokens"] == (3 + 2 + 1) * 2


def test_trie_existing_child_insert_is_a_no_op():
    cache = PrefixCache(1 << 20, chunk=2)
    a = cache.insert(None, (1, 2), _snap(8), pin=False)
    b = cache.insert(None, (1, 2), _snap(8), pin=False)
    assert a is b and cache.stats()["inserts"] == 1
    assert cache.resident_bytes == snapshot_nbytes(_snap(8))


def test_lru_eviction_is_leaf_only_and_budget_bounded():
    cache = PrefixCache(100, chunk=1)
    a = cache.insert(None, (1,), _snap(40), pin=False)
    cache.insert(a, (2,), _snap(40), pin=False)
    # Interior node `a` is older but has a child: the leaf goes first.
    c = cache.insert(None, (3,), _snap(40), pin=False)
    assert c is not None
    assert cache.resident_bytes <= 100
    s = cache.stats()
    assert s["evictions"] == 1
    assert (1,) in cache.root.children          # interior survived
    assert not cache.root.children[(1,)].children  # its leaf was evicted
    # a node larger than the whole budget is refused outright
    assert cache.insert(None, (4,), _snap(200), pin=False) is None
    assert cache.stats()["inserts_refused"] == 1


def test_lru_order_evicts_least_recently_touched():
    cache = PrefixCache(100, chunk=1)
    cache.insert(None, (1,), _snap(40), pin=False)
    cache.insert(None, (2,), _snap(40), pin=False)
    cache.match(chunk_key([1], 1), pin=False)    # touch (1,): (2,) is LRU
    cache.insert(None, (3,), _snap(40), pin=False)
    assert set(cache.root.children) == {(1,), (3,)}


def test_refcount_pins_survive_eviction_pressure():
    cache = PrefixCache(100, chunk=1)
    pinned = cache.insert(None, (1,), _snap(60))      # pin=True
    assert pinned.refs == 1
    # Budget pressure cannot evict the pinned leaf: the insert is refused.
    assert cache.insert(None, (2,), _snap(60), pin=False) is None
    assert cache.stats()["inserts_refused"] == 1
    # Matching pins again (two in-flight stagings share the node).
    got, depth = cache.match(chunk_key([1], 1))
    assert got is pinned and pinned.refs == 2
    cache.release(pinned)
    cache.release(pinned)
    # Fully released: the same insert now evicts it and succeeds.
    assert cache.insert(None, (2,), _snap(60), pin=False) is not None
    assert set(cache.root.children) == {(2,)}
    assert cache.resident_bytes <= 100


def test_interleaved_stagings_share_and_extend_paths():
    """Two concurrent stagings: B matches A's partial path mid-insert,
    extends it divergently, and all pins release cleanly."""
    cache = PrefixCache(1 << 20, chunk=2)
    a_key = chunk_key([1, 2, 3, 4, 5, 6], 2)
    b_key = chunk_key([1, 2, 3, 4, 7, 8], 2)
    a_pins = []
    node = cache.insert(None, a_key[0], _snap(8))
    a_pins.append(node)
    b_node, b_depth = cache.match(b_key)          # B admits mid-staging
    b_pins = [b_node]
    assert b_depth == 1 and b_node is node
    node = cache.insert(node, a_key[1], _snap(8))
    a_pins.append(node)
    got = cache.child(b_node, b_key[1])           # B finds A's new node
    assert got is node
    b_pins.append(got)
    b_tail = cache.insert(got, b_key[2], _snap(8))
    a_tail = cache.insert(node, a_key[2], _snap(8))
    a_pins.append(a_tail)
    b_pins.append(b_tail)
    assert a_tail is not b_tail and len(cache) == 4
    for n in a_pins + b_pins:
        cache.release(n)
    assert all(n.refs == 0 for n in a_pins + b_pins)


# ---------------------------------------------------------------------------
# model-level snapshot parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", FAMILIES)
def test_export_import_roundtrip_mid_prefill(family):
    """export_state at a chunk boundary, import into a fresh cache, finish
    the prompt both ways: logits and caches must be bit-identical."""
    model, params = _model_params(family)
    rng = np.random.default_rng(1)
    L, B, max_seq, cut = 12, 2, 24, 8
    toks = jnp.asarray(rng.integers(1, V, (B, L)), jnp.int32)

    cache = model.init_cache(B, max_seq, jnp.float32)
    _, cache = model.prefill_chunk(params, toks[:, :cut], cache,
                                   jnp.int32(0))
    snap = model.export_state(cache, cut, [0, 1])

    restored = model.import_state(model.init_cache(B, max_seq, jnp.float32),
                                  cut, [0, 1], snap)
    ref, cache = model.prefill_chunk(params, toks[:, cut:], cache,
                                     jnp.int32(cut))
    got, restored = model.prefill_chunk(params, toks[:, cut:], restored,
                                        jnp.int32(cut))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), restored, cache)


def test_sliding_window_kv_snapshot_parity():
    """Ring caches (T == window) snapshot the whole ring — restore must
    reproduce decode exactly even when the prefix exceeds the window."""
    cfg = CFGS["dense"].replace(sliding_window=8)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    rng = np.random.default_rng(2)
    L, max_seq, cut = 16, 32, 12           # cut > window: ring wrapped
    toks = jnp.asarray(rng.integers(1, V, (1, L)), jnp.int32)
    cache = model.init_cache(1, max_seq, jnp.float32)
    assert cache.k.shape[2] == 8           # ring: T == window
    _, cache = model.prefill_chunk(params, toks[:, :cut], cache,
                                   jnp.int32(0))
    snap = model.export_state(cache, cut, [0])
    # ring leaves are kept whole (window-clipped by construction)
    assert jax.tree.leaves(snap)[0].shape[2] == 8
    restored = model.import_state(model.init_cache(1, max_seq, jnp.float32),
                                  cut, [0], snap)
    ref, cache = model.prefill_chunk(params, toks[:, cut:], cache,
                                     jnp.int32(cut))
    got, restored = model.prefill_chunk(params, toks[:, cut:], restored,
                                        jnp.int32(cut))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    a = _greedy(model, params, ref, cache, L)
    b = _greedy(model, params, got, restored, L)
    np.testing.assert_array_equal(a, b)


def test_snapshot_kv_clipped_to_prefix():
    """Linear KV snapshots store only the valid prefix rows — the honest
    byte accounting the cache budget is charged with."""
    model, params = _model_params("dense")
    rng = np.random.default_rng(3)
    max_seq, cut = 24, 8
    toks = jnp.asarray(rng.integers(1, V, (1, cut)), jnp.int32)
    cache = model.init_cache(1, max_seq, jnp.float32)
    _, cache = model.prefill_chunk(params, toks, cache, jnp.int32(0))
    snap = model.export_state(cache, cut, [0])
    for leaf in jax.tree.leaves(snap):
        assert leaf.shape[2] == cut        # (n_layers, 1, cut, nkv, hd)
    full = model.export_state(cache, None, [0])
    assert snapshot_nbytes(snap) * 3 == snapshot_nbytes(full)


def test_snapshot_keep_len_rule():
    assert attention.snapshot_keep_len(8, 100, 8) == 8     # ring: whole
    assert attention.snapshot_keep_len(24, 8, None) == 8   # linear: clip
    assert attention.snapshot_keep_len(24, 8, 16) == 8     # linear, window
    assert attention.snapshot_keep_len(24, None, None) == 24
    assert attention.snapshot_keep_len(24, 100, None) == 24


def _greedy(model, params, logits, cache, start, steps=4):
    toks = [np.asarray(jnp.argmax(logits, -1), np.int32)]
    for t in range(steps):
        tok = jnp.asarray(toks[-1][:, None], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache,
                                          jnp.int32(start + t))
        toks.append(np.asarray(jnp.argmax(logits, -1), np.int32))
    return np.stack(toks)


# ---------------------------------------------------------------------------
# engine-level identity
# ---------------------------------------------------------------------------
def _shared_prefix_prompts(rng, n, sys_len=24, turn_chunks=(1, 2)):
    """Shared system prompt + per-request turns whose lengths are chunk
    multiples (the alignment rule: padded streams must share chunks)."""
    sys_p = rng.integers(1, V, sys_len).tolist()
    return [sys_p + rng.integers(1, V, 8 * int(rng.choice(turn_chunks)))
            .tolist() for _ in range(n)]


@pytest.mark.parametrize("family", FAMILIES)
def test_engine_greedy_identity_cache_on_off(family):
    """Byte-identical greedy outputs with the prefix cache on vs off, with
    real cross-request hits and zero decode recompiles."""
    model, params = _model_params(family)
    rng = np.random.default_rng(7)
    prompts = _shared_prefix_prompts(rng, 6)
    budgets = [3, 5, 2, 6, 4, 3]

    def run(mb):
        eng = ContinuousEngine(model, params, ServeConfig(
            max_batch=2, prefill_buckets=(48,), max_new_tokens=6,
            prefill_chunk=8, prefix_cache_mb=mb))
        for p, m in zip(prompts, budgets):
            eng.submit(p, m)
        return {r.uid: r.out_tokens for r in eng.run()}, eng

    off_out, _ = run(0.0)
    on_out, eng = run(8.0)
    assert on_out == off_out
    assert eng.prefix_cache.stats()["hits"] >= 1
    assert eng.counters["decode_compiles"] == 1
    assert eng.counters["prefill_chunk_compiles"] == 1
    # every pin was released when its request left staging
    assert all(n.refs == 0 for n in eng.prefix_cache._nodes)


def test_engine_repeated_prompt_skips_all_but_last_chunk():
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, V, 32).tolist()
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=1, prefill_buckets=(32,), max_new_tokens=3,
        prefill_chunk=8, prefix_cache_mb=8.0))
    a = eng.submit(prompt)
    b = eng.submit(prompt)
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[a] == done[b]
    # second admission matched span - chunk tokens (the cap leaves one
    # chunk so the final logits exist to sample the first token from)
    assert eng.metrics.prefix_hit_tokens == 32 - 8
    assert eng.metrics.summary()["prefill_tokens"] == 32 + 8


def test_engine_eviction_under_pressure_never_corrupts_live_slots():
    """A budget that forces constant eviction mid-trace changes nothing
    about the outputs — restores copy out of the cache, and pinned paths
    refuse eviction rather than dangle."""
    model, params = _model_params("dense")
    rng = np.random.default_rng(11)
    prompts = _shared_prefix_prompts(rng, 8, sys_len=24)
    budgets = [3, 4, 2, 5, 3, 4, 2, 3]

    def run(mb):
        eng = ContinuousEngine(model, params, ServeConfig(
            max_batch=3, prefill_buckets=(48,), max_new_tokens=5,
            prefill_chunk=8, prefix_cache_mb=mb))
        for p, m in zip(prompts, budgets):
            eng.submit(p, m)
        return {r.uid: r.out_tokens for r in eng.run()}, eng

    off_out, _ = run(0.0)
    # ~3 dense snapshots of this config fit in 64 KB: hot churn
    on_out, eng = run(0.0625)
    assert on_out == off_out
    s = eng.prefix_cache.stats()
    assert s["evictions"] >= 1 or s["inserts_refused"] >= 1
    assert s["peak_bytes"] <= eng.prefix_cache.capacity_bytes
    assert all(n.refs == 0 for n in eng.prefix_cache._nodes)


def test_engine_prefix_cache_requires_chunked_prefill():
    model, params = _model_params("mamba2")
    with pytest.raises(ValueError, match="prefill_chunk"):
        ContinuousEngine(model, params, ServeConfig(prefix_cache_mb=1.0))
    with pytest.raises(ValueError, match="multiple"):
        ContinuousEngine(model, params, ServeConfig(
            prefill_chunk=8, prefix_cache_mb=1.0, prefix_chunk=12))


def test_engine_coarse_prefix_chunk_grain():
    """prefix_chunk = 2x prefill_chunk: snapshots every other chunk, hits
    quantized to the coarser grain, identity preserved."""
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(13)
    prompts = _shared_prefix_prompts(rng, 4, sys_len=32,
                                     turn_chunks=(2,))

    def run(mb):
        eng = ContinuousEngine(model, params, ServeConfig(
            max_batch=2, prefill_buckets=(48,), max_new_tokens=4,
            prefill_chunk=8, prefix_cache_mb=mb, prefix_chunk=16))
        for p in prompts:
            eng.submit(p)
        return {r.uid: r.out_tokens for r in eng.run()}, eng

    off_out, _ = run(0.0)
    on_out, eng = run(8.0)
    assert on_out == off_out
    s = eng.prefix_cache.stats()
    assert s["hits"] >= 1
    assert s["hit_tokens"] % 16 == 0
