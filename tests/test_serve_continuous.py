"""Continuous-batching subsystem: slot refill identity, state pool,
scheduler policy, vectorized sampling, metrics, compile-once discipline."""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.nn.params import init_params
from repro.serve import (ContinuousEngine, Engine, Request, Scheduler,
                         ServeConfig, StatePool)
from repro.serve import sampling
from repro.serve.state_pool import infer_batch_axes

V = 64

CFGS = {
    "dense": ModelConfig(name="dense", family="transformer", vocab_size=V,
                         d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                         head_dim=8, d_ff=64, param_dtype="float32"),
    "mamba2": ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                          chunk_size=8, param_dtype="float32"),
    "mamba1": ModelConfig(name="mamba1", family="mamba", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8,
                          param_dtype="float32"),
    "rgemma": ModelConfig(name="rgemma", family="recurrentgemma",
                          vocab_size=V, d_model=32, n_layers=3, n_heads=4,
                          n_kv_heads=1, head_dim=8, d_ff=96,
                          mlp_type="geglu", lru_width=32, sliding_window=8,
                          scan_layers=False, param_dtype="float32"),
}


def _model_params(name):
    cfg = CFGS[name]
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


def _prompts(rng, n, length):
    return [rng.integers(1, V, length).tolist() for _ in range(n)]


# ---------------------------------------------------------------------------
# tentpole: continuous == wave, token for token, with zero decode recompiles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["mamba2", "mamba1", "dense"])
def test_continuous_matches_wave_greedy(family):
    model, params = _model_params(family)
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, 10, 16)          # one bucket for both engines
    budgets = [2, 7, 3, 8, 2, 6, 4, 8, 3, 5]  # heterogeneous -> staggered

    scfg = ServeConfig(max_batch=4, prefill_buckets=(16,), max_new_tokens=8)
    wave = Engine(model, params, scfg)
    cont = ContinuousEngine(model, params, scfg)
    for p, m in zip(prompts, budgets):
        wave.submit(p, m)
        cont.submit(p, m)
    wave_out = {r.uid: r.out_tokens for r in wave.run()}
    cont_out = {r.uid: r.out_tokens for r in cont.run()}

    assert set(wave_out) == set(cont_out)
    for uid in wave_out:
        assert cont_out[uid] == wave_out[uid], f"uid={uid}"
    # compile-once: slot turnover must never retrace the decode program
    assert cont.counters["decode_compiles"] == 1
    assert cont.counters["prefill_compiles"] == 1


@pytest.mark.parametrize("family", ["mamba2", "mamba1", "dense", "rgemma"])
def test_mid_decode_admission_matches_solo(family):
    """Requests admitted into freed slots mid-decode generate exactly the
    tokens they'd generate running alone (greedy)."""
    model, params = _model_params(family)
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, 5, 12)
    budgets = [2, 6, 3, 6, 4]                # staggered completions

    scfg = ServeConfig(max_batch=2, prefill_buckets=(16,), max_new_tokens=6)
    cont = ContinuousEngine(model, params, scfg)
    for p, m in zip(prompts, budgets):
        cont.submit(p, m)
    batched = {r.uid: r.out_tokens for r in cont.run()}
    assert len(batched) == 5

    for i, (p, m) in enumerate(zip(prompts, budgets)):
        solo = ContinuousEngine(model, params, scfg)
        uid = solo.submit(p, m)
        (r,) = solo.run()
        assert r.uid == uid
        assert batched[i + 1] == r.out_tokens, f"request {i}"


def test_mixed_buckets_one_decode_program():
    """Slots prefilled at different buckets coexist (per-slot positions);
    decode still compiles exactly once, prefill once per bucket."""
    model, params = _model_params("dense")
    rng = np.random.default_rng(7)
    scfg = ServeConfig(max_batch=2, prefill_buckets=(8, 16),
                       max_new_tokens=5)
    cont = ContinuousEngine(model, params, scfg)
    for length in (6, 14, 7, 13, 5):
        cont.submit(rng.integers(1, V, length).tolist())
    done = cont.run()
    assert len(done) == 5 and all(len(r.out_tokens) == 5 for r in done)
    assert cont.counters["decode_compiles"] == 1
    assert cont.counters["prefill_compiles"] == 2

    # per-request greedy identity vs solo at the same bucket
    for r in done:
        solo = ContinuousEngine(model, params, scfg)
        solo.submit(r.prompt)
        (s,) = solo.run()
        assert s.out_tokens == r.out_tokens


# ---------------------------------------------------------------------------
# serving edge cases (satellite)
# ---------------------------------------------------------------------------
def _first_greedy_token(model, params, prompt, bucket):
    toks = np.zeros((1, bucket), np.int32)
    toks[0, bucket - len(prompt):] = prompt
    cache = model.init_cache(1, bucket + 4, jnp.float32)
    logits, _ = model.prefill(params, {"tokens": jnp.asarray(toks)}, cache)
    return int(np.argmax(np.asarray(logits), -1)[0])


@pytest.mark.parametrize("engine_cls", [Engine, ContinuousEngine],
                         ids=["wave", "continuous"])
def test_eos_on_prefill_token(engine_cls):
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, V, 10).tolist()
    eos = _first_greedy_token(model, params, prompt, 16)

    scfg = ServeConfig(max_batch=2, prefill_buckets=(16,), max_new_tokens=8,
                       eos_id=eos)
    eng = engine_cls(model, params, scfg)
    eng.submit(prompt)
    other = rng.integers(1, V, 10).tolist()  # slot must still be reusable
    eng.submit(other)
    eng.submit(other)
    done = {r.uid: r for r in eng.run()}
    assert len(done) == 3 and all(r.done for r in done.values())
    assert done[1].out_tokens == [eos]


@pytest.mark.parametrize("engine_cls", [Engine, ContinuousEngine],
                         ids=["wave", "continuous"])
def test_max_new_tokens_one(engine_cls):
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(13)
    scfg = ServeConfig(max_batch=2, prefill_buckets=(16,), max_new_tokens=8)
    eng = engine_cls(model, params, scfg)
    for p in _prompts(rng, 3, 9):
        eng.submit(p, max_new_tokens=1)
    done = eng.run()
    assert len(done) == 3
    assert all(r.done and len(r.out_tokens) == 1 for r in done)


@pytest.mark.parametrize("engine_cls", [Engine, ContinuousEngine],
                         ids=["wave", "continuous"])
def test_ragged_wave_fewer_requests_than_batch(engine_cls):
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(17)
    scfg = ServeConfig(max_batch=8, prefill_buckets=(16,), max_new_tokens=3)
    eng = engine_cls(model, params, scfg)
    for p in _prompts(rng, 3, 8):
        eng.submit(p)
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out_tokens) == 3 for r in done)


@pytest.mark.parametrize("engine_cls", [Engine, ContinuousEngine],
                         ids=["wave", "continuous"])
def test_prompt_truncation_flagged_and_warned(engine_cls, caplog):
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(19)
    scfg = ServeConfig(max_batch=2, prefill_buckets=(8, 16),
                       max_new_tokens=2)
    eng = engine_cls(model, params, scfg)
    with caplog.at_level(logging.WARNING, logger="repro.serve"):
        eng.submit(rng.integers(1, V, 40).tolist())   # > largest bucket
        eng.submit(rng.integers(1, V, 10).tolist())
    assert any("truncating" in rec.message for rec in caplog.records)
    done = {r.uid: r for r in eng.run()}
    assert done[1].truncated and not done[2].truncated
    assert len(done[1].out_tokens) == 2


# ---------------------------------------------------------------------------
# state pool
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["mamba2", "dense", "rgemma"])
def test_state_pool_row_roundtrip(family):
    model, params = _model_params(family)
    rng = np.random.default_rng(23)
    max_seq = 24
    toks = jnp.asarray(rng.integers(1, V, (4, 8)), jnp.int32)
    src = model.init_cache(4, max_seq, jnp.float32)
    _, src = model.prefill(params, {"tokens": toks}, src)

    pool = StatePool(model, 4, max_seq, jnp.float32)
    axes = pool.batch_axes
    pool.insert_rows(src, [0, 2], [3, 1])

    got = pool.extract_rows([3])
    jax.tree.map(
        lambda g, s, ax: np.testing.assert_array_equal(
            np.asarray(g).take(0, axis=ax),
            np.asarray(s).take(0, axis=ax)),
        got, src, axes)
    got = pool.extract_rows([1])
    jax.tree.map(
        lambda g, s, ax: np.testing.assert_array_equal(
            np.asarray(g).take(0, axis=ax),
            np.asarray(s).take(2, axis=ax)),
        got, src, axes)

    pool.reset_rows([3])
    got = pool.extract_rows([3])
    jax.tree.map(lambda g: np.testing.assert_array_equal(
        np.asarray(g), np.zeros_like(np.asarray(g))), got)
    # untouched slot survives the reset
    got = pool.extract_rows([1])
    jax.tree.map(
        lambda g, s, ax: np.testing.assert_array_equal(
            np.asarray(g).take(0, axis=ax),
            np.asarray(s).take(2, axis=ax)),
        got, src, axes)


@pytest.mark.parametrize("family", ["mamba2", "dense", "rgemma"])
def test_state_pool_snapshot_row(family):
    """clone_row snapshots one slot to the host without touching the
    donated arena; restore_row is its exact inverse — the prefix cache's
    primitives (and the supported way to extract per-slot state, instead
    of ad-hoc per-field gathers)."""
    model, params = _model_params(family)
    rng = np.random.default_rng(31)
    max_seq = 24
    toks = jnp.asarray(rng.integers(1, V, (4, 8)), jnp.int32)
    src = model.init_cache(4, max_seq, jnp.float32)
    _, src = model.prefill(params, {"tokens": toks}, src)

    pool = StatePool(model, 4, max_seq, jnp.float32)
    pool.insert_rows(src, [0, 2], [3, 1])
    snap = pool.clone_row(3)
    # host-side pytree: lifetime decoupled from the pool arena
    assert all(isinstance(leaf, np.ndarray)
               for leaf in jax.tree.leaves(snap))
    pool.reset_rows([3])
    pool.restore_row(3, snap)
    got = pool.extract_rows([3])
    jax.tree.map(
        lambda g, s, ax: np.testing.assert_array_equal(
            np.asarray(g).take(0, axis=ax),
            np.asarray(s).take(0, axis=ax)),
        got, src, pool.batch_axes)
    # clipped snapshot (index=8 consumed tokens) restores identically:
    # everything past the prefix is zero by the write discipline
    clipped = pool.clone_row(1, index=8)
    pool.restore_row(3, clipped, index=8)
    got = pool.extract_rows([3])
    jax.tree.map(
        lambda g, s, ax: np.testing.assert_array_equal(
            np.asarray(g).take(0, axis=ax),
            np.asarray(s).take(2, axis=ax)),
        got, src, pool.batch_axes)


def test_infer_batch_axes_scan_vs_loop_layouts():
    # scan-stacked mamba2: leaves are (n_layers, b, ...) -> batch axis 1
    model, _ = _model_params("mamba2")
    axes = infer_batch_axes(model, 8, jnp.float32)
    assert set(jax.tree.leaves(axes)) == {1}
    # per-layer loop (rgemma): leaves are (b, ...) -> batch axis 0
    model, _ = _model_params("rgemma")
    axes = infer_batch_axes(model, 8, jnp.float32)
    assert set(jax.tree.leaves(axes)) == {0}


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
def test_scheduler_priority_order_and_fcfs_tiebreak():
    sched = Scheduler("priority")
    for uid, pri in [(1, 5), (2, 1), (3, 5), (4, 0)]:
        sched.submit(Request(uid=uid, prompt=[1], max_new_tokens=1,
                             priority=pri))
    order = [sched.pop_ready(0.0).uid for _ in range(4)]
    assert order == [4, 2, 1, 3]
    assert sched.pop_ready(0.0) is None


def test_scheduler_deadline_shedding():
    sched = Scheduler("fcfs")
    sched.submit(Request(uid=1, prompt=[1], max_new_tokens=1,
                         deadline_s=10.0))
    sched.submit(Request(uid=2, prompt=[1], max_new_tokens=1))
    got = sched.pop_ready(now=20.0)      # uid 1 expired while queued
    assert got.uid == 2
    assert [r.uid for r in sched.expired] == [1]
    assert sched.expired[0].expired and sched.expired[0].done


@pytest.mark.parametrize("engine_cls", [Engine, ContinuousEngine],
                         ids=["wave", "continuous"])
def test_engine_deadline_shedding(engine_cls):
    import time as _time
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(43)
    eng = engine_cls(model, params, ServeConfig(
        max_batch=1, prefill_buckets=(8,), max_new_tokens=2))
    expired = eng.submit(rng.integers(1, V, 6).tolist(),
                         deadline_s=_time.time() - 1.0)
    kept = eng.submit(rng.integers(1, V, 6).tolist())
    done = eng.run()
    assert [r.uid for r in done] == [kept]
    assert [r.uid for r in eng.expired] == [expired]
    assert eng.metrics.shed == 1


def test_continuous_priority_admission():
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(29)
    scfg = ServeConfig(max_batch=1, prefill_buckets=(16,), max_new_tokens=2,
                       policy="priority")
    eng = ContinuousEngine(model, params, scfg)
    low = eng.submit(rng.integers(1, V, 8).tolist(), priority=9)
    high = eng.submit(rng.integers(1, V, 8).tolist(), priority=0)
    done = eng.run()
    assert [r.uid for r in done] == [high, low]


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------
def test_gumbel_sampler_deterministic_and_vectorized():
    logits = np.random.default_rng(0).normal(size=(16, V)).astype(np.float32)
    a = sampling.sample(logits, 0.8, sampling.step_rng(0, 7))
    b = sampling.sample(logits, 0.8, sampling.step_rng(0, 7))
    c = sampling.sample(logits, 0.8, sampling.step_rng(0, 8))
    assert a.shape == (16,) and a.dtype == np.int32
    np.testing.assert_array_equal(a, b)      # same (seed, step) replays
    assert not np.array_equal(a, c)          # step advances the stream
    # temperature 0 is exact argmax
    np.testing.assert_array_equal(
        sampling.sample(logits, 0.0, sampling.step_rng(0, 0)),
        np.argmax(logits, -1))


def test_gumbel_sampler_matches_softmax_distribution():
    logits = np.array([[np.log(3.0), 0.0]], np.float32)  # p = (0.75, 0.25)
    draws = np.array([
        sampling.sample(logits, 1.0, sampling.step_rng(1, s))[0]
        for s in range(2000)])
    p0 = float((draws == 0).mean())
    assert 0.70 < p0 < 0.80


def test_engine_temperature_sampling_deterministic():
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(31)
    prompts = _prompts(rng, 4, 8)

    def run_once():
        eng = ContinuousEngine(model, params, ServeConfig(
            max_batch=2, prefill_buckets=(8,), max_new_tokens=4,
            temperature=0.9, seed=42))
        for p in prompts:
            eng.submit(p)
        return {r.uid: r.out_tokens for r in eng.run()}

    assert run_once() == run_once()


# ---------------------------------------------------------------------------
# metrics / accounting / streaming
# ---------------------------------------------------------------------------
def test_wave_latency_accounting_per_request():
    """Same-wave requests with different budgets finish at different times;
    stats use summed sequential wave time, not the max request latency."""
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(37)
    scfg = ServeConfig(max_batch=2, prefill_buckets=(8,), max_new_tokens=12)
    eng = Engine(model, params, scfg)
    eng.submit(rng.integers(1, V, 6).tolist(), max_new_tokens=2)
    eng.submit(rng.integers(1, V, 6).tolist(), max_new_tokens=12)
    eng.submit(rng.integers(1, V, 6).tolist(), max_new_tokens=2)  # wave 2
    done = {r.uid: r for r in eng.run()}
    assert done[1].latency_s < done[2].latency_s
    stats = eng.stats(list(done.values()))
    assert stats["wall_s"] > 0
    # two sequential waves: total wall >= the longest single request
    assert stats["wall_s"] >= max(r.latency_s for r in done.values()) * 0.5
    assert stats["tokens_per_s"] == pytest.approx(
        stats["generated_tokens"] / stats["wall_s"])


def test_streaming_callback_and_metrics():
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(41)
    streamed = {}

    def on_token(uid, tok):
        streamed.setdefault(uid, []).append(tok)

    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(8,), max_new_tokens=3))
    for p in _prompts(rng, 3, 6):
        eng.submit(p, on_token=on_token)
    done = eng.run()
    for r in done:
        assert streamed[r.uid] == r.out_tokens
        assert r.first_token_s is not None and r.finish_s >= r.first_token_s
        assert r.latency_s > 0
    m = eng.metrics.summary()
    assert m["completed"] == 3
    assert m["generated_tokens"] == sum(len(r.out_tokens) for r in done)
    assert 0.0 < m["slot_occupancy"] <= 1.0
    assert eng.metrics.ttft.count == 3
