"""Per-arch smoke tests: REDUCED config, one forward/train step on CPU,
output shapes + no NaNs.  (Full configs are exercised via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.registry import ASSIGNED
from repro.models import build_model
from repro.nn.params import count_params, init_params

B, S = 2, 64


def _batch_for(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "whisper":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   cfg.dtype)
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = jnp.ones((B, cfg.num_patches, cfg.d_model),
                                         cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = init_params(model.param_specs(), rng, cfg.dtype)
    batch = _batch_for(cfg, rng)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["accuracy"]) >= 0.0

    # one gradient step moves the loss (and produces finite grads)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in gleaves), arch
    assert any(float(jnp.abs(g).max()) > 0 for g in gleaves), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "internlm2-20b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab_size=92544),
        "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32,
                            n_kv_heads=32, d_ff=11008, vocab_size=102400),
        "qwen1.5-4b": dict(n_layers=40, d_model=2560, n_heads=20,
                           n_kv_heads=20, d_ff=6912, vocab_size=151936,
                           qkv_bias=True),
        "gemma-2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                         d_ff=16384, vocab_size=256000, head_dim=256),
        "llava-next-mistral-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                                      n_kv_heads=8, d_ff=14336,
                                      vocab_size=32000),
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                                  n_kv_heads=4, moe_d_ff=768,
                                  vocab_size=151936, n_experts=128,
                                  n_experts_per_token=8),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, moe_d_ff=32768, vocab_size=131072,
                            n_experts=8, n_experts_per_token=2),
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6,
                             n_kv_heads=6, d_ff=1536, vocab_size=51865),
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, d_state=128,
                            vocab_size=50280),
        "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                                  n_kv_heads=1, d_ff=7680,
                                  vocab_size=256000, lru_width=2560),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_match_published_sizes():
    """Sanity: parameter totals land near the published model sizes."""
    targets = {
        "internlm2-20b": (17e9, 22e9),
        "deepseek-7b": (6e9, 8e9),
        "qwen1.5-4b": (3.5e9, 4.5e9),
        "gemma-2b": (2e9, 3e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "grok-1-314b": (290e9, 340e9),
        "whisper-tiny": (3e7, 5e7),
        "mamba2-2.7b": (2.4e9, 3e9),
        "recurrentgemma-2b": (2.4e9, 3.2e9),
        "mamba-130m": (1.1e8, 1.5e8),
        "mamba2-130m": (1.1e8, 1.5e8),
    }
    for arch, (lo, hi) in targets.items():
        n = count_params(build_model(get_config(arch)).param_specs())
        assert lo <= n <= hi, (arch, n)


def test_recurrentgemma_grouped_scan_matches_loop():
    """The grouped-scan training trunk == the per-layer loop trunk."""
    from repro.models.base import ModelConfig
    base_kw = dict(name="rg", family="recurrentgemma", vocab_size=64,
                   d_model=32, n_layers=7, n_heads=4, n_kv_heads=1,
                   head_dim=8, d_ff=96, mlp_type="geglu", lru_width=32,
                   sliding_window=16, param_dtype="float32")
    cfg_scan = ModelConfig(**base_kw, scan_layers=True)
    cfg_loop = ModelConfig(**base_kw, scan_layers=False)
    m1 = build_model(cfg_scan)
    m2 = build_model(cfg_loop)
    params = init_params(m1.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 64)
    batch = {"tokens": tokens, "labels": tokens}
    l1 = float(m1.loss(params, batch)[0])
    l2 = float(m2.loss(params, batch)[0])
    assert abs(l1 - l2) < 1e-5, (l1, l2)
