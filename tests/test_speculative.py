"""Self-speculative decoding: accept rule, model-level reference step,
and the continuous engine's compiled burst path.

The invariant under test everywhere: speculation is an *execution
strategy*, not a sampling change — with ``speculate_k`` on, every
request's token stream is byte-identical to the non-speculative engine
(greedy AND temperature, thanks to (seed, uid, position)-keyed sampling),
and the compile-once discipline still holds (the draft pass is a second
trace of the one decode program, verify is one new program, zero
post-warmup retraces)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.nn import quant
from repro.nn.params import init_params
from repro.serve import (ContinuousEngine, ServeConfig, accept_lengths,
                         emit_counts, needs_rollback)

V = 64

CFGS = {
    "dense": ModelConfig(name="dense", family="transformer", vocab_size=V,
                         d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                         head_dim=8, d_ff=64, param_dtype="float32"),
    "mamba2": ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                          chunk_size=8, param_dtype="float32"),
    "mamba1": ModelConfig(name="mamba1", family="mamba", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8,
                          param_dtype="float32"),
    "rgemma": ModelConfig(name="rgemma", family="recurrentgemma",
                          vocab_size=V, d_model=32, n_layers=3, n_heads=4,
                          n_kv_heads=1, head_dim=8, d_ff=96,
                          mlp_type="geglu", lru_width=32, sliding_window=8,
                          scan_layers=False, param_dtype="float32"),
}

FAMILIES = list(CFGS)


def _model_params(name):
    cfg = CFGS[name]
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


def _prompts(rng, n, length):
    return [rng.integers(1, V, length).tolist() for _ in range(n)]


# ---------------------------------------------------------------------------
# accept rule (pure; the property suite fuzzes it, these pin examples)
# ---------------------------------------------------------------------------
def test_accept_rule_worked_examples():
    draft = np.array([[5, 6, 7, 8],     # all match
                      [5, 6, 9, 8],     # diverges at j=2
                      [1, 6, 7, 8],     # diverges at j=0
                      [5, 6, 7, 9]])    # diverges at the last slot
    verify = np.array([[5, 6, 7, 8]] * 4)
    m = accept_lengths(draft, verify)
    np.testing.assert_array_equal(m, [4, 2, 0, 3])
    # n_emit = min(m + 1, k): the correction token is free except when
    # the whole draft was right.
    np.testing.assert_array_equal(emit_counts(m, 4), [4, 3, 1, 4])
    # rollback iff the post-verify state overshot the emitted stream:
    # m >= k-1 means the cache already sits exactly at the emission
    # boundary.
    np.testing.assert_array_equal(needs_rollback(m, 4),
                                  [False, True, True, False])


def test_accept_rule_k1_never_rolls_back():
    draft = np.array([[3], [4]])
    verify = np.array([[3], [9]])
    m = accept_lengths(draft, verify)
    np.testing.assert_array_equal(emit_counts(m, 1), [1, 1])
    assert not needs_rollback(m, 1).any()


# ---------------------------------------------------------------------------
# model-level reference: speculative_step == sequential greedy decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", FAMILIES)
def test_speculative_step_matches_sequential_greedy(family):
    model, params = _model_params(family)
    draft_params = quant.quantize_params_for_mode(params, "w8")
    rng = np.random.default_rng(101)
    b, plen, n_new, k = 2, 8, 12, 3
    toks = jnp.asarray(rng.integers(1, V, (b, plen)), jnp.int32)
    max_seq = plen + n_new + k + 1

    # Sequential full-precision greedy reference.
    cache = model.init_cache(b, max_seq, jnp.float32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    t0 = np.argmax(np.asarray(logits, np.float32), -1).astype(np.int32)
    ref = [t0]
    cur, idx = t0, plen
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray(cur[:, None]), cache,
            jnp.asarray(idx, jnp.int32))
        cur = np.argmax(np.asarray(logits, np.float32), -1).astype(np.int32)
        ref.append(cur)
        idx += 1
    ref = np.stack(ref, axis=1)          # (b, n_new)

    # Speculative: same prefill, then bursts of speculative_step.
    cache = model.init_cache(b, max_seq, jnp.float32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    t0 = np.argmax(np.asarray(logits, np.float32), -1).astype(np.int32)
    out = [[int(t0[i])] for i in range(b)]
    pend = t0
    idx = np.full((b,), plen, np.int32)
    rollbacks = 0
    while min(len(o) for o in out) < n_new:
        emitted, n_emit, cache, idx = model.speculative_step(
            draft_params, params, pend[:, None], cache, idx, k)
        rollbacks += int(needs_rollback(
            np.asarray(n_emit) - 1 + (np.asarray(n_emit) == k), k).sum())
        for i in range(b):
            out[i].extend(int(emitted[i, j]) for j in range(int(n_emit[i])))
        pend = np.array([o[-1] for o in out], np.int32)

    for i in range(b):
        assert out[i][:n_new] == ref[i].tolist(), f"row {i}"
    # The w8 draft must actually disagree sometimes on this model, or the
    # rollback path went untested; emission ran past n_new only via
    # accepted prefixes, so total emitted < n_new + k per row.
    assert all(len(o) < n_new + k for o in out)


def test_speculative_step_k_equals_one_is_plain_decode():
    """k=1 drafts nothing useful (the verify token is the only emission)
    but must still advance state exactly like a plain decode step."""
    model, params = _model_params("mamba2")
    draft_params = quant.quantize_params_for_mode(params, "w8")
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(1, V, (1, 8)), jnp.int32)
    cache = model.init_cache(1, 16, jnp.float32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    t0 = np.argmax(np.asarray(logits, np.float32), -1).astype(np.int32)

    ref_logits, _ = model.decode_step(
        params, jnp.asarray(t0[:, None]), cache, jnp.asarray(8, jnp.int32))
    ref = int(np.argmax(np.asarray(ref_logits, np.float32), -1)[0])

    emitted, n_emit, _, new_idx = model.speculative_step(
        draft_params, params, t0[:, None], cache, np.asarray(8, np.int32), 1)
    assert int(n_emit[0]) == 1 and int(emitted[0, 0]) == ref
    np.testing.assert_array_equal(np.asarray(new_idx), [9])


# ---------------------------------------------------------------------------
# engine: spec on == spec off, byte for byte
# ---------------------------------------------------------------------------
def _run_engine(model, params, prompts, budgets, **cfg_kw):
    scfg = ServeConfig(max_batch=2, prefill_buckets=(16,), max_new_tokens=8,
                       **cfg_kw)
    eng = ContinuousEngine(model, params, scfg)
    try:
        for p, m in zip(prompts, budgets):
            eng.submit(p, m)
        done = eng.run()
    finally:
        eng.close()
    return {r.uid: r.out_tokens for r in done}, eng


@pytest.mark.parametrize("family", FAMILIES)
def test_engine_spec_matches_nonspec_greedy(family):
    model, params = _model_params(family)
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, 6, 12)
    budgets = [8, 3, 6, 8, 2, 7]          # staggered refills mid-burst

    base, _ = _run_engine(model, params, prompts, budgets)
    spec, eng = _run_engine(model, params, prompts, budgets, speculate_k=3)
    assert base == spec
    m = eng.metrics.summary()
    assert m["spec_bursts"] > 0
    assert 0.0 < m["spec_accept_rate"] <= 1.0
    assert m["spec_tokens_per_verify"] >= 1.0
    # Compile-once: the draft pass is a second trace of the ONE decode
    # program (quantized pytree), verify is exactly one program.
    assert eng.counters["decode_compiles"] == 2
    assert eng.counters["verify_compiles"] == 1


@pytest.mark.parametrize("family", ["mamba2", "rgemma"])
def test_engine_spec_matches_nonspec_temperature(family):
    """Keyed sampling makes even *sampled* streams invariant to
    speculation: the verify chunk draws position p with the same noise a
    plain decode step would."""
    model, params = _model_params(family)
    rng = np.random.default_rng(13)
    prompts = _prompts(rng, 5, 12)
    budgets = [6, 4, 8, 3, 7]

    base, _ = _run_engine(model, params, prompts, budgets,
                          temperature=0.9, seed=42)
    spec, eng = _run_engine(model, params, prompts, budgets,
                            temperature=0.9, seed=42, speculate_k=4)
    assert base == spec
    assert eng.metrics.summary()["spec_bursts"] > 0


def test_engine_spec_with_chunked_prefill():
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(17)
    prompts = _prompts(rng, 5, 14)
    budgets = [8, 5, 8, 4, 6]

    base, _ = _run_engine(model, params, prompts, budgets, prefill_chunk=8)
    spec, eng = _run_engine(model, params, prompts, budgets,
                            prefill_chunk=8, speculate_k=3)
    assert base == spec
    assert eng.metrics.summary()["spec_bursts"] > 0


def test_engine_spec_k1_no_rollbacks():
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(19)
    prompts = _prompts(rng, 4, 10)
    base, _ = _run_engine(model, params, prompts, [6] * 4)
    spec, eng = _run_engine(model, params, prompts, [6] * 4, speculate_k=1)
    assert base == spec
    m = eng.metrics.summary()
    assert m["spec_bursts"] > 0 and m["spec_rollbacks"] == 0


def test_engine_spec_eos_mid_prefix():
    """EOS produced inside an accepted prefix finishes the request there:
    no tokens past EOS leak out, and the freed slot is refilled."""
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(23)
    prompts = _prompts(rng, 4, 10)
    base, _ = _run_engine(model, params, prompts, [8] * 4)
    # Pick an EOS id that appears mid-stream in some request's output.
    eos = None
    for toks in base.values():
        if len(toks) > 2:
            eos = toks[1]
            break
    assert eos is not None

    ref, _ = _run_engine(model, params, prompts, [8] * 4, eos_id=eos)
    spec, _ = _run_engine(model, params, prompts, [8] * 4, eos_id=eos,
                          speculate_k=3)
    assert ref == spec
    assert any(t and t[-1] == eos and len(t) < 8 for t in spec.values())


# ---------------------------------------------------------------------------
# compile-once: zero post-warmup retraces with speculation on
# ---------------------------------------------------------------------------
def test_engine_spec_zero_postwarmup_recompiles():
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(29)
    scfg = ServeConfig(max_batch=2, prefill_buckets=(8, 16),
                       max_new_tokens=8, speculate_k=3,
                       strict_recompile=True)   # retrace -> RecompileError
    eng = ContinuousEngine(model, params, scfg)
    try:
        # Warmup: both prefill buckets, bursts, rollback drains.
        for length in (6, 12, 7, 13):
            eng.submit(rng.integers(1, V, length).tolist())
        eng.run()
        eng.reset_stats()
        for length in (5, 14, 6, 11, 13, 7):
            eng.submit(rng.integers(1, V, length).tolist())
        done = eng.run()
    finally:
        eng.close()
    assert len(done) == 6
    trips = {k: s.trips for k, s in eng.sentinels.items()}
    assert {"decode", "prefill", "verify"} <= set(trips)
    assert all(t == 0 for t in trips.values()), trips
    assert eng.metrics.summary()["spec_bursts"] > 0


def test_speculate_k_validation():
    model, params = _model_params("mamba2")
    with pytest.raises(ValueError, match="speculate_k"):
        ContinuousEngine(model, params,
                         ServeConfig(max_batch=1, prefill_buckets=(8,),
                                     max_new_tokens=2, speculate_k=-1))
