"""Checkpointing (atomic, async, GC, resume) + fault-tolerance runtime."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, all_steps, ckpt,
                              latest_step, restore, save)
from repro.runtime import (RestartPolicy, StepMonitor, Watchdog,
                           run_with_restarts)


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 8)),
                                    jnp.bfloat16),
                   "b": jnp.asarray(rng.standard_normal((8,)), jnp.float32)},
        "opt": {"step": jnp.int32(7),
                "m": {"w": jnp.zeros((4, 8)), "b": jnp.ones((8,))}},
    }


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    save(tmp_path, 7, state, extra={"data_step": 7})
    got, step, extra = restore(tmp_path, state)
    assert step == 7 and extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_gc_keeps_last_k(tmp_path):
    state = _state()
    for s in range(6):
        save(tmp_path, s, state, keep=3)
    assert all_steps(tmp_path) == [3, 4, 5]


def test_partial_write_is_invisible(tmp_path):
    state = _state()
    save(tmp_path, 1, state)
    # simulate a crash mid-write: a stale .tmp dir must be ignored
    (tmp_path / "step_00000002.tmp").mkdir()
    assert latest_step(tmp_path) == 1
    got, step, _ = restore(tmp_path, state)
    assert step == 1


def test_async_checkpointer(tmp_path):
    state = _state()
    w = AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3):
        w.save(s, state)
    w.wait()
    assert latest_step(tmp_path) == 3


def test_restore_casts_dtypes(tmp_path):
    state = _state()
    save(tmp_path, 1, state)
    target = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    got, _, _ = restore(tmp_path, target)
    assert got["params"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Runtime
# ---------------------------------------------------------------------------

def test_step_monitor_flags_stragglers():
    mon = StepMonitor(straggler_factor=2.0, warmup_steps=3)
    for s in range(10):
        mon.observe(s, 0.1)
    rec = mon.observe(10, 0.5)
    assert rec.straggler
    assert mon.summary()["stragglers"] == 1


def test_watchdog_fires_on_hang():
    fired = []
    w = Watchdog(0.2, on_hang=lambda: fired.append(1))
    time.sleep(0.6)
    w.stop()
    assert fired


def test_restart_loop_recovers_from_crashes(tmp_path):
    policy = RestartPolicy(max_restarts=5, ckpt_dir=str(tmp_path))
    crashes = {"left": 2}

    def train_some(state, start):
        for s in range(start, start + 5):
            state = {"x": state["x"] + 1.0,
                     "opt": {"step": jnp.int32(s + 1),
                             "m": state["opt"]["m"],
                             "v": state["opt"]["v"]},
                     "params": state["params"]}
            if s == 7 and crashes["left"] > 0:
                crashes["left"] -= 1
                raise RuntimeError("injected node failure")
        return state, start + 5

    init = {"x": jnp.float32(0), "params": {"w": jnp.zeros(2)},
            "opt": {"step": jnp.int32(0), "m": jnp.zeros(2),
                    "v": jnp.zeros(2)}}
    state, step, restarts, crash_loops = run_with_restarts(
        train_some, init, policy, target_steps=20)
    assert step == 20
    assert restarts == 2
    # Both crashes hit the same step boundary (start=5), so the loop
    # flags a crash loop there — distinct from transient-failure restarts.
    assert crash_loops == [5]
    # progress was preserved across the crash (x counts every good step)
    assert float(state["x"]) == 20.0
