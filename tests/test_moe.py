"""MoE dispatch correctness: scatter/gather combine vs explicit reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig
from repro.nn import moe
from repro.nn.params import init_params


def _cfg(capacity_factor=8.0):
    return ModelConfig(name="moe", family="transformer", vocab_size=64,
                       d_model=16, n_layers=1, moe=True, n_experts=4,
                       n_experts_per_token=2, moe_d_ff=24,
                       capacity_factor=capacity_factor,
                       param_dtype="float32")


def _reference(params, cfg, x):
    """Dense reference: run every expert on every token, combine by gates."""
    b, s, d = x.shape
    xf = x.reshape(-1, d).astype(jnp.float32)
    logits = xf @ params["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.n_experts_per_token)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        hi = xf @ params["wi"][e]
        hg = xf @ params["wg"][e]
        h = jax.nn.silu(hg) * hi
        outs.append(h @ params["wo"][e])
    outs = jnp.stack(outs, 1)                      # (n, e, d)
    y = jnp.zeros_like(xf)
    for k in range(cfg.n_experts_per_token):
        y += gate_vals[:, k:k + 1] * jnp.take_along_axis(
            outs, expert_ids[:, k][:, None, None].repeat(outs.shape[-1], -1),
            axis=1)[:, 0]
    return y.reshape(b, s, d)


def test_moe_matches_dense_reference_without_drops(rng):
    cfg = _cfg(capacity_factor=8.0)   # big capacity: nothing drops
    params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    y, aux = moe.apply(params, cfg, x)
    want = _reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_are_bounded(rng):
    cfg = _cfg(capacity_factor=1.0)
    params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 16, 16)), jnp.float32)
    y, _ = moe.apply(params, cfg, x)
    ref_out = _reference(params, cfg, x)
    # dropped tokens -> zero contribution; the rest must match the reference
    match = np.isclose(np.asarray(y), np.asarray(ref_out),
                       rtol=1e-3, atol=1e-3).all(axis=-1)
    assert match.mean() > 0.3  # capacity 1.0 with top-2 keeps >~ half
    assert np.isfinite(np.asarray(y)).all()


def test_moe_grads_flow(rng):
    cfg = _cfg()
    params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 8, 16)), jnp.float32)

    def loss(p):
        y, aux = moe.apply(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    assert float(jnp.abs(g["wi"]).max()) > 0
    assert float(jnp.abs(g["router"]["w"]).max()) > 0
