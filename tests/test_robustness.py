"""Fault-tolerant serving (docs/robustness.md): fault-injection harness,
overload backpressure, poison quarantine, backend fallback, watchdog
recovery — the chaos tests' core invariant is that *healthy* requests
stay byte-identical to a fault-free control run."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import trace_report
from repro.models import ModelConfig, build_model
from repro.nn.params import init_params
from repro.runtime.elastic import backoff_delay_s
from repro.runtime.faults import (FaultEvent, FaultInjector,
                                  InjectedBackendError, parse_plan)
from repro.runtime.health import StepMonitor, Watchdog
from repro.serve import ContinuousEngine, Request, Scheduler, ServeConfig

V = 64

CFG = ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                  d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                  chunk_size=8, param_dtype="float32")


def _model_params(cfg=CFG):
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


def _prompts(seed, n, length):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, V, length).tolist() for _ in range(n)]


def _run(model, params, scfg, prompts, budgets=None):
    eng = ContinuousEngine(model, params, scfg)
    for i, p in enumerate(prompts):
        eng.submit(p, budgets[i] if budgets else None)
    done = eng.run()
    eng.close()
    return eng, {r.uid: r for r in done}


# ---------------------------------------------------------------------------
# fault-injection harness (unit)
# ---------------------------------------------------------------------------
def test_parse_plan_round_trip():
    plan = parse_plan("poison@5:slot=1,mode=inf; fail@8:program=decode;"
                      "stall@3:stall_s=0.25")
    assert [ev.kind for ev in plan] == ["poison", "fail", "stall"]
    assert plan[0].poll == 5 and plan[0].slot == 1 and plan[0].mode == "inf"
    assert plan[1].program == "decode"
    assert plan[2].stall_s == 0.25


@pytest.mark.parametrize("spec", ["boom@3", "poison@3:mode=zero",
                                  "poison5", "poison@3:volume=11"])
def test_parse_plan_rejects_bad_specs(spec):
    with pytest.raises(ValueError):
        parse_plan(spec)


def test_injector_fires_once_and_reports():
    inj = FaultInjector("fail@2:program=decode;poison@4", seed=7)
    inj.pre_call("decode", 1)                       # not due yet
    with pytest.raises(InjectedBackendError):
        inj.pre_call("decode", 3)                   # due (poll >= 2)
    inj.pre_call("decode", 4)                       # fired: never again
    assert inj.poison_targets(3, [0, 1]) == []      # not due
    assert inj.poison_targets(4, []) == []          # waits for live slots
    assert inj.poison_targets(5, [1, 2]) == [(1, "nan")]
    assert inj.poison_targets(6, [1, 2]) == []      # fired
    s = inj.summary()
    assert s == {"fired": {"poison": 1, "fail": 1}, "pending": {},
                 "events": 2}


def test_poison_payload_and_corrupt():
    inj = FaultInjector([FaultEvent("poison", 0)], seed=3)
    x = inj.poison_payload((4, 8), "nan")
    assert np.isnan(x).any() and not np.isinf(x).any()
    x = inj.poison_payload((4, 8), "inf")
    assert np.isinf(x).any()
    tree = {"f": np.ones((2, 3), np.float32), "i": np.arange(4, dtype=np.int32)}
    bad = inj.corrupt(tree, "nan")
    assert not np.isfinite(bad["f"]).all()
    np.testing.assert_array_equal(bad["i"], tree["i"])   # ints untouched


# ---------------------------------------------------------------------------
# satellites: health + backoff primitives
# ---------------------------------------------------------------------------
def test_backoff_delay_doubles_and_caps():
    assert [backoff_delay_s(k, 0.5, cap_s=3.0) for k in (1, 2, 3, 4, 5)] \
        == [0.5, 1.0, 2.0, 3.0, 3.0]


def test_step_monitor_rolling_window_constant_memory():
    mon = StepMonitor(window=10)
    for _ in range(200):
        mon.observe(None, 0.01)
    assert len(mon.records) == 10 and len(mon._durations) == 10
    s = mon.summary()
    assert s["steps"] == 200 and s["mean_s"] == pytest.approx(0.01)
    # step defaults to the cumulative count, not the trimmed list length
    assert mon.records[-1].step == 199


def test_watchdog_latches_until_pet():
    fires = []
    wd = Watchdog(0.08, on_hang=lambda: fires.append(time.monotonic()))
    try:
        time.sleep(0.4)
        assert wd.fired and len(fires) == 1     # latched: no re-fire
        wd.pet()
        time.sleep(0.4)
        assert len(fires) == 2                  # new hang after the pet
    finally:
        wd.stop()
    assert not wd.alive


def test_scheduler_defers_retry_backoff():
    sched = Scheduler("fcfs")
    req = Request(uid=1, prompt=[1], max_new_tokens=1, not_before_s=100.0)
    sched.submit(req)
    assert sched.pop_ready(now=50.0) is None
    assert len(sched) == 1                      # deferred, not dropped
    assert sched.pop_ready(now=150.0) is req


# ---------------------------------------------------------------------------
# chaos: poison quarantine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("probe", ["logits", "state"])
def test_poison_quarantine_healthy_rows_identical(probe):
    model, params = _model_params()
    prompts = _prompts(3, 4, 12)
    base = dict(max_batch=2, prefill_buckets=(16,), max_new_tokens=6)
    _, control = _run(model, params, ServeConfig(**base), prompts)

    eng, done = _run(model, params, ServeConfig(
        **base, poison_probe=probe, fault_plan="poison@3:slot=0"), prompts)
    poisoned = [r for r in done.values() if r.status == "poisoned"]
    healthy = [r for r in done.values() if r.status == "ok"]
    assert len(poisoned) == 1 and len(healthy) == 3
    for r in healthy:                    # blast radius: one slot, not four
        assert r.out_tokens == control[r.uid].out_tokens, f"uid={r.uid}"
    assert eng.metrics.quarantined == 1
    assert eng.metrics.shed_reasons == {"poison": 1}
    assert eng._injector.summary()["fired"] == {"poison": 1}
    # quarantine resets the row; compile-once discipline must survive
    assert all(s.trips == 0 for s in eng.sentinels.values())


def test_poison_quarantine_not_counted_as_completion():
    model, params = _model_params()
    prompts = _prompts(5, 2, 12)
    eng, done = _run(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=6,
        poison_probe="logits", fault_plan="poison@2:slot=0"), prompts)
    assert len(done) == 2                # the caller still sees the casualty
    assert eng.metrics.completed == 1
    assert sum(eng.metrics.shed_reasons.values()) == eng.metrics.shed


# ---------------------------------------------------------------------------
# chaos: backend fallback
# ---------------------------------------------------------------------------
def test_injected_backend_failure_falls_back_identically():
    cfg = CFG.with_decode_mode("cumba")
    model, params = _model_params(cfg)
    prompts = _prompts(7, 3, 12)
    base = dict(max_batch=2, prefill_buckets=(16,), max_new_tokens=6)
    _, control = _run(model, params, ServeConfig(**base), prompts)

    eng, done = _run(model, params, ServeConfig(
        **base, fault_plan="fail@3:program=decode"), prompts)
    assert eng.model.cfg.xamba.decode == "naive"     # one rung down
    assert eng.metrics.backend_fallbacks == 1
    for uid, r in done.items():          # every decode mode is numerically
        assert r.status == "ok"          # the same program
        assert r.out_tokens == control[uid].out_tokens
    # fallback-rebuilt jits lazily re-arm their sentinels: 0 trips
    assert all(s.trips == 0 for s in eng.sentinels.values())


def test_backend_failure_without_fallback_raises():
    model, params = _model_params(CFG.with_decode_mode("cumba"))
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=1, prefill_buckets=(16,), max_new_tokens=4,
        backend_fallback=False, fault_plan="fail@1:program=decode"))
    eng.submit(_prompts(9, 1, 12)[0])
    with pytest.raises(InjectedBackendError):
        eng.run()
    eng.close()


def test_injected_stall_fires_inside_timing_window():
    model, params = _model_params()
    prompts = _prompts(11, 2, 12)
    base = dict(max_batch=2, prefill_buckets=(16,), max_new_tokens=6)
    _, control = _run(model, params, ServeConfig(**base), prompts)
    eng, done = _run(model, params, ServeConfig(
        **base, fault_plan="stall@3:program=decode,stall_s=0.05"), prompts)
    assert eng._injector.summary()["fired"] == {"stall": 1}
    assert eng.monitor_decode.max_s >= 0.05
    for uid, r in done.items():          # a stall delays, never corrupts
        assert r.out_tokens == control[uid].out_tokens


# ---------------------------------------------------------------------------
# overload protection
# ---------------------------------------------------------------------------
def test_bounded_queue_rejects_with_backpressure():
    model, params = _model_params()
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=1, prefill_buckets=(16,), max_new_tokens=3,
        max_queue_depth=2))
    prompts = _prompts(13, 4, 10)
    uids = [eng.submit(p) for p in prompts]
    assert uids[0] is not None and uids[1] is not None
    assert uids[2] is None and uids[3] is None       # explicit refusal
    assert eng.metrics.rejected == 2
    done = eng.run()
    eng.close()
    assert len(done) == 2                # accepted work completes normally


def test_overload_mode_enters_and_clears():
    model, params = _model_params()
    prompts = _prompts(17, 5, 10)
    base = dict(max_batch=1, prefill_buckets=(16,), max_new_tokens=3)
    _, control = _run(model, params, ServeConfig(**base), prompts)
    eng, done = _run(model, params, ServeConfig(
        **base, overload_queue_depth=2), prompts)
    assert eng.metrics.overload_entries >= 1
    assert eng.metrics.overload_exits == eng.metrics.overload_entries
    assert not eng._overloaded           # drained: hysteresis cleared it
    for uid, r in done.items():          # degraded mode sheds *work rate*,
        assert r.out_tokens == control[uid].out_tokens   # never tokens


def test_shed_inflight_deadline():
    model, params = _model_params()
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=1, prefill_buckets=(16,), max_new_tokens=50,
        shed_inflight=True))
    uid = eng.submit(_prompts(19, 1, 10)[0],
                     deadline_s=time.time() + 3600)
    eng.poll()                           # admitted, decoding
    victim = eng._slot_req[0]
    assert victim is not None and victim.uid == uid
    victim.deadline_s = time.time() - 1.0
    eng.poll()                           # SLA passed mid-flight: shed
    eng.close()
    assert victim.status == "shed_deadline" and victim.expired
    assert eng.metrics.shed_reasons == {"deadline": 1}
    assert eng._slot_req[0] is None      # capacity freed for live work
    assert not eng.busy


# ---------------------------------------------------------------------------
# watchdog recovery + retries
# ---------------------------------------------------------------------------
def test_watchdog_recovery_requeues_and_replays_identically():
    model, params = _model_params()
    prompts = _prompts(23, 2, 12)
    base = dict(max_batch=1, prefill_buckets=(16,), max_new_tokens=5)
    _, control = _run(model, params, ServeConfig(**base), prompts)

    eng = ContinuousEngine(model, params, ServeConfig(
        **base, watchdog_action="recover", max_retries=1))
    for p in prompts:
        eng.submit(p)
    eng.poll()                           # request 1 is mid-decode
    eng._on_hang()                       # what the watchdog thread would do
    done = eng.run()
    eng.close()
    assert eng.metrics.watchdog_recoveries == 1
    assert eng.metrics.retries == 1
    assert len(done) == 2
    for r in done:                       # keyed sampling: the replayed
        assert r.status == "ok"          # stream is byte-identical
        assert r.retries in (0, 1)
        assert r.out_tokens == control[r.uid].out_tokens


def test_retry_budget_exhaustion_sheds():
    model, params = _model_params()
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=1, prefill_buckets=(16,), max_new_tokens=5,
        watchdog_action="recover", max_retries=0))
    eng.submit(_prompts(29, 1, 10)[0])
    eng.poll()
    eng._on_hang()
    done = eng.run()
    eng.close()
    assert [r.status for r in done] == ["retry_exhausted"]
    assert eng.metrics.shed_reasons == {"retry_exhausted": 1}
    assert sum(eng.metrics.shed_reasons.values()) == eng.metrics.shed
    assert not eng.busy


def test_retry_backoff_defers_readmission():
    model, params = _model_params()
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=1, prefill_buckets=(16,), max_new_tokens=4,
        watchdog_action="recover", max_retries=2, retry_backoff_s=30.0))
    eng.submit(_prompts(31, 1, 10)[0])
    eng.poll()
    eng._on_hang()
    eng.poll()                           # recovery requeues with backoff
    req = eng.scheduler.pop_ready(time.time())
    assert req is None                   # not_before_s is ~30s out
    eng.close()


# ---------------------------------------------------------------------------
# prefix-snapshot faults
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fault", ["snap_corrupt", "snap_drop"])
def test_snapshot_fault_never_poisons_the_prefix_cache(fault):
    model, params = _model_params()
    prompt = _prompts(37, 1, 16)[0]
    base = dict(max_batch=1, prefill_buckets=(16,), max_new_tokens=4,
                prefill_chunk=8, prefix_cache_mb=4.0)
    _, control = _run(model, params, ServeConfig(**base), [prompt])

    eng = ContinuousEngine(model, params, ServeConfig(
        **base, poison_probe="logits", fault_plan=f"{fault}@0"))
    eng.submit(prompt)
    (first,) = eng.run()
    # The faulted insert (dropped or corrupt-and-refused) left NO node —
    # and crucially no NaN node a later request could restore from.
    assert eng.prefix_cache.stats()["nodes"] == 0
    eng.submit(prompt)                   # same prompt again: clean miss
    (second,) = eng.run()
    eng.close()
    assert first.out_tokens == control[1].out_tokens
    assert second.out_tokens == control[1].out_tokens
    assert eng.prefix_cache.stats()["hits"] == 0
    assert eng.prefix_cache.stats()["nodes"] > 0     # post-fault inserts OK


# ---------------------------------------------------------------------------
# observability: fault instants in the trace report
# ---------------------------------------------------------------------------
def test_trace_report_tallies_fault_events_and_check_passes(tmp_path):
    model, params = _model_params(CFG.with_decode_mode("cumba"))
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=6,
        poison_probe="logits", trace=str(tmp_path / "t.json"),
        fault_plan="poison@3:slot=0;fail@5:program=decode"))
    for p in _prompts(41, 4, 12):
        eng.submit(p)
    eng.run()
    eng.close()
    path = tmp_path / "t.jsonl"
    eng.tracer.save_jsonl(str(path))
    rep = trace_report.analyze(trace_report.load_events(str(path)))
    assert rep["fault_events"]["quarantine"] == 1
    assert rep["fault_events"]["backend_fallback"] == 1
    # fault instants are zero-duration: the phase-coverage reconciliation
    # and the compile-once audit still hold on a chaotic trace
    assert trace_report.check(rep) == []
