"""Fused decode-step subsystem: kernel/reference/full-sequence parity
across every XambaConfig decode mode, the pre-sliced decode view, the
grouped recurrentgemma cache layout, donation compile-once, and the
deprecated ``apply`` shim.

``pallas`` (compiled) needs a TPU; ``pallas_interpret`` runs the same
kernel bodies on CPU and is what CI exercises.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selective_scan as sscan, ssd as ssd_mod
from repro.core.xamba import XambaConfig
from repro.kernels import ops as kops, ref
from repro.models import ModelConfig, build_model
from repro.nn.params import init_params, restack_layers
from repro.serve import ContinuousEngine, ServeConfig

V = 64
MODES = ("naive", "cumba", "pallas_interpret")

CFGS = {
    "mamba2": ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                          chunk_size=8, param_dtype="float32"),
    "mamba1": ModelConfig(name="mamba1", family="mamba", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8,
                          param_dtype="float32"),
    "rglru": ModelConfig(name="rglru", family="recurrentgemma", vocab_size=V,
                         d_model=32, n_layers=3, n_heads=4, n_kv_heads=1,
                         head_dim=8, d_ff=96, mlp_type="geglu", lru_width=32,
                         sliding_window=8, scan_layers=True,
                         param_dtype="float32"),
}


def _with_mode(cfg, mode, **xkw):
    return dataclasses.replace(cfg, xamba=XambaConfig(decode=mode, **xkw))


def _params(cfg, seed=0):
    return init_params(build_model(cfg).param_specs(),
                       jax.random.PRNGKey(seed), jnp.float32)


# ---------------------------------------------------------------------------
# core-level decode steps: every mode ties the oracle at <= 1e-5
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", MODES)
def test_ssd_decode_step_modes_tie_reference(mode):
    rng = np.random.default_rng(0)
    b, h, p, n, g = 3, 4, 8, 16, 2
    state = jnp.asarray(rng.normal(size=(b, h, p, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 1.0, size=(b, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.1, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, g, n)), jnp.float32)
    ns, y = ssd_mod.ssd_decode_step(state, x, dt, A, B, C, mode=mode)
    ns_r, y_r = ref.ssd_step_ref(state, x, dt, A, B, C)
    assert float(jnp.abs(ns - ns_r).max()) <= 1e-5
    assert float(jnp.abs(y - y_r).max()) <= 1e-5


@pytest.mark.parametrize("mode", MODES)
def test_selective_scan_decode_step_modes_tie_reference(mode):
    rng = np.random.default_rng(1)
    b, d, n = 3, 12, 8
    state = jnp.asarray(rng.normal(size=(b, d, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 1.0, size=(b, d)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.1, 2.0, size=(d, n)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    ns, y = sscan.selective_scan_decode_step(state, u, dt, A, B, C, D,
                                             mode=mode)
    ns_r, y_r = ref.sscan_step_ref(state, u, dt, A, B, C, D)
    assert float(jnp.abs(ns - ns_r).max()) <= 1e-5
    assert float(jnp.abs(y - y_r).max()) <= 1e-5


# ---------------------------------------------------------------------------
# fused mixer kernels: pallas_interpret ties the jnp oracle at <= 1e-5
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("actiba", [False, True], ids=["exact", "actiba"])
def test_mamba2_fused_kernel_ties_reference(actiba):
    rng = np.random.default_rng(2)
    xamba = XambaConfig(decode="pallas_interpret", actiba=actiba)
    b, h, p, n, g, w = 2, 4, 8, 16, 2, 4
    di = h * p
    dxbc = di + 2 * g * n
    f = jnp.float32
    args = (jnp.asarray(rng.normal(size=(b, di)), f),
            jnp.asarray(rng.normal(size=(b, dxbc)), f),
            jnp.asarray(rng.normal(size=(b, h)), f),
            jnp.asarray(rng.normal(size=(b, w - 1, dxbc)), f),
            jnp.asarray(rng.normal(size=(b, h, p, n)), f),
            jnp.asarray(rng.normal(size=(w, dxbc)) * 0.3, f),
            jnp.asarray(rng.normal(size=(dxbc,)) * 0.1, f),
            jnp.asarray(rng.normal(size=(h,)) * 0.1, f),
            -jnp.asarray(rng.uniform(0.1, 2.0, size=(h,)), f),
            jnp.asarray(rng.normal(size=(h,)), f),
            jnp.asarray(rng.normal(size=(di,)), f))
    got = kops.mamba2_decode_step(*args, ngroups=g, head_dim=p, xamba=xamba,
                                  interpret=True)
    from repro.core import pwl
    want = ref.mamba2_step_ref(*args, ngroups=g, head_dim=p,
                               silu=pwl.activation("silu", xamba),
                               softplus=pwl.activation("softplus", xamba))
    for a, r in zip(got, want):
        assert float(jnp.abs(a - r).max()) <= 1e-5


def test_mamba1_fused_kernel_ties_reference():
    rng = np.random.default_rng(3)
    b, d, n, w, r_ = 2, 12, 8, 4, 6
    f = jnp.float32
    args = (jnp.asarray(rng.normal(size=(b, d)), f),
            jnp.asarray(rng.normal(size=(b, d)), f),
            jnp.asarray(rng.normal(size=(b, w - 1, d)), f),
            jnp.asarray(rng.normal(size=(b, d, n)), f),
            jnp.asarray(rng.normal(size=(w, d)) * 0.3, f),
            jnp.asarray(rng.normal(size=(d,)) * 0.1, f),
            jnp.asarray(rng.normal(size=(d, r_ + 2 * n)) * 0.2, f),
            jnp.asarray(rng.normal(size=(r_, d)) * 0.2, f),
            jnp.asarray(rng.normal(size=(d,)) * 0.1, f),
            -jnp.asarray(rng.uniform(0.1, 2.0, size=(d, n)), f),
            jnp.asarray(rng.normal(size=(d,)), f))
    got = kops.mamba1_decode_step(*args, dt_rank=r_, interpret=True)
    want = ref.mamba1_step_ref(*args, dt_rank=r_)
    for a, r in zip(got, want):
        assert float(jnp.abs(a - r).max()) <= 1e-5


def test_rglru_fused_kernel_ties_reference():
    rng = np.random.default_rng(4)
    b, wd, wc = 2, 16, 4
    f = jnp.float32
    args = (jnp.asarray(rng.normal(size=(b, wd)), f),
            jnp.asarray(rng.normal(size=(b, wd)), f),
            jnp.asarray(rng.normal(size=(b, wc - 1, wd)), f),
            jnp.asarray(rng.normal(size=(b, wd)), f),
            jnp.asarray(rng.normal(size=(wc, wd)) * 0.3, f),
            jnp.asarray(rng.normal(size=(wd,)) * 0.1, f),
            jnp.asarray(rng.normal(size=(wd, wd)) * 0.2, f),
            jnp.asarray(rng.normal(size=(wd,)) * 0.1, f),
            jnp.asarray(rng.normal(size=(wd, wd)) * 0.2, f),
            jnp.asarray(rng.normal(size=(wd,)) * 0.1, f),
            jnp.asarray(rng.uniform(0.5, 2.0, size=(wd,)), f))
    got = kops.rglru_decode_step(*args, interpret=True)
    want = ref.rglru_step_ref(*args)
    for a, r in zip(got, want):
        assert float(jnp.abs(a - r).max()) <= 1e-5


# ---------------------------------------------------------------------------
# model-level: fused decode == reference step == force_prefill_path slice
# ---------------------------------------------------------------------------
def _full_logits(cfg, params, tokens):
    model = build_model(cfg)
    if cfg.family in ("mamba", "mamba2"):
        return model.forward(params, tokens)
    x = model._embed(params, tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
    h, _ = model._trunk(params, x, positions)
    return model._logits(params, h)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("family", ["mamba2", "mamba1", "rglru"])
def test_decode_modes_match_full_forward(family, mode):
    cfg = _with_mode(CFGS[family], mode)
    model = build_model(cfg)
    params = _params(CFGS[family])
    S, P = 16, 12
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, S), 0, V)
    full = _full_logits(CFGS[family], params, tokens)

    cache = model.init_cache(2, S, jnp.float32)
    logits, cache = model.prefill(params, {"tokens": tokens[:, :P]}, cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1]),
                               rtol=5e-4, atol=5e-4)
    for t in range(P, S):
        logits, cache = model.decode_step(params, tokens[:, t:t + 1], cache,
                                          jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]),
            rtol=5e-4, atol=5e-4, err_msg=f"{family}/{mode} t={t}")


@pytest.mark.parametrize("family", ["mamba2", "mamba1"])
def test_decode_matches_force_prefill_path_slice(family):
    """The O(1) fused step == re-running the full-sequence (chunked) form
    one token longer — the paper's two-model equivalence."""
    cfg = _with_mode(CFGS[family], "cumba")
    model = build_model(cfg)
    fp = build_model(dataclasses.replace(cfg, force_prefill_path=True))
    params = _params(CFGS[family])
    S, P = 14, 10
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, S), 0, V)

    cache = model.init_cache(2, S, jnp.float32)
    _, cache = model.prefill(params, {"tokens": tokens[:, :P]}, cache)
    cache_fp = fp.init_cache(2, S, jnp.float32)
    _, cache_fp = fp.prefill(params, {"tokens": tokens[:, :P]}, cache_fp)
    for t in range(P, S):
        tok = tokens[:, t:t + 1]
        logits, cache = model.decode_step(params, tok, cache, jnp.int32(t))
        logits_fp, cache_fp = fp.decode_step(params, tok, cache_fp,
                                             jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_fp),
                                   rtol=5e-4, atol=5e-4, err_msg=f"t={t}")


# ---------------------------------------------------------------------------
# decode view / stacked layouts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["mamba2", "mamba1"])
def test_decode_view_matches_stacked(family):
    cfg = _with_mode(CFGS[family], "cumba")
    model = build_model(cfg)
    params = _params(CFGS[family])
    view = model.decode_view(params)
    assert isinstance(view["layers"], tuple)
    # idempotent
    assert model.decode_view(view) is view or \
        isinstance(model.decode_view(view)["layers"], tuple)

    cache = model.init_cache(2, 16, jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, V)
    _, cache = model.prefill(params, {"tokens": tokens}, cache)
    tok = tokens[:, :1]
    l_stacked, c_stacked = model.decode_step(params, tok, cache, jnp.int32(8))
    l_view, c_view = model.decode_step(view, tok, cache, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(l_stacked), np.asarray(l_view),
                               rtol=1e-5, atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
        c_stacked, c_view)


def test_rglru_grouped_scan_matches_per_layer_loop():
    cfg = CFGS["rglru"]
    model = build_model(cfg)                                   # grouped
    loop = build_model(dataclasses.replace(cfg, scan_layers=False))
    params = _params(cfg)
    S, P = 14, 10
    tokens = jax.random.randint(jax.random.PRNGKey(8), (2, S), 0, V)

    cache_g = model.init_cache(2, S, jnp.float32)
    assert isinstance(cache_g, dict) and "groups" in cache_g
    cache_l = loop.init_cache(2, S, jnp.float32)
    lg, cache_g = model.prefill(params, {"tokens": tokens[:, :P]}, cache_g)
    ll, cache_l = loop.prefill(params, {"tokens": tokens[:, :P]}, cache_l)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ll),
                               rtol=1e-5, atol=1e-5)
    for t in range(P, S):
        tok = tokens[:, t:t + 1]
        lg, cache_g = model.decode_step(params, tok, cache_g, jnp.int32(t))
        ll, cache_l = loop.decode_step(params, tok, cache_l, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ll),
                                   rtol=1e-5, atol=1e-5, err_msg=f"t={t}")


def test_restack_layers_matches_per_layer_params():
    loop_cfg = dataclasses.replace(CFGS["mamba2"], scan_layers=False)
    loop = build_model(loop_cfg)
    params = _params(loop_cfg)
    stacked = build_model(CFGS["mamba2"])
    sparams = dict(params, layers=restack_layers(params["layers"]))
    tokens = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, V)
    np.testing.assert_allclose(
        np.asarray(loop.forward(params, tokens)),
        np.asarray(stacked.forward(sparams, tokens)), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# serving: greedy identity through the continuous engine + compile-once
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["cumba", "pallas_interpret"])
def test_continuous_engine_greedy_identity_fused(mode):
    """The donated, pooled, slot-scheduled engine emits exactly the tokens
    of a manual prefill + decode_step loop in the same decode mode."""
    cfg = _with_mode(CFGS["mamba2"], mode)
    model = build_model(cfg)
    params = _params(CFGS["mamba2"])
    prompts = [list(range(1, 9)), list(range(9, 17))]
    max_new = 4

    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(8,), max_new_tokens=max_new))
    for p in prompts:
        eng.submit(p)
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert eng.counters["decode_compiles"] in (1, "unavailable")

    cache = model.init_cache(2, 8 + max_new, jnp.float32)
    toks = jnp.asarray(prompts, jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    cur = jnp.argmax(logits, -1)
    outs = [[int(c)] for c in cur]
    for t in range(1, max_new):
        logits, cache = model.decode_step(params, cur[:, None], cache,
                                          jnp.int32(8 + t - 1))
        cur = jnp.argmax(logits, -1)
        for i in range(2):
            outs[i].append(int(cur[i]))
    for uid, manual in zip(sorted(done), outs):
        assert done[uid] == manual, f"uid={uid} mode={mode}"


def test_donated_decode_compiles_once_across_turnover():
    """Slot turnover + donation: the decode program still compiles exactly
    once and the pool arena survives being donated every step."""
    cfg = _with_mode(CFGS["mamba2"], "cumba")
    model = build_model(cfg)
    params = _params(CFGS["mamba2"])
    rng = np.random.default_rng(11)
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(8, 16), max_new_tokens=3))
    for n in (6, 14, 7, 13, 5):
        eng.submit(rng.integers(1, V, n).tolist())
    done = eng.run()
    assert len(done) == 5 and all(len(r.out_tokens) == 3 for r in done)
    assert eng.counters["decode_compiles"] in (1, "unavailable")
    assert eng.counters["prefill_compiles"] in (2, "unavailable")


# ---------------------------------------------------------------------------
# deprecated apply() shim
# ---------------------------------------------------------------------------
def test_apply_shim_dispatches_and_warns():
    cfg = CFGS["mamba2"]
    model = build_model(cfg)
    params = _params(cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(12), (2, 8), 0, V)
    cache = model.init_cache(2, 12, jnp.float32)

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        logits, cache2 = model.apply(params, tokens, state=cache)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    want, _ = model.prefill(params, {"tokens": tokens}, cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=1e-6, atol=1e-6)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        l2, _ = model.apply(params, tokens[:, :1], state=cache2,
                            index=jnp.int32(8))
        want2, _ = model.decode_step(params, tokens[:, :1], cache2,
                                     jnp.int32(8))
        # single-token dispatch without a position is an error, not a
        # silent position-0 KV write
        with pytest.raises(TypeError):
            model.apply(params, tokens[:, :1], state=cache2)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(want2),
                               rtol=1e-6, atol=1e-6)
