"""Data pipeline: packing invariants, determinism, prefetch."""
import numpy as np

from repro.data import (DataConfig, PrefetchIterator, SyntheticLM,
                        batch_packed, pack_documents)


def test_synthetic_deterministic_and_structured():
    cfg = DataConfig(vocab_size=128, seq_len=64, global_batch=4, seed=3)
    a = SyntheticLM(cfg).next()
    b = SyntheticLM(cfg).next()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 64)
    assert a["tokens"].min() >= 1 and a["tokens"].max() < 128
    # the induction span: last span repeats an earlier span
    toks = a["tokens"][0]
    span = toks[-16:]
    found = any((toks[i:i + 16] == span).all() for i in range(0, 64 - 32))
    assert found


def test_pack_documents_invariants(rng):
    docs = [list(rng.integers(1, 99, int(n))) for n in
            rng.integers(1, 40, size=25)]
    total = sum(len(d) for d in docs)
    rows = list(pack_documents(docs, seq_len=32))
    # every token survives packing exactly once
    packed_tokens = sum(int((r["segments"] > 0).sum()) for r in rows)
    assert packed_tokens == total
    for r in rows:
        assert r["tokens"].shape == (32,)
        # no label crosses a document boundary
        seg = r["segments"]
        lab = r["labels"]
        for i in range(32):
            if seg[i] == 0:
                assert lab[i] == -1
            elif i > 0 and seg[i] != seg[i - 1]:
                assert lab[i] == -1  # first token of a new doc is masked


def test_batch_packed_shapes(rng):
    docs = [list(rng.integers(1, 99, 20)) for _ in range(20)]
    batches = list(batch_packed(pack_documents(docs, 16), batch=4))
    assert batches and all(b["tokens"].shape == (4, 16) for b in batches)


def test_prefetch_iterator_passthrough():
    it = PrefetchIterator(iter(range(10)), prefetch=3)
    assert list(it) == list(range(10))


def test_prefetch_surfaces_errors():
    def gen():
        yield 1
        raise ValueError("boom")
    it = PrefetchIterator(gen())
    assert next(it) == 1
    try:
        next(it)
        raised = False
    except ValueError:
        raised = True
    assert raised
