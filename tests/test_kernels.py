"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pwl
from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 200), (1, 513),
                                   (128, 128), (2, 2, 2, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cumba_cumsum(rng, shape, dtype):
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    got = ops.cumba_cumsum(x, interpret=True)
    want = ref.cumsum_last_ref(x)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", [(100, 37), (7, 3, 513), (1, 8), (64, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reduba_sum(rng, shape, dtype):
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    got = ops.reduba_sum(x, interpret=True)
    want = jnp.sum(x.astype(jnp.float32), axis=-1).astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("name", ["silu", "softplus", "gelu", "sigmoid"])
@pytest.mark.parametrize("segments", [8, 32])
def test_actiba_kernel_matches_table(rng, name, segments):
    t = pwl.get_table(name, segments=segments)
    x = jnp.asarray(rng.standard_normal((33, 257)) * 5, jnp.float32)
    got = ops.actiba_activate(x, t, interpret=True)
    want = ref.pwl_activate_ref(x, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mkn", [(64, 96, 130), (128, 256, 128), (17, 40, 9)])
@pytest.mark.parametrize("gated", [False, True])
def test_matmul_pwl(rng, mkn, gated):
    m, k, n = mkn
    t = pwl.get_table("silu", segments=16)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.1, jnp.float32)
    v = jnp.asarray(rng.standard_normal((k, n)) * 0.1, jnp.float32) \
        if gated else None
    got = ops.matmul_pwl(x, w, t, v, interpret=True)
    want = ref.matmul_pwl_ref(x, w, t, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dims", [(2, 3, 128, 4, 16, 2, 8),
                                  (1, 2, 256, 2, 32, 1, 16)])
def test_ssd_chunk_kernel(rng, dims):
    b, c, L, h, p, g, n = dims
    x_c = jnp.asarray(rng.standard_normal((b, c, L, h, p)), jnp.float32)
    a_c = jnp.asarray(-rng.uniform(0.001, 0.1, (b, h, c, L)), jnp.float32)
    A_cum = jnp.cumsum(a_c, axis=-1)
    B_c = jnp.asarray(rng.standard_normal((b, c, L, g, n)), jnp.float32)
    C_c = jnp.asarray(rng.standard_normal((b, c, L, g, n)), jnp.float32)
    y, st = ops.ssd_chunk(x_c, a_c, A_cum, B_c, C_c, interpret=True)
    yr, str_ = ref.ssd_chunk_ref(x_c, a_c, A_cum, B_c, C_c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", [
    dict(hq=4, hkv=2, lq=256, lk=256, causal=True, win=None),
    dict(hq=2, hkv=2, lq=128, lk=384, causal=True, win=None),
    dict(hq=4, hkv=1, lq=200, lk=200, causal=True, win=64),
    dict(hq=2, hkv=2, lq=128, lk=128, causal=False, win=None),
])
@pytest.mark.parametrize("hd", [64, 128])
def test_flash_attention(rng, cfg, hd):
    q = jnp.asarray(rng.standard_normal((2, cfg["hq"], cfg["lq"], hd)),
                    jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, cfg["hkv"], cfg["lk"], hd)),
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, cfg["hkv"], cfg["lk"], hd)),
                    jnp.float32)
    got = ops.flash_attention(q, k, v, causal=cfg["causal"],
                              window=cfg["win"], interpret=True)
    want = ref.attention_ref(q, k, v, causal=cfg["causal"],
                             window=cfg["win"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_grad_matches_reference(rng):
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, interpret=True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("shape", [(2, 300, 70), (1, 64, 512), (3, 17, 130)])
def test_rg_lru_scan(rng, shape):
    a = jnp.asarray(rng.uniform(0.5, 0.999, shape), jnp.float32)
    b = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    got = ops.rg_lru_scan(a, b, interpret=True)
    want = ref.rg_lru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
