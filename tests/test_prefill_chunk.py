"""Chunked prefill: model-level parity with whole-sequence prefill, and
engine-level identity when the continuous engine admits prompts chunk by
chunk (``ServeConfig.prefill_chunk``).

The contract under test (``models/base.py: DecodeAPI.prefill_chunk``):
feeding a prompt in fixed-size slices, threading the cache through, is
numerically the whole-sequence prefill (≤ 1e-5 fp32) and greedy decoding
from the resulting state is token-identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, Engine, ServeConfig
from repro.serve.scheduler import chunk_span

V = 64

CFGS = {
    "mamba2": ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                          chunk_size=8, param_dtype="float32"),
    "mamba1": ModelConfig(name="mamba1", family="mamba", vocab_size=V,
                          d_model=32, n_layers=2, d_state=8,
                          param_dtype="float32"),
    "dense": ModelConfig(name="dense", family="transformer", vocab_size=V,
                         d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                         head_dim=8, d_ff=64, param_dtype="float32"),
    # sliding_window == ring KV caches; scan_layers off = per-layer lists
    "rgemma": ModelConfig(name="rgemma", family="recurrentgemma",
                          vocab_size=V, d_model=32, n_layers=3, n_heads=4,
                          n_kv_heads=1, head_dim=8, d_ff=96,
                          mlp_type="geglu", lru_width=32, sliding_window=8,
                          scan_layers=False, param_dtype="float32"),
}
FAMILIES = list(CFGS)


def _model_params(name):
    cfg = CFGS[name]
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


def _chunked_prefill(model, params, toks, max_seq, chunk):
    """Feed ``toks`` through prefill_chunk in ``chunk``-sized slices."""
    cache = model.init_cache(toks.shape[0], max_seq, jnp.float32)
    off = 0
    logits = None
    while off < toks.shape[1]:
        s = min(chunk, toks.shape[1] - off)
        logits, cache = model.prefill_chunk(params, toks[:, off:off + s],
                                            cache, jnp.int32(off))
        off += s
    return logits, cache


def _greedy_continue(model, params, logits, cache, start, steps=3):
    toks = [np.asarray(jnp.argmax(logits, -1), np.int32)]
    for t in range(steps):
        tok = jnp.asarray(toks[-1][:, None], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache,
                                          jnp.int32(start + t))
        toks.append(np.asarray(jnp.argmax(logits, -1), np.int32))
    return np.stack(toks)


# ---------------------------------------------------------------------------
# model-level parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("chunk", [4, 5])   # 5 straddles every boundary
def test_chunked_matches_whole_sequence(family, chunk):
    model, params = _model_params(family)
    rng = np.random.default_rng(1)
    L, max_seq = 12, 20
    toks = jnp.asarray(rng.integers(1, V, (2, L)), jnp.int32)

    cache = model.init_cache(2, max_seq, jnp.float32)
    ref, ref_cache = model.prefill(params, {"tokens": toks}, cache)
    got, got_cache = _chunked_prefill(model, params, toks, max_seq, chunk)

    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    # greedy continuations from both caches are token-identical
    a = _greedy_continue(model, params, ref, ref_cache, L)
    b = _greedy_continue(model, params, got, got_cache, L)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("family", FAMILIES)
def test_chunk_edge_sizes(family):
    """chunk=1 (degenerates to the decode step path) and chunk >= prompt
    (degenerates to one whole-sequence call) both match prefill."""
    model, params = _model_params(family)
    rng = np.random.default_rng(2)
    L, max_seq = 6, 12
    toks = jnp.asarray(rng.integers(1, V, (1, L)), jnp.int32)
    cache = model.init_cache(1, max_seq, jnp.float32)
    ref, _ = model.prefill(params, {"tokens": toks}, cache)
    for chunk in (1, 16):
        got, _ = _chunked_prefill(model, params, toks, max_seq, chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5, err_msg=f"chunk={chunk}")


def test_whisper_chunked_matches_whole_sequence():
    cfg = ModelConfig(name="whisper", family="whisper", vocab_size=V,
                      d_model=32, n_layers=2, encoder_layers=1, n_heads=4,
                      n_kv_heads=4, head_dim=8, d_ff=64, mlp_type="mlp",
                      norm_type="layernorm", frontend="audio_stub",
                      encoder_seq=8, param_dtype="float32")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    rng = np.random.default_rng(3)
    frames = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)
    toks = jnp.asarray(rng.integers(1, V, (1, 9)), jnp.int32)
    cache = model.init_cache(1, 16, jnp.float32)
    ref, _ = model.prefill(params, {"tokens": toks, "frames": frames}, cache)
    cache = model.init_cache(1, 16, jnp.float32)
    off = 0
    while off < toks.shape[1]:
        s = min(4, toks.shape[1] - off)
        got, cache = model.prefill_chunk(
            params, {"tokens": toks[:, off:off + s], "frames": frames},
            cache, jnp.int32(off))
        off += s
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_chunk_span():
    assert chunk_span((32,), 8, 1) == 8
    assert chunk_span((32,), 8, 8) == 8
    assert chunk_span((32,), 8, 9) == 16
    assert chunk_span((32,), 8, 100) == 32    # capped at largest bucket
    assert chunk_span((30,), 8, 100) == 32    # cap rounds UP to a multiple
    assert chunk_span((32,), 8, 0) == 8       # at least one chunk


# ---------------------------------------------------------------------------
# engine-level identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", FAMILIES)
def test_engine_chunked_matches_wave_greedy(family):
    """With the bucket a chunk multiple, chunked admission pads prompts to
    the same length as the monolithic bucket — outputs must be identical
    to the wave engine, with one compiled chunk program and one decode."""
    model, params = _model_params(family)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, V, 16).tolist() for _ in range(6)]
    budgets = [2, 7, 3, 8, 2, 6]

    wave = Engine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=8))
    cont = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=8,
        prefill_chunk=8))
    for p, m in zip(prompts, budgets):
        wave.submit(p, m)
        cont.submit(p, m)
    wave_out = {r.uid: r.out_tokens for r in wave.run()}
    cont_out = {r.uid: r.out_tokens for r in cont.run()}
    assert set(wave_out) == set(cont_out)
    for uid in wave_out:
        assert cont_out[uid] == wave_out[uid], f"uid={uid}"
    assert cont.counters["decode_compiles"] == 1
    assert cont.counters["prefill_chunk_compiles"] == 1


@pytest.mark.parametrize("family", ["mamba2", "dense"])
def test_engine_chunked_mid_decode_admission_matches_solo(family):
    """A request admitted chunk-wise into a freed slot mid-decode generates
    exactly what it would generate running alone."""
    model, params = _model_params(family)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, V, 12).tolist() for _ in range(5)]
    budgets = [2, 6, 3, 6, 4]
    scfg = ServeConfig(max_batch=2, prefill_buckets=(16,), max_new_tokens=6,
                       prefill_chunk=8)
    cont = ContinuousEngine(model, params, scfg)
    for p, m in zip(prompts, budgets):
        cont.submit(p, m)
    batched = {r.uid: r.out_tokens for r in cont.run()}
    assert len(batched) == 5

    for i, (p, m) in enumerate(zip(prompts, budgets)):
        solo = ContinuousEngine(model, params, scfg)
        uid = solo.submit(p, m)
        (r,) = solo.run()
        assert r.uid == uid
        assert batched[i + 1] == r.out_tokens, f"request {i}"


def test_engine_ragged_lengths_straddle_chunks():
    """Prompt lengths straddling chunk boundaries pad to different chunk
    spans yet share one compiled chunk program; each output matches its
    solo chunked run."""
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(11)
    scfg = ServeConfig(max_batch=2, prefill_buckets=(24,), max_new_tokens=4,
                       prefill_chunk=8)
    cont = ContinuousEngine(model, params, scfg)
    lengths = (5, 8, 9, 16, 17)
    prompts = [rng.integers(1, V, n).tolist() for n in lengths]
    for p in prompts:
        cont.submit(p)
    done = {r.uid: r for r in cont.run()}
    assert len(done) == 5 and all(len(r.out_tokens) == 4
                                  for r in done.values())
    assert cont.counters["prefill_chunk_compiles"] == 1
    assert cont.counters["decode_compiles"] == 1
    for uid, r in done.items():
        solo = ContinuousEngine(model, params, scfg)
        solo.submit(r.prompt)
        (s,) = solo.run()
        assert s.out_tokens == r.out_tokens, f"uid={uid}"


def test_engine_token_budget_output_invariant():
    """A larger prefill token budget drains prompts in fewer polls but
    cannot change any request's tokens."""
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, V, 20).tolist() for _ in range(4)]

    def run(budget):
        eng = ContinuousEngine(model, params, ServeConfig(
            max_batch=2, prefill_buckets=(24,), max_new_tokens=4,
            prefill_chunk=8, prefill_token_budget=budget))
        for p in prompts:
            eng.submit(p)
        out = {r.uid: r.out_tokens for r in eng.run()}
        return out, eng.metrics.summary()["prefill_chunks"]

    base, chunks0 = run(0)
    big, chunks1 = run(64)
    assert base == big
    assert chunks1 <= chunks0                        # never more calls


def test_engine_chunk_zero_means_disabled():
    """prefill_chunk=0 (an obvious 'off' spelling) must behave exactly
    like None: monolithic bucketed prefill, no chunk machinery."""
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(15)
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=3,
        prefill_chunk=0))
    eng.submit(rng.integers(1, V, 10).tolist())
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    assert "prefill_chunk_compiles" not in eng.counters


def test_engine_chunked_eos_on_prefill_token():
    """EOS sampled from the final chunk finishes the request without it
    ever occupying a decode step; the slot is immediately reusable."""
    model, params = _model_params("mamba2")
    rng = np.random.default_rng(17)
    prompt = rng.integers(1, V, 10).tolist()
    toks = np.zeros((1, 16), np.int32)
    toks[0, 16 - len(prompt):] = prompt
    cache = model.init_cache(1, 20, jnp.float32)
    logits, _ = model.prefill(params, {"tokens": jnp.asarray(toks)}, cache)
    eos = int(np.argmax(np.asarray(logits), -1)[0])

    scfg = ServeConfig(max_batch=1, prefill_buckets=(16,), max_new_tokens=8,
                       eos_id=eos, prefill_chunk=8)
    eng = ContinuousEngine(model, params, scfg)
    eng.submit(prompt)
    other = rng.integers(1, V, 10).tolist()
    eng.submit(other)
    done = {r.uid: r for r in eng.run()}
    assert len(done) == 2 and all(r.done for r in done.values())
    assert done[1].out_tokens == [eos]
