"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; multi-device tests spawn subprocesses
with their own flags (see test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
