"""Distribution tests — run in subprocesses with their own fake device
count so the main test process keeps its single CPU device."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_sharding_rules_divisibility_fallback():
    out = _run("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.nn.params import ParamSpec
        from repro.distributed.sharding import make_shardings

        mesh = make_mesh((2, 4), ("data", "model"))
        specs = {
            "ok": ParamSpec((16, 8), ("embed", "mlp")),      # divisible
            "bad": ParamSpec((16, 6), ("embed", "mlp")),     # 6 % 4 != 0
            "expert": ParamSpec((2, 8, 8), ("expert", "embed", "mlp")),
        }
        sh, report = make_shardings(specs, mesh)
        assert sh["ok"].spec == P(("data",), "model"), sh["ok"].spec
        assert sh["bad"].spec[1] is None, sh["bad"].spec
        # expert=2 does not divide model=4 -> falls to replicate (no pod axis)
        assert sh["expert"].spec[0] is None, sh["expert"].spec
        assert len(report.fallbacks) == 2, report.fallbacks
        print("RULES_OK")
    """)
    assert "RULES_OK" in out


def test_train_step_compiles_and_runs_on_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.nn.params import init_params
        from repro.distributed.sharding import make_shardings
        from repro.distributed import api as dist_api
        from repro.train import TrainConfig, make_train_step
        from repro.optim import adamw, AdamWConfig

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("mamba2-130m", reduced=True).replace(
            param_dtype="float32", d_model=64, ssm_head_dim=16)
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             jnp.float32)
        sh, _ = make_shardings(model.param_specs(), mesh)
        params = jax.tree.map(jax.device_put, params, sh)
        state = {"params": params, "opt": adamw.init(params, AdamWConfig())}
        tc = TrainConfig()
        step = make_train_step(model, tc)
        tokens = jnp.zeros((8, 32), jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        with mesh, dist_api.activation_layout(batch_axes=("data",)):
            batch = jax.device_put(
                batch, NamedSharding(mesh, P(("data",), None)))
            state, metrics = jax.jit(step)(state, batch)
            state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        print("TRAIN_MESH_OK", float(metrics["loss"]))
    """)
    assert "TRAIN_MESH_OK" in out


def test_multidevice_matches_single_device():
    """The same train step gives the same loss on 1 and 8 devices."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.nn.params import init_params
        from repro.train import TrainConfig, make_train_step
        from repro.optim import adamw, AdamWConfig

        cfg = get_config("deepseek-7b", reduced=True).replace(
            param_dtype="float32")
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             jnp.float32)
        state = {"params": params, "opt": adamw.init(params, AdamWConfig())}
        step = make_train_step(model, TrainConfig())
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        MESH
        print("LOSS", float(metrics["loss"]))
    """
    single = code.replace("MESH", "state, metrics = jax.jit(step)(state, batch)")
    multi = code.replace("MESH", """
        mesh = make_mesh((2, 4), ("data", "model"))
        from repro.distributed.sharding import make_shardings
        sh, _ = make_shardings(model.param_specs(), mesh)
        state["params"] = jax.tree.map(jax.device_put, state["params"], sh)
        state["opt"]["m"] = jax.tree.map(jax.device_put, state["opt"]["m"], sh)
        state["opt"]["v"] = jax.tree.map(jax.device_put, state["opt"]["v"], sh)
        with mesh:
            batch = jax.device_put(batch, NamedSharding(mesh, P(("data",), None)))
            state, metrics = jax.jit(step)(state, batch)
    """)
    l1 = float(_run(single, devices=1).split("LOSS")[-1])
    l8 = float(_run(multi, devices=8).split("LOSS")[-1])
    assert abs(l1 - l8) < 1e-3, (l1, l8)


def test_compressed_pod_psum_close_to_exact():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.distributed.collectives import (compressed_pod_psum,
                                                   init_errors, shard_map)

        mesh = make_mesh((4, 2), ("pod", "data"))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)}
        err = init_errors(g)

        def f(g, e):
            red, new_err = compressed_pod_psum(g, e, axis="pod")
            return red, new_err

        red, new_err = jax.jit(shard_map(
            f, mesh=mesh, axis_names={"pod"},
            in_specs=(P("pod"), P("pod")),
            out_specs=(P("pod"), P("pod"))))(g, err)
        # exact: each pod shard holds g-rows; psum over pod of each row-shard
        exact = jax.jit(shard_map(
            lambda g: jax.lax.psum(g, "pod"), mesh=mesh, axis_names={"pod"},
            in_specs=P("pod"), out_specs=P("pod")))(g)
        rel = float(jnp.abs(red["w"] - exact["w"]).max() /
                    (jnp.abs(exact["w"]).max() + 1e-9))
        assert rel < 0.05, rel           # int8 quantization error bound
        # error feedback: residual equals what quantization lost locally
        assert float(jnp.abs(new_err["w"]).max()) < 0.05
        print("COMPRESS_OK", rel)
    """)
    assert "COMPRESS_OK" in out


def test_reshard_state_across_meshes():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.nn.params import init_params
        from repro.distributed.sharding import make_shardings
        from repro.optim import adamw, AdamWConfig
        from repro.runtime import reshard_state

        cfg = get_config("gemma-2b", reduced=True).replace(
            param_dtype="float32")
        model = build_model(cfg)
        specs = model.param_specs()
        params = init_params(specs, jax.random.PRNGKey(0), jnp.float32)
        state = {"params": params, "opt": adamw.init(params, AdamWConfig())}

        mesh_a = make_mesh((4, 2), ("data", "model"))
        mesh_b = make_mesh((2, 2), ("data", "model"))  # "lost" half the hosts
        sa = reshard_state(state, specs, mesh_a)
        sb = reshard_state(sa, specs, mesh_b)
        for x, y in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(sb["params"])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """The dry-run path itself (lower+compile+analyses) on an 8-dev mesh."""
    out = _run("""
        import os
        import jax, jax.numpy as jnp
        from repro.configs import get_config, shapes as shp
        from repro.launch import dryrun
        from repro.launch.mesh import make_mesh

        # monkeypatch the production mesh to a small one
        import repro.launch.dryrun as dr
        dr.make_production_mesh = lambda multi_pod=False: make_mesh(
            (2, 2, 2) if multi_pod else (2, 4),
            ("pod", "data", "model") if multi_pod else ("data", "model"))

        from pathlib import Path
        rec = dr.run_cell("mamba2-130m", "train_4k", "single",
                          Path("/tmp/dr_test"),
                          overrides={"n_layers": 2, "d_model": 256,
                                     "vocab_size": 1024})
        assert rec["ok"], rec
        assert rec["roofline"]["compute_s"] > 0
        rec2 = dr.run_cell("mamba2-130m", "decode_32k", "multi",
                           Path("/tmp/dr_test"),
                           overrides={"n_layers": 2, "d_model": 256,
                                      "vocab_size": 1024})
        assert rec2["ok"], rec2
        print("DRYRUN_OK")
    """, devices=8)
    assert "DRYRUN_OK" in out
