"""Unit tests for ``repro.serve.sampling``.

The serving engines depend on three properties of the sampler:

* determinism — a fixed seed replays the exact same tokens;
* greedy collapse — ``temperature <= 0`` is a pure argmax;
* key invariance — ``sample_keyed`` gives a (request, position) pair the
  same Gumbel noise regardless of batch shape, row order, or whether the
  logits came from a plain decode step or a speculative verify chunk.
  The last one is the load-bearing property for self-speculative
  decoding (serve/speculative.py): it is why spec on/off streams match
  byte-for-byte even under temperature sampling.
"""
import numpy as np
import pytest

from repro.serve import sampling


def _logits(rng, b, vocab):
    return rng.normal(size=(b, vocab)).astype(np.float32)


# ---------------------------------------------------------------- sample


def test_sample_greedy_is_argmax():
    rng = np.random.default_rng(0)
    z = _logits(rng, 5, 33)
    got = sampling.sample(z, 0.0, sampling.step_rng(0, 0))
    np.testing.assert_array_equal(got, np.argmax(z, axis=-1))
    # Negative temperature behaves like 0 (greedy), not like an error.
    got_neg = sampling.sample(z, -1.0, sampling.step_rng(0, 0))
    np.testing.assert_array_equal(got_neg, np.argmax(z, axis=-1))


def test_sample_deterministic_under_fixed_seed():
    rng = np.random.default_rng(1)
    z = _logits(rng, 4, 64)
    a = sampling.sample(z, 0.8, sampling.step_rng(7, 3))
    b = sampling.sample(z, 0.8, sampling.step_rng(7, 3))
    np.testing.assert_array_equal(a, b)
    # A different step key gives an independent draw (almost surely
    # different on a 64-way vocab with 4 rows... but assert only on the
    # generator state, not luck: the noise itself must differ).
    g1 = sampling._gumbel(sampling.step_rng(7, 3), (4, 64))
    g2 = sampling._gumbel(sampling.step_rng(7, 4), (4, 64))
    assert not np.array_equal(g1, g2)


def test_sample_matches_softmax_distribution():
    # Gumbel-max over 3 logits should hit each index roughly in
    # proportion to softmax(z / T).  Loose bounds; fixed seed.
    z = np.array([[2.0, 1.0, 0.0]], np.float32)
    temp = 1.0
    counts = np.zeros(3)
    for step in range(4000):
        tok = sampling.sample(z, temp, sampling.step_rng(11, step))
        counts[tok[0]] += 1
    p = np.exp(z[0] / temp)
    p /= p.sum()
    np.testing.assert_allclose(counts / counts.sum(), p, atol=0.03)


# ---------------------------------------------------------- sample_keyed


def test_sample_keyed_greedy_is_argmax():
    rng = np.random.default_rng(2)
    z = _logits(rng, 6, 40)
    got = sampling.sample_keyed(z, 0.0, seed=0, uids=range(6),
                                positions=[0] * 6)
    np.testing.assert_array_equal(got, np.argmax(z, axis=-1))


def test_sample_keyed_deterministic_and_row_order_invariant():
    """Shuffling the batch rows must permute the output identically:
    each row's draw depends only on its (uid, position) key."""
    rng = np.random.default_rng(3)
    b, vocab = 8, 50
    z = _logits(rng, b, vocab)
    uids = np.array([10, 11, 12, 13, 14, 15, 16, 17])
    poss = np.array([5, 1, 9, 2, 2, 7, 0, 4])

    base = sampling.sample_keyed(z, 0.9, seed=42, uids=uids, positions=poss)
    again = sampling.sample_keyed(z, 0.9, seed=42, uids=uids, positions=poss)
    np.testing.assert_array_equal(base, again)

    perm = rng.permutation(b)
    shuf = sampling.sample_keyed(z[perm], 0.9, seed=42, uids=uids[perm],
                                 positions=poss[perm])
    np.testing.assert_array_equal(shuf, base[perm])


def test_sample_keyed_batch_composition_invariant():
    """A row's token doesn't change when other rows join or leave the
    batch (continuous batching refills slots mid-decode)."""
    rng = np.random.default_rng(4)
    z = _logits(rng, 5, 32)
    uids, poss = [3, 4, 5, 6, 7], [1, 2, 3, 4, 5]
    full = sampling.sample_keyed(z, 0.7, seed=9, uids=uids, positions=poss)
    # Serve row 2 alone: same logits, same key, same token.
    solo = sampling.sample_keyed(z[2:3], 0.7, seed=9, uids=uids[2:3],
                                 positions=poss[2:3])
    assert solo[0] == full[2]


def test_sample_keyed_distinguishes_seed_uid_and_position():
    z = np.zeros((1, 256), np.float32)  # flat logits: token == noise argmax
    base = sampling.sample_keyed(z, 1.0, seed=0, uids=[1], positions=[1])
    for kw in ({"seed": 1, "uids": [1], "positions": [1]},
               {"seed": 0, "uids": [2], "positions": [1]},
               {"seed": 0, "uids": [1], "positions": [2]}):
        other = sampling.sample_keyed(z, 1.0, **kw)
        assert other[0] != base[0], kw


def test_keyed_gumbel_matches_per_row_generator():
    g = sampling.keyed_gumbel(seed=5, uids=[8, 9], positions=[2, 3],
                              vocab=16)
    for i, (u, p) in enumerate([(8, 2), (9, 3)]):
        ref = sampling._gumbel(np.random.default_rng([5, u, p]), 16)
        np.testing.assert_array_equal(g[i], ref.astype(np.float32))


def test_verify_step_sampling_consistency():
    """The speculative verify chunk samples position p of request u with
    the exact noise a plain decode step would have used there — one call
    with positions [p0..p0+k-1] equals k single-position calls."""
    rng = np.random.default_rng(6)
    k, vocab, uid, p0 = 4, 48, 21, 10
    vl = _logits(rng, k, vocab)  # verify logits for positions p0..p0+k-1

    chunk = sampling.sample_keyed(vl, 0.8, seed=3, uids=[uid] * k,
                                  positions=[p0 + j for j in range(k)])
    step = np.array([
        sampling.sample_keyed(vl[j:j + 1], 0.8, seed=3, uids=[uid],
                              positions=[p0 + j])[0]
        for j in range(k)])
    np.testing.assert_array_equal(chunk, step)


@pytest.mark.parametrize("temp", [0.0, 0.8])
def test_sample_keyed_dtype_and_shape(temp):
    z = np.zeros((3, 7), np.float32)
    out = sampling.sample_keyed(z, temp, seed=0, uids=[0, 1, 2],
                                positions=[0, 0, 0])
    assert out.shape == (3,) and out.dtype == np.int32
