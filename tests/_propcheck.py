"""Dependency-free stand-in for the slice of hypothesis the property
suite uses (``@given`` / ``@settings`` / ``strategies.integers`` /
``strategies.sampled_from``).

CI installs real hypothesis (requirements-dev.txt) and gets shrinking,
example databases and adaptive generation; environments without it fall
back to this shim so ``tests/test_core_properties.py`` still *runs* the
properties — over ``max_examples`` deterministic pseudo-random examples
keyed on the test name — instead of being skipped wholesale.  A failure
reports the drawn example so it can be replayed by hand.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


def settings(deadline=None, max_examples: int = 15, **_ignored):
    """Only ``max_examples`` matters here; everything else (deadline,
    database, ...) is a real-hypothesis concern."""
    del deadline

    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_propcheck_max_examples", 15)
            # Deterministic per-test stream: the same examples on every
            # run and machine, independent of collection order.
            name_key = zlib.crc32(fn.__qualname__.encode())
            for ex in range(n):
                rng = np.random.default_rng([name_key, ex])
                drawn = {k: s.example(rng)
                         for k, s in sorted(strats.items())}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{ex}: {drawn}") from e
        # Hide the drawn parameters from pytest's fixture resolution
        # (functools.wraps copies the original signature otherwise).
        wrapper.__signature__ = inspect.Signature()
        wrapper.__wrapped__ = None
        del wrapper.__wrapped__
        return wrapper
    return deco
