"""GPipe-style pipeline: output == sequential stage application."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.pipeline import pipeline_apply, reference_apply


def _stage(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


def _setup(rng, stages=4, m=6, mb=3, d=8):
    params = {
        "w": jnp.asarray(rng.standard_normal((stages, d, d)) * 0.5,
                         jnp.float32),
        "b": jnp.asarray(rng.standard_normal((stages, d)) * 0.1,
                         jnp.float32),
    }
    mbs = jnp.asarray(rng.standard_normal((m, mb, d)), jnp.float32)
    return params, mbs


def test_pipeline_matches_sequential(rng):
    params, mbs = _setup(rng)
    out = jax.jit(lambda p, x: pipeline_apply(_stage, p, x))(params, mbs)
    want = jnp.stack([reference_apply(_stage, params, mbs[i])
                      for i in range(mbs.shape[0])])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pipeline_fewer_microbatches_than_stages(rng):
    params, mbs = _setup(rng, stages=5, m=2)
    out = pipeline_apply(_stage, params, mbs)
    want = jnp.stack([reference_apply(_stage, params, mbs[i])
                      for i in range(2)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pipeline_differentiable(rng):
    params, mbs = _setup(rng)

    def loss(p):
        return jnp.sum(pipeline_apply(_stage, p, mbs) ** 2)

    def loss_ref(p):
        return sum(jnp.sum(reference_apply(_stage, p, mbs[i]) ** 2)
                   for i in range(mbs.shape[0]))

    g1 = jax.grad(loss)(params)
    g2 = jax.grad(loss_ref)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
