"""Program cards + registry (docs/observability.md): golden-stable card
fields on a fixed reduced config, budget trips on synthetic cliffs,
stable program ids across re-registration, engine integration, and
per-program recompile attribution."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, build_model
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, ServeConfig
from repro.serve.program_registry import (DEFAULT_BUDGETS, ProgramBudget,
                                          ProgramRegistry, budget_for,
                                          build_card, shape_args)
from repro.serve.tracing import RecompileSentinel, Tracer

V = 64

CFG = ModelConfig(name="mamba2", family="mamba2", vocab_size=V,
                  d_model=32, n_layers=2, d_state=8, ssm_head_dim=8,
                  chunk_size=8, param_dtype="float32")


def _model_params():
    model = build_model(CFG)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    return model, params


def _decode_lowering(model, params, slots=2):
    dview = model.decode_view(params)
    cache = model.init_cache(slots, 16, jnp.float32)
    fn = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i),
                 donate_argnums=(2,))
    ex = shape_args((dview, jnp.ones((slots, 1), jnp.int32), cache,
                     jnp.zeros((slots,), jnp.int32)))
    return fn, ex


# ---------------------------------------------------------------------------
# cards: golden stability + budgets
# ---------------------------------------------------------------------------
def test_build_card_golden_stable():
    """Same program, two independent AOT builds -> identical analysis
    fields (the card is a *property of the program*, not of the run)."""
    model, params = _model_params()
    fn1, ex = _decode_lowering(model, params)
    c1 = build_card("decode", "p0:decode", fn1, ex)
    fn2, ex2 = _decode_lowering(model, params)
    c2 = build_card("decode", "p0:decode", fn2, ex2)

    assert c1.flops > 0 and c1.bytes_accessed > 0
    assert c1.instructions > 0 and c1.opcodes
    assert c1.copies >= 0 and c1.compile_s > 0
    for field in ("flops", "bytes_accessed", "argument_bytes",
                  "output_bytes", "temp_bytes", "instructions",
                  "copies", "copy_bytes"):
        assert getattr(c1, field) == getattr(c2, field), field
    assert c1.roofline["bottleneck"] in ("compute_s", "memory_s",
                                         "collective_s")
    assert c1.roofline_s > 0
    # the card serializes (BENCH artifacts / trace_report --cards)
    d = json.loads(json.dumps(c1.to_dict()))
    assert d["name"] == "decode" and d["program_id"] == "p0:decode"
    assert d["flops"] == c1.flops and d["copies"] == c1.copies


def test_card_budget_trip_and_pass():
    model, params = _model_params()
    fn, ex = _decode_lowering(model, params)
    generous = ProgramBudget(max_copies=10_000,
                             max_temp_bytes=1 << 40)
    ok = build_card("decode", "p0:decode", fn, ex, budget=generous)
    assert ok.check_budget() == []
    assert ok.to_dict()["budget_violations"] == []

    fn2, ex2 = _decode_lowering(model, params)
    cliff = ProgramBudget(max_copies=0, max_temp_bytes=1)
    bad = build_card("decode", "p0:decode", fn2, ex2, budget=cliff)
    violations = bad.check_budget()
    # a synthetic zero-copy budget must trip on copies (and, since any
    # real program needs scratch, on the 1-byte temp arena too)
    assert violations, "zero budget did not trip"
    assert any("copy" in v for v in violations)
    assert any("temp" in v for v in violations)
    assert bad.to_dict()["budget_violations"] == violations


def test_budget_for_gates_on_config_size():
    full = type("C", (), {"name": "mamba2-130m", "d_model": 768})()
    small = type("C", (), {"name": "mamba2-130m", "d_model": 32})()
    other = type("C", (), {"name": "nope", "d_model": 4096})()
    b = budget_for(full, "decode")
    assert isinstance(b, ProgramBudget)
    assert b.max_copies == DEFAULT_BUDGETS[("mamba2-130m",
                                            "decode")]["max_copies"]
    assert budget_for(small, "decode") is None      # reduced: no budget
    assert budget_for(full, "qmatmul") is None      # unbudgeted program
    assert budget_for(other, "decode") is None      # unknown arch


# ---------------------------------------------------------------------------
# registry: ids, idempotence, lazy cards
# ---------------------------------------------------------------------------
def test_registry_ids_stable_across_reregistration():
    reg = ProgramRegistry()
    f = jax.jit(lambda x: x + 1)
    g = jax.jit(lambda x: x * 2)
    assert reg.register("decode", f) == "p0:decode"
    assert reg.register("prefill", g) == "p1:prefill"
    # re-registering (backend rebuild) keeps the id — trace spans from
    # before and after the rebuild attribute to the same program
    assert reg.register("decode", g) == "p0:decode"
    assert reg.names() == ["decode", "prefill"]
    assert reg.program_id("decode") == "p0:decode"
    assert "decode" in reg and "nope" not in reg
    assert reg.program_id("nope") is None


def test_registry_card_build_and_invalidate():
    reg = ProgramRegistry()
    f = jax.jit(lambda x: x @ x)
    ex = (jax.ShapeDtypeStruct((8, 8), jnp.float32),)
    reg.register("square", f, ex)
    card = reg.card("square")
    assert card.program_id == "p0:square" and card.flops > 0
    assert reg.card("square") is card               # cached
    assert reg.card("square", rebuild=True) is not card
    reg.invalidate()
    assert reg.to_dict() == {}                       # built cards only

    reg.register("noargs", jax.jit(lambda x: x))
    with pytest.raises(ValueError, match="example args"):
        reg.card("noargs")
    # the default card sweep skips unbuildable programs instead of dying
    assert set(reg.cards()) == {"square"}


def test_registry_check_budgets():
    model, params = _model_params()
    fn, ex = _decode_lowering(model, params)
    reg = ProgramRegistry()
    reg.register("decode", fn, ex,
                 budget=ProgramBudget(max_copies=0, max_temp_bytes=1))
    violations = reg.check_budgets()
    assert violations and all("decode" in v for v in violations)
    with pytest.raises(RuntimeError, match="budget"):
        reg.assert_budgets()


# ---------------------------------------------------------------------------
# engine integration + recompile attribution
# ---------------------------------------------------------------------------
def test_engine_registers_programs_and_builds_cards():
    model, params = _model_params()
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=2, prefill_buckets=(16,), max_new_tokens=4))
    try:
        names = eng.registry.names()
        assert names[:2] == ["decode", "prefill"]
        assert {"pool_insert", "pool_extract", "pool_reset"} <= set(names)
        assert eng.registry.program_id("decode") == "p0:decode"
        # decode sentinel carries the registry id -> recompile trips are
        # attributable to a program, not just a span name
        assert eng.sentinels["decode"].program_id == "p0:decode"
        card = eng.registry.card("decode")
        assert card.flops > 0 and card.program_id == "p0:decode"
    finally:
        eng.close()


def test_sentinel_attributes_program_id_in_trip_instant():
    f = jax.jit(lambda x: x * 2)
    s = RecompileSentinel("decode", f, program_id="p0:decode")
    f(jnp.ones((2,)))
    assert s.check() == 0                            # lazy-arm
    f(jnp.ones((3,)))                                # retrace
    tr = Tracer()
    assert s.check(tr) == 1
    ev = next(e for e in tr.events if e["ph"] == "i")
    assert ev["args"]["program_id"] == "p0:decode"
    assert ev["args"]["program"] == "decode"
