"""scripts/bench_diff.py (make bench-diff): per-metric direction +
tolerance semantics — improvement passes, regression fails, a metric
missing from the fresh artifact fails, a metric without a baseline
passes as "new", and the bounds are inclusive at the tolerance edge."""
import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_diff",
    os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                 "bench_diff.py"))
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def _check(direction, base, fresh, rtol=0.0, atol=0.0, metric="m"):
    spec = {"file": "B.json", "metric": metric, "direction": direction,
            "rtol": rtol, "atol": atol}
    return bench_diff.check_metric(spec, {metric: base}, {metric: fresh})


# ---------------------------------------------------------------------------
# direction semantics
# ---------------------------------------------------------------------------
def test_higher_improvement_and_regression():
    assert _check("higher", 100.0, 150.0, rtol=0.1)["status"] == "ok"
    assert _check("higher", 100.0, 95.0, rtol=0.1)["status"] == "ok"
    assert _check("higher", 100.0, 85.0, rtol=0.1)["status"] == "regression"


def test_lower_improvement_and_regression():
    assert _check("lower", 0.1, 0.05, rtol=0.2)["status"] == "ok"
    assert _check("lower", 0.1, 0.11, rtol=0.2)["status"] == "ok"
    assert _check("lower", 0.1, 0.2, rtol=0.2)["status"] == "regression"


def test_equal_two_sided():
    assert _check("equal", 10, 10)["status"] == "ok"
    assert _check("equal", 10, 11)["status"] == "regression"
    assert _check("equal", 10, 9)["status"] == "regression"
    assert _check("equal", 10, 11, atol=1.0)["status"] == "ok"
    # relative band scales with the baseline magnitude
    assert _check("equal", 1000.0, 1049.0, rtol=0.05)["status"] == "ok"
    assert _check("equal", 1000.0, 1051.0, rtol=0.05)["status"] == \
        "regression"


def test_tolerance_edges_inclusive():
    # higher: floor = base*(1-rtol) - atol; landing ON the floor passes
    assert _check("higher", 100.0, 90.0, rtol=0.1)["status"] == "ok"
    assert _check("higher", 100.0, 89.0, rtol=0.1, atol=1.0)["status"] \
        == "ok"
    # lower: ceiling inclusive too
    assert _check("lower", 100.0, 110.0, rtol=0.1)["status"] == "ok"
    # equal: |diff| == tol passes
    assert _check("equal", 100.0, 105.0, rtol=0.05)["status"] == "ok"


def test_zero_tolerance_counters():
    assert _check("equal", 0, 0)["status"] == "ok"
    assert _check("equal", 0, 1)["status"] == "regression"


def test_unknown_direction_fails():
    assert _check("sideways", 1, 1)["status"] == "missing"


# ---------------------------------------------------------------------------
# missing / new metrics
# ---------------------------------------------------------------------------
def test_metric_missing_in_fresh_fails():
    spec = {"file": "B.json", "metric": "a.b", "direction": "higher"}
    row = bench_diff.check_metric(spec, {"a": {"b": 1.0}}, {"a": {}})
    assert row["status"] == "missing"


def test_metric_missing_in_baseline_is_new():
    spec = {"file": "B.json", "metric": "a.b", "direction": "higher"}
    row = bench_diff.check_metric(spec, {"a": {}}, {"a": {"b": 1.0}})
    assert row["status"] == "new"


def test_non_numeric_fresh_fails():
    assert _check("higher", 1.0, "fast")["status"] == "missing"
    assert _check("equal", 1.0, True)["status"] == "missing"


# ---------------------------------------------------------------------------
# dotted-path resolution
# ---------------------------------------------------------------------------
def test_get_path_nested_lists_and_dotted_keys():
    doc = {"phases": [{"wall_s": 1.5}],
           "pwl_err": {"silu.k16": {"max_abs": 0.007}}}
    assert bench_diff.get_path(doc, "phases.0.wall_s") == 1.5
    assert bench_diff.get_path(doc, "pwl_err.silu.k16.max_abs") == 0.007
    assert bench_diff.get_path(doc, "phases.7.wall_s") is None
    assert bench_diff.get_path(doc, "nope.deeper") is None


# ---------------------------------------------------------------------------
# end-to-end: schema + dirs + exit codes
# ---------------------------------------------------------------------------
def _write(tmp_path, rel, doc):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(doc))
    return p


@pytest.fixture
def dirs(tmp_path):
    _write(tmp_path, "base/BENCH_x.json", {"tok_s": 100.0, "compiles": 1})
    schema = _write(tmp_path, "base/schema.json", {"metrics": [
        {"file": "BENCH_x.json", "metric": "tok_s",
         "direction": "higher", "rtol": 0.2},
        {"file": "BENCH_x.json", "metric": "compiles",
         "direction": "equal"},
    ]})
    return tmp_path, schema


def test_main_passes_on_ok_and_new(dirs, tmp_path, capsys):
    root, schema = dirs
    _write(root, "fresh/BENCH_x.json",
           {"tok_s": 99.0, "compiles": 1, "extra": 5})
    rc = bench_diff.main(["--schema", str(schema),
                          "--baseline-dir", str(root / "base"),
                          "--fresh-dir", str(root / "fresh")])
    assert rc == 0
    assert "0 failing" in capsys.readouterr().out


def test_main_fails_on_synthetic_regression(dirs, tmp_path, capsys):
    root, schema = dirs
    _write(root, "fresh/BENCH_x.json", {"tok_s": 50.0, "compiles": 1})
    report = root / "report.json"
    rc = bench_diff.main(["--schema", str(schema),
                          "--baseline-dir", str(root / "base"),
                          "--fresh-dir", str(root / "fresh"),
                          "--json", str(report)])
    assert rc == 1
    assert "FAIL" in capsys.readouterr().out
    rep = json.loads(report.read_text())
    assert rep["failures"] == ["tok_s"]
    (row,) = [r for r in rep["rows"] if r["metric"] == "tok_s"]
    assert row["status"] == "regression" and row["bound"] == 80.0


def test_main_fails_on_unreadable_fresh_artifact(dirs, tmp_path):
    root, schema = dirs
    (root / "fresh").mkdir()
    rc = bench_diff.main(["--schema", str(schema),
                          "--baseline-dir", str(root / "base"),
                          "--fresh-dir", str(root / "fresh")])
    assert rc == 1


def test_main_missing_baseline_doc_passes_as_new(dirs, tmp_path):
    root, schema = dirs
    _write(root, "fresh/BENCH_x.json", {"tok_s": 1.0, "compiles": 99})
    rc = bench_diff.main(["--schema", str(schema),
                          "--baseline-dir", str(root / "nosuch"),
                          "--fresh-dir", str(root / "fresh")])
    assert rc == 0
