"""Quickstart: train a tiny Mamba-2 with XAMBA optimizations, watch the
loss fall, then generate tokens through the static-shape serving engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.xamba import XambaConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.nn.params import count_params, init_params
from repro.optim import AdamWConfig, ScheduleConfig, adamw
from repro.serve import Engine, ServeConfig
from repro.train import TrainConfig, make_train_step


def main():
    # --- model: reduced mamba2-130m with CumBA+ReduBA enabled -------------
    cfg = get_config("mamba2-130m", reduced=True).replace(
        xamba=XambaConfig.optimized())
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         cfg.dtype)
    print(f"model: {cfg.name} ({count_params(model.param_specs())/1e6:.1f}M "
          f"params), xamba={cfg.xamba.cumba}/{cfg.xamba.reduba}")

    # --- train on synthetic induction data --------------------------------
    state = {"params": params, "opt": adamw.init(params, AdamWConfig())}
    tc = TrainConfig(schedule=ScheduleConfig(base_lr=1e-3, warmup_steps=5,
                                             total_steps=60))
    step = jax.jit(make_train_step(model, tc))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                  global_batch=8))
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.next().items()}
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == 59:
            print(f"step {i:3d}  loss={float(metrics['loss']):.4f}  "
                  f"acc={float(metrics['accuracy']):.3f}")

    # --- generate by hand: the explicit prefill / decode_step API ----------
    # prefill runs the chunked parallel form over the prompt and emits the
    # recurrent state; decode_step is the O(1) fused recurrence.  The
    # engines below wrap exactly this pair (plus donation + slot refill).
    params = state["params"]
    prompt = jax.random.randint(jax.random.PRNGKey(7), (1, 16), 1,
                                cfg.vocab_size)
    cache = model.init_cache(1, 32, cfg.dtype)
    logits, cache = model.prefill(params, {"tokens": prompt}, cache)
    toks = [int(jnp.argmax(logits, -1)[0])]
    for t in range(5):
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache,
                                          jnp.int32(16 + t))
        toks.append(int(jnp.argmax(logits, -1)[0]))
    print("manual greedy decode:", toks)
    # (the pre-refactor call signature still works, with a warning:
    #  model.apply(params, tok, state=cache, index=...) — see
    #  docs/architecture.md for the migration.)

    # --- serve: batched requests through the engine ------------------------
    engine = Engine(model, params, ServeConfig(
        max_batch=4, prefill_buckets=(32, 64), max_new_tokens=12))
    for seed in range(4):
        prompt = jax.random.randint(jax.random.PRNGKey(seed), (20,), 1,
                                    cfg.vocab_size).tolist()
        engine.submit(prompt)
    done = engine.run()
    for r in done:
        print(f"request {r.uid}: generated {r.out_tokens}")
    print("stats:", engine.stats(done))


if __name__ == "__main__":
    main()
