"""Batched serving through the static-shape engines (paper Step-1).

Shows bucketed prefill + decoding across mixed prompt lengths, with
either the lockstep wave engine or the continuous-batching engine
(``--engine continuous``: finished slots refill mid-decode).

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-130m \
        --engine continuous
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--engine", choices=("wave", "continuous"),
                    default="wave")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    from repro.core.xamba import DECODE_MODES
    ap.add_argument("--decode-mode", default=None, choices=DECODE_MODES,
                    help="XambaConfig.decode: how the fused single-token "
                         "step executes (default: the config's mode)")
    from repro.core.xamba import PREFILL_MODES
    ap.add_argument("--prefill-mode", default=None, choices=PREFILL_MODES,
                    help="XambaConfig.prefill: how the multi-token SSD "
                         "prefill pipeline executes (naive = unfused "
                         "chain, cumba = fused XLA pipeline, pallas* = "
                         "the one-kernel Pallas pipeline)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous engine: admit prompts this many "
                         "tokens per step instead of one monolithic "
                         "bucketed prefill")
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    help="continuous engine + --prefill-chunk: reuse "
                         "cached prompt-prefix state across requests "
                         "(docs/prefix_cache.md); 0 = off")
    from repro.core.xamba import QUANT_MODES
    ap.add_argument("--quant", default="none", choices=QUANT_MODES,
                    help="W8 weight-only quantization: serve on int8 "
                         "per-channel weights (fp state pools/caches); "
                         "combine with --decode-mode/--prefill-chunk for "
                         "the fully optimized configuration")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if args.decode_mode:
        cfg = cfg.with_decode_mode(args.decode_mode)
    if args.prefill_mode:
        cfg = cfg.with_prefill_mode(args.prefill_mode)
    if args.quant != "none":
        cfg = cfg.with_quant(args.quant)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         cfg.dtype)
    if args.quant != "none":
        from repro.nn import quant
        params = quant.quantize_params_for_mode(params, args.quant)
        s = quant.quant_summary(params)
        print(f"quant {args.quant}: {s['quantized_tensors']} tensors int8, "
              f"{s['compression']}x smaller than fp32")
    engine_cls = ContinuousEngine if args.engine == "continuous" else Engine
    engine = engine_cls(model, params, ServeConfig(
        max_batch=4, prefill_buckets=(16, 64, 128),
        max_new_tokens=args.max_new, temperature=args.temperature,
        prefill_chunk=(args.prefill_chunk
                       if args.engine == "continuous" else None),
        prefix_cache_mb=(args.prefix_cache_mb
                         if args.engine == "continuous" else 0.0)))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        n = int(rng.integers(4, 100))
        engine.submit(rng.integers(1, cfg.vocab_size, n).tolist())
    done = engine.run()
    wall = time.time() - t0

    for r in done[:5]:
        print(f"req {r.uid:2d}  prompt={len(r.prompt):3d} toks  "
              f"out={r.out_tokens[:6]}...")
    stats = engine.stats(done)
    stats["wall_s"] = round(wall, 2)
    print("stats:", stats)


if __name__ == "__main__":
    main()
