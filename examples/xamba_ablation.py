"""XAMBA technique ablation on the paper's Mamba-2 130M (Fig. 4a in
miniature): baseline -> +CumBA -> +ReduBA -> +both -> +ActiBA, with
latency, compiled op-cost, and quality-vs-exact for each.

    PYTHONPATH=src python examples/xamba_ablation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import hlo_cost, time_fn
from repro.configs import get_config
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn.params import init_params

VARIANTS = [
    ("baseline (NPU-style op chain)", XambaConfig.baseline()),
    ("+CumBA", XambaConfig(cumba="cumba", reduba="naive")),
    ("+ReduBA", XambaConfig(cumba="naive", reduba="reduba")),
    ("+CumBA+ReduBA", XambaConfig.optimized()),
    ("+ActiBA (k=32)", XambaConfig.full(segments=32)),
]


def main():
    base_cfg = get_config("mamba2-130m", reduced=True).replace(
        param_dtype="float32", n_layers=4, chunk_size=64)
    model0 = build_model(base_cfg.replace(xamba=XambaConfig.optimized()))
    params = init_params(model0.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 256), 0,
                                base_cfg.vocab_size)
    exact = None
    t_base = None

    print(f"{'variant':34s} {'ms/fwd':>9s} {'speedup':>8s} "
          f"{'hlo_bytes':>10s} {'top1 vs exact':>14s}")
    for name, xamba in VARIANTS:
        cfg = base_cfg.replace(xamba=xamba)
        model = build_model(cfg)
        fwd = jax.jit(lambda p, t, m=model: m.forward(p, t))
        t = time_fn(fwd, params, tokens, iters=4)
        cost = hlo_cost(lambda p, t, m=model: m.forward(p, t), params,
                        tokens)
        logits = np.asarray(fwd(params, tokens), np.float32)
        if exact is None:
            exact = logits
            t_base = t
        top1 = (logits.argmax(-1) == exact.argmax(-1)).mean()
        print(f"{name:34s} {t*1e3:9.1f} {t_base/t:7.2f}x "
              f"{cost['bytes']:10.2e} {top1:14.4f}")


if __name__ == "__main__":
    main()
