"""End-to-end training driver for the paper's mamba2-130m.

Presets:
  --preset cpu-smoke   reduced model, 40 steps  (runs on this CPU box)
  --preset cpu-130m    full 130M model, short seq, a few steps (slow CPU)
  --preset pod         full 130M, seq 4096, global batch 256, mesh 16x16 —
                       the configuration the multi-pod dry-run validates;
                       run this on real hardware.

Everything goes through the production path: sharded state, microbatching,
async atomic checkpoints, straggler monitor, crash-resume.

    PYTHONPATH=src python examples/train_mamba2_130m.py --preset cpu-smoke
"""
import argparse

from repro.launch import train as train_mod

PRESETS = {
    "cpu-smoke": ["--arch", "mamba2-130m", "--reduced", "--steps", "40",
                  "--batch", "8", "--seq", "128", "--ckpt-every", "20"],
    "cpu-130m": ["--arch", "mamba2-130m", "--steps", "3", "--batch", "2",
                 "--seq", "256", "--log-every", "1"],
    "pod": ["--arch", "mamba2-130m", "--steps", "300", "--batch", "256",
            "--seq", "4096", "--mesh", "16x16:data,model",
            "--microbatches", "2"],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-smoke", choices=list(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_mamba2_130m")
    args, rest = ap.parse_known_args()
    argv = PRESETS[args.preset] + ["--ckpt-dir", args.ckpt_dir] + rest
    train_mod.main(argv)


if __name__ == "__main__":
    main()
