"""Benchmark helpers: wall-clock timing + compiled-cost probes.

This box is CPU (TPU is the *target*), so every benchmark reports two
views where relevant:

* ``us_per_call`` — median CPU wall time (algorithmic effect is still
  visible: the CumBA/ReduBA remaps change the op mix on any backend);
* ``derived``     — a hardware-independent figure from the compiled module
  (HLO flops/bytes, speedup ratio, error, tokens/s), which is the number
  the paper's claim maps onto.
"""
from __future__ import annotations

import time
from typing import Callable, Iterable

import jax
import numpy as np


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median seconds per call of a jitted function."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def hlo_cost(fn: Callable, *args) -> dict:
    """flops / bytes accessed of the compiled module for these args."""
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def emit(name: str, us_per_call: float, derived) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line)
    return line
