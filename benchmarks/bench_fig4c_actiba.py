"""Fig. 4(c) reproduction: ActiBA on the Mamba(-1) 130M block.

The paper maps Softplus (1.2x) then also SiLU (total 2.6x) onto the PLU.
On TPU the corresponding win is *drain-phase fusion*: the PWL epilogue
runs while the producing matmul drains, eliminating the pre-activation
HBM round-trip.  We report (a) block wall time per variant, and (b) the
fused-vs-unfused HBM traffic of the gated-MLP unit from the compiled
modules — the hardware-independent quantity behind the paper's latency
claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, hlo_cost, time_fn
from repro.configs import get_config
from repro.core import pwl
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn import ssm
from repro.nn.params import init_params

SEQ = 256
BATCH = 8


def _block_fn(xamba):
    cfg = get_config("mamba-130m").replace(
        n_layers=1, param_dtype="float32", xamba=xamba)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    block_params = jax.tree.map(lambda x: x[0], params["layers"])

    def fn(x):
        y, _ = ssm.mamba1_apply(block_params["mixer"], cfg, x)
        return y

    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (BATCH, SEQ, cfg.d_model)) * 0.1, jnp.float32)
    return jax.jit(fn), x


def run() -> list:
    rows = []
    variants = [
        ("exact", XambaConfig.optimized()),
        ("pwl_acts", XambaConfig.full(segments=16)),
        ("pwl_acts_k32", XambaConfig.full(segments=32)),
    ]
    times = {}
    for name, xamba in variants:
        fn, x = _block_fn(xamba)
        t = time_fn(fn, x, iters=6)
        times[name] = t
        rows.append(emit(f"fig4c.mamba_block.{name}", t * 1e6,
                         f"speedup={times['exact'] / t:.2f}x"))

    # Drain-phase fusion: unfused (matmul -> store -> activate -> multiply)
    # vs the fused matmul_pwl kernel-equivalent XLA form, HBM bytes.
    rng = np.random.default_rng(0)
    m, kdim, n = 2048, 768, 1536
    x = jnp.asarray(rng.standard_normal((m, kdim)) * 0.1, jnp.float32)
    w = jnp.asarray(rng.standard_normal((kdim, n)) * 0.05, jnp.float32)
    v = jnp.asarray(rng.standard_normal((kdim, n)) * 0.05, jnp.float32)
    table = pwl.get_table("silu", segments=16)

    def unfused(x, w, v):
        a = jnp.dot(x, w)
        b = jnp.dot(x, v)
        return pwl.eval_pwl(table, a) * b

    cost_un = hlo_cost(unfused, x, w, v)
    t_un = time_fn(jax.jit(unfused), x, w, v, iters=6)
    rows.append(emit("fig4c.gated_unit.xla_chain", t_un * 1e6,
                     f"hbm_bytes={cost_un['bytes']:.3e}"))

    # Drain-fusion accounting: without epilogue fusion the two (m, n) f32
    # pre-activation tensors round-trip HBM (store + reload); the
    # matmul_pwl kernel (and XLA's elementwise fusion on this simple chain)
    # eliminate them.  Report the analytic saving the PLU/drain path buys
    # on a datapath without that fusion — the paper's baseline situation.
    saved = 2 * m * n * 4 * 2  # two tensors, store+reload, f32
    rows.append(emit("fig4c.gated_unit.drain_fusion", 0.0,
                     f"bytes_saved_vs_unfused_datapath={saved:.3e};"
                     f"share_of_chain={saved / (cost_un['bytes'] + saved):.2%}"))
    return rows


if __name__ == "__main__":
    run()
