"""Prefix-state cache vs cold prefill on a shared-system-prompt trace.

The workload every serving stack recognizes: a fixed system prompt (~70%
of each request) followed by a short per-request turn, Poisson arrivals,
short outputs.  Cache OFF, every admission re-prefills the system prompt;
cache ON, the first request populates the radix cache and later
admissions restore the deepest chunk-boundary snapshot and prefill only
their own turn (``serve/prefix_cache.py``).

Turn lengths are drawn in whole prefill chunks so the padded staged
streams stay aligned — the cache's alignment rule under static-shape
left-padding (see ``docs/prefix_cache.md``; template-shaped production
traffic has the same property, fully ragged lengths hit at ~1/chunk
rate).

Measured (same replayed trace, fresh engines):

* **TTFT p95** against nominal arrivals (full mode asserts >= 2x better
  with the cache: hits skip ~70% of each prompt's chunk polls).  The
  trace is long enough (64 requests) that the cold population — the
  first concurrent batch, admitted before the trie holds the system
  prompt — sits below the p95 cut: the percentile measures the steady
  state the cache is for, while the mean and goodput still pay the full
  cold-start and snapshot-insert cost;
* **prefill tokens** (>= 50% reduction — compute actually skipped);
* **goodput** (within 5%: the cache must not tax steady-state decode);
* **greedy identity** — byte-identical outputs cache on vs off (the
  snapshot IS the state the same padded stream produces);
* **0 decode recompiles** after warmup, and cache residency never above
  the configured budget.

    PYTHONPATH=src:. python -m benchmarks.bench_serve_prefix [--smoke]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.bench_serve_continuous import _cont_poll, _drain
from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build_model
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, ServeConfig
from repro.serve.metrics import _percentile


def make_shared_prefix_workload(rng, n, vocab, arrival_mean_s, *,
                                sys_len=96, chunk=16, turn_chunks=(1, 2, 3),
                                output_mix=(4, 8)):
    """Poisson arrivals; every prompt = shared system prefix + a private
    turn of 1-3 whole chunks (template-aligned lengths)."""
    sys_prompt = rng.integers(1, vocab, sys_len).tolist()
    t, work = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(arrival_mean_s))
        turn = rng.integers(1, vocab,
                            chunk * int(rng.choice(turn_chunks))).tolist()
        work.append((t, sys_prompt + turn, int(rng.choice(output_mix))))
    return work


def bench_prefix(arch="mamba2-130m", requests=64, batch=4, arrival_ms=30.0,
                 chunk=16, sys_len=96, cache_mb=64.0, seed=0, smoke=False,
                 trace_seed=None):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         cfg.dtype)
    trace_seed = seed if trace_seed is None else trace_seed
    workload = make_shared_prefix_workload(
        np.random.default_rng(trace_seed), requests, cfg.vocab_size,
        arrival_ms / 1e3, sys_len=sys_len, chunk=chunk)

    results = {}
    outputs = {}
    for name, mb in (("cache_off", 0.0), ("cache_on", cache_mb)):
        scfg = ServeConfig(max_batch=batch, prefill_buckets=(192,),
                           max_new_tokens=8, seed=seed, prefill_chunk=chunk,
                           prefix_cache_mb=mb)
        engine = ContinuousEngine(model, params, scfg)
        # Warm every compiled program (chunk prefill, decode, pool row ops,
        # snapshot gather AND — via the repeated prompt, which hits the
        # cache — the restore scatter) outside the timed window.  The two
        # rounds matter: submitted together, both prompts would admit
        # into the same empty-cache poll and both MISS, leaving the
        # restore path cold until the first measured hit.  The warmup
        # prompts share nothing with the trace, and the cache counters
        # reset so the measured hits are all cross-request trace reuse.
        wrng = np.random.default_rng(seed + 1)
        warm_prompt = wrng.integers(1, cfg.vocab_size, 40).tolist()
        engine.submit(warm_prompt, 2)
        engine.run()
        engine.submit(warm_prompt, 2)
        engine.run()
        engine.reset_stats()
        if engine.prefix_cache is not None:
            engine.prefix_cache.reset_stats()
        c0 = engine.counters["decode_compiles"]
        done, wall, nominal_ttft = _drain(engine, workload, _cont_poll)
        m = engine.metrics.summary()
        goodput = sum(len(r.out_tokens) for r in done if r.done) / wall
        c1 = engine.counters["decode_compiles"]
        recompiles = (c1 - c0 if isinstance(c0, int) and isinstance(c1, int)
                      else "unavailable")
        ttft = sorted(nominal_ttft.values())
        outputs[name] = {r.uid: list(r.out_tokens) for r in done}
        results[name] = {
            "goodput_tok_s": round(goodput, 2), "wall_s": round(wall, 3),
            "ttft_mean_s": round(float(np.mean(ttft)), 4),
            "ttft_p95_s": round(_percentile(ttft, 0.95), 4),
            "prefill_tokens": m["prefill_tokens"],
            "prefill_time_s": round(m["prefill_time_s"], 3),
            "decode_recompiles": recompiles,
        }
        if engine.prefix_cache is not None:
            s = engine.prefix_cache.stats()
            results[name]["cache"] = s
            assert s["peak_bytes"] <= s["capacity_bytes"], \
                "prefix cache exceeded its byte budget"
        assert len(done) == requests, (name, len(done))
        assert recompiles == 0 or recompiles == "unavailable", \
            f"{name} retraced decode after warmup"

    assert outputs["cache_on"] == outputs["cache_off"], \
        "prefix cache changed greedy outputs"
    off, on = results["cache_off"], results["cache_on"]
    results["chunk_size"] = chunk
    results["sys_prompt_tokens"] = sys_len
    results["ttft_p95_improvement"] = round(
        off["ttft_p95_s"] / max(on["ttft_p95_s"], 1e-9), 3)
    results["prefill_token_reduction"] = round(
        1.0 - on["prefill_tokens"] / max(off["prefill_tokens"], 1), 3)
    results["cache_on_over_off_goodput"] = round(
        on["goodput_tok_s"] / max(off["goodput_tok_s"], 1e-9), 3)
    results["greedy_identical"] = True
    emit("serve_prefix_ttft_p95_improvement", 0.0,
         results["ttft_p95_improvement"])
    emit("serve_prefix_prefill_token_reduction", 0.0,
         results["prefill_token_reduction"])
    assert on["cache"]["hits"] >= 1, "prefix cache never hit"
    if not smoke:
        # Real-time margins need an otherwise-idle box, like the other
        # serve arms; smoke only checks hits / identity / compile-once.
        assert results["ttft_p95_improvement"] >= 2.0, (
            f"prefix cache TTFT-p95 only "
            f"{results['ttft_p95_improvement']:.2f}x better "
            f"({on['ttft_p95_s']:.4f}s vs {off['ttft_p95_s']:.4f}s)")
        assert results["prefill_token_reduction"] >= 0.5, (
            f"prefill tokens only reduced "
            f"{results['prefill_token_reduction']:.0%}")
        assert results["cache_on_over_off_goodput"] >= 0.95, (
            f"prefix cache cost >5% goodput: "
            f"{on['goodput_tok_s']:.1f} vs {off['goodput_tok_s']:.1f}")
    return results


def run(smoke: bool = False, trace_seed: int = 0) -> dict:
    """Standalone entrypoint (``make smoke-prefix``); the serve harness
    embeds :func:`bench_prefix` as BENCH_serve.json's ``prefix`` block."""
    if smoke:
        return bench_prefix(requests=8, arrival_ms=10.0, smoke=True,
                            trace_seed=trace_seed)
    return bench_prefix(trace_seed=trace_seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--arrival-ms", type=float, default=30.0)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--cache-mb", type=float, default=64.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-seed", type=int, default=None)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        results = run(smoke=True, trace_seed=args.trace_seed or 0)
    else:
        results = bench_prefix(args.arch, args.requests, args.batch,
                               args.arrival_ms, args.chunk,
                               cache_mb=args.cache_mb, seed=args.seed,
                               trace_seed=args.trace_seed)
    for name in ("cache_off", "cache_on"):
        r = results[name]
        print(f"{name:9s} ttft_p95={r['ttft_p95_s'] * 1e3:7.1f} ms  "
              f"prefill_toks={r['prefill_tokens']:6d}  "
              f"goodput={r['goodput_tok_s']:8.1f} tok/s")
    print(f"ttft_p95_improvement={results['ttft_p95_improvement']}x  "
          f"prefill_token_reduction="
          f"{results['prefill_token_reduction']:.0%}  hits="
          f"{results['cache_on']['cache']['hits']}")


if __name__ == "__main__":
    main()
