"""Fig. 4(a)/(b) reproduction: Mamba-2 130M block latency under XAMBA.

The paper reports, for a single-block Mamba-2 130M on the NPU:
CumBA 2.7x, ReduBA 1.2x, combined 4.8x vs the unoptimized baseline, with
CumSum >50% of baseline latency.  Here the SAME model block (d_model=768,
full size) runs under each technique combination; ``--breakdown`` also
reports the segsum share of baseline time (the Fig. 4b shift).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, hlo_cost, time_fn
from repro.configs import get_config
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn import ssm
from repro.nn.params import init_params

SEQ = 256      # one SSD chunk — the regime of the paper's CumSum_b
BATCH = 8


def _block_fn(xamba):
    cfg = get_config("mamba2-130m").replace(
        n_layers=1, param_dtype="float32", xamba=xamba)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    block_params = jax.tree.map(lambda x: x[0], params["layers"])

    def fn(x):
        y, _ = ssm.mamba2_apply(block_params["mixer"], cfg, x)
        return y

    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (BATCH, SEQ, cfg.d_model)) * 0.1, jnp.float32)
    return jax.jit(fn), x


def run() -> list:
    rows = []
    variants = [
        ("baseline", XambaConfig.baseline()),
        ("cumba", XambaConfig(cumba="cumba", reduba="naive")),
        ("reduba", XambaConfig(cumba="naive", reduba="reduba")),
        ("cumba+reduba", XambaConfig.optimized()),
    ]
    times = {}
    for name, xamba in variants:
        fn, x = _block_fn(xamba)
        t = time_fn(fn, x, iters=6)
        times[name] = t
        cost = hlo_cost(fn, x)
        speed = times["baseline"] / t
        rows.append(emit(f"fig4a.mamba2_block.{name}", t * 1e6,
                         f"speedup={speed:.2f}x;flops={cost['flops']:.2e};"
                         f"bytes={cost['bytes']:.2e}"))

    # Fig 4b: what fraction of the baseline block is the segsum/cumsum op?
    from repro.core import segsum
    a = jnp.asarray(np.random.default_rng(1).standard_normal(
        (BATCH, 24, 1, SEQ)) * 0.1, jnp.float32)
    f = jax.jit(lambda a: segsum.segsum(a, mode="naive"))
    t_seg = time_fn(f, a, iters=6)
    rows.append(emit("fig4b.segsum_share_of_baseline",
                     t_seg * 1e6,
                     f"share={t_seg / times['baseline']:.2%}"))
    return rows


if __name__ == "__main__":
    run()
