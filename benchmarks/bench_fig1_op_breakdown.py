"""Fig. 1 reproduction: per-op bottleneck census for Mamba(-2) ops.

The paper profiles Mamba/Mamba-2 on the NPU and finds CumSum/ReduceSum
(Mamba-2) and Swish/Softplus (Mamba) dominating.  Here each op runs in its
baseline form vs its XAMBA remap at the paper's dimensions (CumSum_b is the
(256, 256) segsum inside SSD for mamba2-130m), reporting wall time and the
compiled op mix (HLO flops/bytes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, hlo_cost, time_fn
from repro.core import pwl, reduce as xreduce, segsum


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)

    # ---- CumSum_b: (B, 256, 256) masked cumsum (the 99.9% op) ------------
    x = jnp.asarray(rng.standard_normal((24, 256, 256)), jnp.float32)
    f_naive = jax.jit(lambda x: segsum.cumsum(x, axis=-2, mode="naive"))
    f_cumba = jax.jit(lambda x: segsum.cumsum(x, axis=-2, mode="cumba"))
    t_naive = time_fn(f_naive, x)
    t_cumba = time_fn(f_cumba, x)
    rows.append(emit("fig1.cumsum_b.naive", t_naive * 1e6,
                     f"flops={hlo_cost(f_naive, x)['flops']:.2e}"))
    rows.append(emit("fig1.cumsum_b.cumba", t_cumba * 1e6,
                     f"speedup={t_naive / t_cumba:.2f}x"))

    # ---- segsum (the real SSD form) ---------------------------------------
    a = jnp.asarray(rng.standard_normal((1, 24, 16, 256)) * 0.1, jnp.float32)
    s_naive = jax.jit(lambda a: segsum.segsum(a, mode="naive"))
    s_cumba = jax.jit(lambda a: segsum.segsum(a, mode="cumba"))
    tn = time_fn(s_naive, a)
    tc = time_fn(s_cumba, a)
    rows.append(emit("fig1.segsum.naive", tn * 1e6,
                     f"bytes={hlo_cost(s_naive, a)['bytes']:.2e}"))
    rows.append(emit("fig1.segsum.cumba", tc * 1e6,
                     f"speedup={tn / tc:.2f}x"))

    # ---- ReduceSum --------------------------------------------------------
    m = jnp.asarray(rng.standard_normal((2048, 2048)), jnp.float32)
    r_naive = jax.jit(lambda m: xreduce.reduce_sum(m, 0, "naive"))
    r_reduba = jax.jit(lambda m: xreduce.reduce_sum(m, 0, "reduba"))
    tn = time_fn(r_naive, m)
    tr = time_fn(r_reduba, m)
    rows.append(emit("fig1.reducesum.naive", tn * 1e6,
                     f"flops={hlo_cost(r_naive, m)['flops']:.2e}"))
    rows.append(emit("fig1.reducesum.reduba", tr * 1e6,
                     f"speedup={tn / tr:.2f}x"))

    # ---- Activations (Swish / Softplus) -----------------------------------
    h = jnp.asarray(rng.standard_normal((1024, 1536)) * 3, jnp.float32)
    for name in ("silu", "softplus"):
        exact = jax.jit(pwl._EXACT_FNS[name])
        table = pwl.get_table(name, segments=16)
        approx = jax.jit(lambda h, t=table: pwl.eval_pwl(t, h))
        te = time_fn(exact, h)
        ta = time_fn(approx, h)
        err = pwl.pwl_error(pwl.numpy_fn(name), table)["max_abs"]
        rows.append(emit(f"fig1.{name}.exact", te * 1e6,
                         f"bytes={hlo_cost(exact, h)['bytes']:.2e}"))
        rows.append(emit(f"fig1.{name}.pwl16", ta * 1e6,
                         f"max_err={err:.4f}"))
    return rows


if __name__ == "__main__":
    run()
