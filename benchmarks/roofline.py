"""Roofline table builder: reads the dry-run JSON artifacts and emits the
per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load(pattern: str = "*.json", tag: str = ""):
    recs = []
    for p in sorted(glob.glob(str(ART / pattern))):
        name = Path(p).stem
        if tag and not name.endswith(f"-{tag}"):
            continue
        if not tag and name.count("__") != 2:
            continue
        try:
            recs.append(json.load(open(p)))
        except json.JSONDecodeError:
            pass
    return recs


def fmt_row(r) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} | "
                f"skip | — | — | — | — | — | — |")
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} | "
                f"FAIL | — | — | — | — | — | — |")
    rl = r["roofline"]
    mem = r["memory"]
    fits = "y" if mem["fits_16gb_hbm"] else "n"
    return ("| {arch} | {shape} | {mesh} | {gb:.1f}/{fits} | {c:.3f} | "
            "{m:.3f} | {k:.3f} | {dom} | {frac:.3f} | {ur:.2f} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        gb=mem["total_gb"], fits=fits, c=rl["compute_s"], m=rl["memory_s"],
        k=rl["collective_s"], dom=rl["bottleneck"].replace("_s", ""),
        frac=rl["roofline_fraction"], ur=rl["useful_ratio"])


HEADER = ("| arch | shape | mesh | HBM GB/fits | compute s | memory s | "
          "collective s | bottleneck | roofline frac | useful ratio |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def table(mesh: str = "single", tag: str = "") -> str:
    recs = [r for r in load(tag=tag) if r.get("mesh") == mesh]
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    return "\n".join([HEADER] + [fmt_row(r) for r in recs])


def run() -> list:
    rows = []
    for r in load():
        if r.get("skipped") or not r.get("ok"):
            continue
        rl = r["roofline"]
        name = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}"
        derived = (f"bottleneck={rl['bottleneck']};"
                   f"frac={rl['roofline_fraction']:.3f};"
                   f"useful={rl['useful_ratio']:.2f}")
        print(f"{name},0.0,{derived}")
        rows.append(name)
    return rows


if __name__ == "__main__":
    print(table("single"))
    print()
    print(table("multi"))
