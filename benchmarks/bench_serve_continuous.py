"""Wave vs continuous-batching goodput under Poisson arrivals, and
monolithic vs chunked prefill under a long-prompt mix.

Two experiments on the reduced mamba2 config, both replaying a Poisson
arrival trace in real time:

* **engines** (``bench``): mixed prompt lengths, strongly heterogeneous
  output budgets (the straggler regime continuous batching is for); both
  engines serve the *same* trace at equal ``max_batch``.  Asserts
  continuous goodput >= 1.5x wave and zero decode recompiles after warmup.
* **prefill** (``bench_prefill``): mostly-short traffic with a long-prompt
  tail, continuous engine only, monolithic bucketed prefill vs chunked
  (``ServeConfig.prefill_chunk``).  A monolithic long prefill blocks the
  engine loop for the whole prompt, so short requests arriving behind it
  eat its wall time in their TTFT; chunked prefill bounds that
  head-of-line blocking at one chunk.  Asserts (full mode) TTFT-p95
  improves, goodput stays within 5%, and decode never recompiles.

    PYTHONPATH=src python -m benchmarks.bench_serve_continuous
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build_model
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, Engine, ServeConfig

OUTPUT_MIX = (4, 8, 16, 128)    # heterogeneous budgets -> wave stragglers


def make_workload(rng, n, vocab, arrival_mean_s, *, n_long=0,
                  long_len=(96, 129), short_len=(4, 17), output_mix=None):
    """Poisson arrivals; exactly ``n_long`` long prompts, evenly spaced
    through the trace (deterministic count — the prefill benchmark's p95
    must sit in the short population, see ``bench_prefill``)."""
    t = 0.0
    work = []
    mix = output_mix or OUTPUT_MIX
    long_at = {round((i + 1) * n / (n_long + 1)) for i in range(n_long)}
    for i in range(n):
        t += float(rng.exponential(arrival_mean_s))
        lo, hi = long_len if i in long_at else short_len
        plen = int(rng.integers(lo, hi))
        work.append((t, rng.integers(1, vocab, plen).tolist(),
                     int(rng.choice(mix))))
    return work


def _drain(engine, workload, poll):
    """Replay the arrival trace in real time; ``poll`` advances the engine
    by one unit of work (one continuous step / one wave drain).

    Returns ``(done, wall, nominal_ttft)``.  ``nominal_ttft`` maps uid ->
    first-token latency measured from the trace's NOMINAL arrival time,
    not the submit stamp: while the engine is blocked inside a compiled
    call (e.g. a monolithic long-prompt prefill) this loop cannot submit,
    so engine-internal TTFT starts late and hides exactly the
    head-of-line blocking the prefill experiment measures."""
    done = []
    nominal_arrival = {}
    i = 0
    t0 = time.perf_counter()
    while i < len(workload) or engine.busy:
        now = time.perf_counter() - t0
        while i < len(workload) and workload[i][0] <= now:
            t_i, prompt, max_new = workload[i]
            uid = engine.submit(prompt, max_new)
            nominal_arrival[uid] = t0 + t_i
            i += 1
        out = poll(engine)
        if out is None:          # nothing to do yet: wait for an arrival
            time.sleep(min(1e-3, max(0.0, workload[i][0] - now)))
        else:
            done.extend(out)
    wall = time.perf_counter() - t0
    # perf_counter and time.time share no epoch; re-derive the offset once.
    epoch = time.time() - time.perf_counter()
    nominal_ttft = {r.uid: r.first_token_s - (nominal_arrival[r.uid] + epoch)
                    for r in done if r.first_token_s is not None}
    return done, wall, nominal_ttft


def _wave_poll(engine):
    if not engine.busy:
        return None
    return engine.run()


def _cont_poll(engine):
    if not engine.busy:
        return None
    return engine.poll()


def _warmup(engine, vocab, rng):
    """Compile prefill (largest bucket) + decode outside the timed window."""
    engine.submit(rng.integers(1, vocab, 8).tolist(), 2)
    engine.run()
    engine.reset_stats()


def bench(arch="mamba2-130m", requests=32, batch=4, arrival_ms=5.0,
          seed=0, smoke=False, trace_seed=None):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         cfg.dtype)
    scfg = ServeConfig(max_batch=batch, prefill_buckets=(16,),
                       max_new_tokens=max(OUTPUT_MIX), seed=seed)
    # The arrival trace gets its own seed (reproducible run-to-run and
    # steerable independently of param init); recorded in the env block.
    trace_seed = seed if trace_seed is None else trace_seed
    workload = make_workload(np.random.default_rng(trace_seed), requests,
                             cfg.vocab_size, arrival_ms / 1e3)

    results = {}
    for name, engine_cls, poll in (("wave", Engine, _wave_poll),
                                   ("continuous", ContinuousEngine,
                                    _cont_poll)):
        engine = engine_cls(model, params, scfg)
        _warmup(engine, cfg.vocab_size, np.random.default_rng(seed + 1))
        decode_compiles_warm = engine.counters["decode_compiles"]
        done, wall, _ = _drain(engine, workload, poll)
        goodput = sum(len(r.out_tokens) for r in done if r.done) / wall
        m = engine.metrics.summary()
        # Compile counters report "unavailable" on jax versions without
        # a jit cache-size probe; only difference real counts.
        c0, c1 = decode_compiles_warm, engine.counters["decode_compiles"]
        recompiles = (c1 - c0 if isinstance(c0, int) and isinstance(c1, int)
                      else "unavailable")
        results[name] = {
            "goodput_tok_s": round(goodput, 2), "wall_s": round(wall, 3),
            "occupancy": round(m["slot_occupancy"], 3),
            "ttft_mean_s": round(m["ttft_mean_s"], 4),
            "ttft_p99_s": round(m["ttft_p99_s"], 4),
            "decode_recompiles": recompiles,
            "wall_source": m["wall_source"],
        }
        emit(f"serve_{name}_goodput_tok_s", wall * 1e6 / max(len(done), 1),
             round(goodput, 2))
        emit(f"serve_{name}_occupancy", 0.0, round(m["slot_occupancy"], 3))
        assert len(done) == requests, (name, len(done))

    ratio = results["continuous"]["goodput_tok_s"] / \
        results["wave"]["goodput_tok_s"]
    results["continuous_over_wave_goodput"] = round(ratio, 3)
    emit("serve_continuous_over_wave_goodput", 0.0, round(ratio, 3))

    rc = results["continuous"]["decode_recompiles"]
    assert rc == 0 or rc == "unavailable", \
        "continuous engine retraced decode after warmup"
    if not smoke:
        # The goodput margin needs the full straggler workload; the smoke
        # run only checks the engines drain and never recompile.
        assert ratio >= 1.5, (
            f"continuous goodput only {ratio:.2f}x wave "
            f"(continuous={results['continuous']['goodput_tok_s']:.1f} "
            f"tok/s, wave={results['wave']['goodput_tok_s']:.1f} tok/s)")
    return results


def bench_prefill(arch="mamba2-130m", requests=48, batch=4, arrival_ms=40.0,
                  chunk=16, seed=0, smoke=False, trace_seed=None):
    """Monolithic vs chunked prefill on the continuous engine: mostly-short
    Poisson traffic with a rare long-prompt tail (the head-of-line-blocking
    regime chunked prefill is for).

    The workload is deliberately NOT saturated: arrivals are slower than
    service, so TTFT is dominated by whatever blocks the engine loop when
    a request lands — which, monolithically, is a whole long-prompt
    prefill (tens of ms at the large bucket) and, chunked, is at most one
    chunk (+ one decode step).  Exactly two long prompts are planted (< 5%
    of requests) because chunking intentionally trades the long request's
    own TTFT (its chunks interleave with decode) for everyone else's tail
    latency; with longs above the p95 cut the percentile would sit inside
    the long population and measure that trade instead of the
    unblocking."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         cfg.dtype)
    buckets = (16, 512)
    trace_seed = seed if trace_seed is None else trace_seed
    workload = make_workload(np.random.default_rng(trace_seed), requests,
                             cfg.vocab_size, arrival_ms / 1e3,
                             n_long=2, long_len=(384, 513),
                             output_mix=(4, 8))

    results = {}
    for name, pchunk in (("monolithic", None), ("chunked", chunk)):
        # Default token budget (one chunk call per poll): the whole point
        # is the minimal per-poll block.  Larger budgets drain long
        # prompts in fewer polls but re-grow the block shorts wait behind.
        # NOTE: full-mode assertions compare real-time traces and expect
        # an otherwise-idle box (like the goodput margin above).
        scfg = ServeConfig(max_batch=batch, prefill_buckets=buckets,
                           max_new_tokens=8, seed=seed,
                           prefill_chunk=pchunk)
        engine = ContinuousEngine(model, params, scfg)
        # Warm every compiled program: both prefill buckets (or the single
        # offset-agnostic chunk program), decode, and the pool scatters.
        wrng = np.random.default_rng(seed + 1)
        engine.submit(wrng.integers(1, cfg.vocab_size, 8).tolist(), 2)
        engine.submit(wrng.integers(1, cfg.vocab_size, 400).tolist(), 2)
        engine.run()
        engine.reset_stats()
        c0 = engine.counters["decode_compiles"]
        done, wall, nominal_ttft = _drain(engine, workload, _cont_poll)
        m = engine.metrics.summary()
        goodput = sum(len(r.out_tokens) for r in done if r.done) / wall
        c1 = engine.counters["decode_compiles"]
        recompiles = (c1 - c0 if isinstance(c0, int) and isinstance(c1, int)
                      else "unavailable")
        # TTFT against NOMINAL arrivals (see _drain) — the engine's own
        # stamps cannot see blocking that delays submission itself.
        from repro.serve.metrics import _percentile
        ttft = sorted(nominal_ttft.values())
        ttft_p95 = _percentile(ttft, 0.95)
        results[name] = {
            "goodput_tok_s": round(goodput, 2), "wall_s": round(wall, 3),
            "ttft_mean_s": round(float(np.mean(ttft)), 4),
            "ttft_p95_s": round(ttft_p95, 4),
            "prefill_chunks": m["prefill_chunks"],
            "prefill_time_s": round(m["prefill_time_s"], 3),
            "decode_recompiles": recompiles,
            "wall_source": m["wall_source"],
        }
        emit(f"serve_prefill_{name}_ttft_p95_s", 0.0, round(ttft_p95, 4))
        assert len(done) == requests, (name, len(done))
        assert recompiles == 0 or recompiles == "unavailable", \
            f"{name} prefill retraced decode after warmup"

    mono, chk = results["monolithic"], results["chunked"]
    results["chunk_size"] = chunk
    results["ttft_p95_improvement"] = round(
        mono["ttft_p95_s"] / max(chk["ttft_p95_s"], 1e-9), 3)
    results["chunked_over_monolithic_goodput"] = round(
        chk["goodput_tok_s"] / max(mono["goodput_tok_s"], 1e-9), 3)
    emit("serve_prefill_ttft_p95_improvement", 0.0,
         results["ttft_p95_improvement"])
    if not smoke:
        assert results["ttft_p95_improvement"] >= 1.0, (
            f"chunked prefill worsened TTFT-p95: "
            f"{chk['ttft_p95_s']:.4f}s vs {mono['ttft_p95_s']:.4f}s")
        assert results["chunked_over_monolithic_goodput"] >= 0.95, (
            f"chunked prefill cost >5% goodput: "
            f"{chk['goodput_tok_s']:.1f} vs {mono['goodput_tok_s']:.1f}")
    return results


def bench_phase(arch="mamba2-130m", requests=48, batch=4, reps=3, seed=0,
                smoke=False):
    """Tracing overhead + phase attribution on a saturated continuous run.

    All requests are submitted upfront and drained with ``engine.run()``
    (no real-time arrival replay — wall must be deterministic enough to
    compare).  ``reps`` interleaved pairs of untraced/traced runs on the
    same model and params; overhead compares best-of-``reps`` walls, the
    usual estimator for "cost of the instrumentation itself" under OS
    noise.  The traced run's events feed ``trace_report.analyze`` and
    become BENCH_serve.json's ``phase_breakdown`` block.

    Asserts (both modes) the per-phase self-times reconcile with the
    trace's wall extent within 5% and the compile-once programs never
    retraced; asserts (full mode) tracing overhead <= 2%.
    """
    from repro.launch.trace_report import CHECK_PROGRAMS, analyze

    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         cfg.dtype)
    rng = np.random.default_rng(seed)
    # The heavier end of the budget mix: a 2% overhead bound on a ~0.1s
    # drain is below OS jitter, so keep the measured window near a second.
    prompts = [(rng.integers(1, cfg.vocab_size,
                             int(rng.integers(4, 17))).tolist(),
                int(rng.choice(OUTPUT_MIX[1:])))
               for _ in range(requests)]

    def one_run(traced):
        scfg = ServeConfig(max_batch=batch, prefill_buckets=(16,),
                           max_new_tokens=max(OUTPUT_MIX), seed=seed,
                           trace=traced or None, strict_recompile=True)
        engine = ContinuousEngine(model, params, scfg)
        _warmup(engine, cfg.vocab_size, np.random.default_rng(seed + 1))
        for prompt, max_new in prompts:
            engine.submit(prompt, max_new)
        t0 = time.perf_counter()
        done = engine.run()
        wall = time.perf_counter() - t0
        assert len(done) == requests, len(done)
        return wall, engine

    walls = {False: [], True: []}
    events = None
    traced_engine = None
    for r in range(reps):
        # Alternate the pair order so monotone background-load drift
        # cancels out of the best-of comparison instead of always
        # taxing the same arm.
        for traced in ((False, True) if r % 2 == 0 else (True, False)):
            wall, engine = one_run(traced)
            walls[traced].append(wall)
            if traced:
                events = engine.tracer.events
                traced_engine = engine
    # Signed best-of-reps ratio.  A small NEGATIVE value does not mean
    # tracing speeds anything up — it is the measurement's noise floor
    # showing (the committed -3.2% artifact read as a speedup).  Report
    # the raw signed number plus the per-rep pair band so the noise is
    # visible, clamp the headline at zero (overhead is one-sided), and
    # assert only the upper bound.
    raw = min(walls[True]) / min(walls[False]) - 1.0
    pair_ratios = [t / u - 1.0 for t, u in zip(walls[True], walls[False])]
    noise_band = [round(min(pair_ratios), 4), round(max(pair_ratios), 4)]
    overhead = max(0.0, raw)

    # Program cards from the traced engine's registry give each
    # program_breakdown row its roofline term (achieved vs attainable);
    # AOT card builds share no dispatch cache with the timed runs, so
    # building them here cannot have perturbed the walls above.
    cards = traced_engine.registry.cards()
    rep = analyze(events, cards={n: c.to_dict() for n, c in cards.items()})
    pb = rep["phase_breakdown"]
    pgb = rep["program_breakdown"]
    results = {
        "wall_untraced_s": round(min(walls[False]), 4),
        "wall_traced_s": round(min(walls[True]), 4),
        "tracing_overhead": round(overhead, 4),
        "tracing_overhead_raw": round(raw, 4),
        "tracing_noise_band": noise_band,
        "trace_events": len(events),
        "recompile_trips": rep["recompile_trips"],
        "program_breakdown": pgb,
        **pb,
    }
    emit("serve_tracing_overhead", 0.0, round(overhead, 4))
    emit("serve_phase_coverage", 0.0, pb["coverage"])
    assert abs(pb["coverage"] - 1.0) <= 0.05, (
        f"phase self-times ({pb['phase_total_s']:.4f}s) do not reconcile "
        f"with trace wall ({pb['wall_s']:.4f}s): "
        f"coverage {pb['coverage']:.1%}")
    assert abs(pgb["coverage"] - 1.0) <= 0.05, (
        f"per-program walls ({pgb['program_total_s']:.4f}s + host "
        f"{pgb['_host_s']:.4f}s + idle {pgb['_idle_s']:.4f}s) do not "
        f"reconcile with trace wall ({pgb['wall_s']:.4f}s): "
        f"coverage {pgb['coverage']:.1%}")
    for prog in CHECK_PROGRAMS:
        assert not rep["recompile_trips"].get(prog), (
            f"compile-once program {prog!r} retraced during the traced run: "
            f"{rep['recompile_trips']}")
    if not smoke:
        # Overhead needs best-of-reps on an otherwise-idle box to be a
        # meaningful bound; the smoke run only checks attribution.
        assert raw <= 0.02, (
            f"tracing overhead {raw:.1%} exceeds the 2% budget "
            f"(traced {min(walls[True]):.4f}s vs "
            f"untraced {min(walls[False]):.4f}s)")
    return results


def run(smoke: bool = False, trace_seed: int = 0) -> dict:
    """Harness entrypoint; the returned dict is ``BENCH_serve.json``."""
    from benchmarks import bench_serve_prefix
    if smoke:
        out = bench(requests=10, arrival_ms=2.0, smoke=True,
                    trace_seed=trace_seed)
        out["prefill"] = bench_prefill(requests=8, arrival_ms=5.0,
                                       smoke=True, trace_seed=trace_seed)
        out["phase_breakdown"] = bench_phase(requests=10, reps=1,
                                             smoke=True)
    else:
        out = bench(trace_seed=trace_seed)
        out["prefill"] = bench_prefill(trace_seed=trace_seed)
        out["phase_breakdown"] = bench_phase()
    # Per-program attribution sits beside (not inside) the phase view:
    # same trace, different cut (programs vs host sections).
    out["program_breakdown"] = out["phase_breakdown"].pop(
        "program_breakdown")
    out["prefix"] = bench_serve_prefix.run(smoke=smoke,
                                           trace_seed=trace_seed)
    from benchmarks import bench_serve_chaos
    out["robustness"] = bench_serve_chaos.run(smoke=smoke)
    import jax as _jax
    out["env"] = {"trace_seed": trace_seed, "jax": _jax.__version__,
                  "backend": _jax.default_backend()}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--arrival-ms", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-seed", type=int, default=None,
                    help="arrival-trace seed (default: --seed); recorded "
                         "in the BENCH JSON env block")
    args = ap.parse_args()
    results = bench(args.arch, args.requests, args.batch, args.arrival_ms,
                    args.seed, trace_seed=args.trace_seed)
    for name, r in results.items():
        if not isinstance(r, dict):
            print(f"{name}: {r}")
            continue
        print(f"{name:11s} goodput={r['goodput_tok_s']:8.1f} tok/s  "
              f"occupancy={r['occupancy']:.2f}  "
              f"ttft={r['ttft_mean_s'] * 1e3:7.1f} ms  "
              f"wall={r['wall_s']:.1f} s")


if __name__ == "__main__":
    main()
