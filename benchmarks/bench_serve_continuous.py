"""Wave vs continuous-batching goodput under Poisson arrivals.

Workload: Poisson request arrivals with mixed prompt lengths and strongly
heterogeneous output budgets (the straggler regime continuous batching is
for).  Both engines serve the *same* arrival trace at equal ``max_batch``
on the reduced mamba2 config; we report completed tokens/s (goodput),
slot occupancy, and TTFT, and assert

* continuous goodput >= 1.5x wave goodput, and
* zero decode recompiles after warmup (compile-once discipline holds
  while slots turn over).

    PYTHONPATH=src python -m benchmarks.bench_serve_continuous
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build_model
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, Engine, ServeConfig

OUTPUT_MIX = (4, 8, 16, 128)    # heterogeneous budgets -> wave stragglers


def make_workload(rng, n, vocab, arrival_mean_s):
    t = 0.0
    work = []
    for _ in range(n):
        t += float(rng.exponential(arrival_mean_s))
        plen = int(rng.integers(4, 17))
        work.append((t, rng.integers(1, vocab, plen).tolist(),
                     int(rng.choice(OUTPUT_MIX))))
    return work


def _drain(engine, workload, poll):
    """Replay the arrival trace in real time; ``poll`` advances the engine
    by one unit of work (one continuous step / one wave drain)."""
    done = []
    i = 0
    t0 = time.perf_counter()
    while i < len(workload) or engine.busy:
        now = time.perf_counter() - t0
        while i < len(workload) and workload[i][0] <= now:
            _, prompt, max_new = workload[i]
            engine.submit(prompt, max_new)
            i += 1
        out = poll(engine)
        if out is None:          # nothing to do yet: wait for an arrival
            time.sleep(min(1e-3, max(0.0, workload[i][0] - now)))
        else:
            done.extend(out)
    wall = time.perf_counter() - t0
    return done, wall


def _wave_poll(engine):
    if not engine.busy:
        return None
    return engine.run()


def _cont_poll(engine):
    if not engine.busy:
        return None
    return engine.poll()


def _warmup(engine, vocab, rng):
    """Compile prefill (largest bucket) + decode outside the timed window."""
    engine.submit(rng.integers(1, vocab, 8).tolist(), 2)
    engine.run()
    engine.reset_stats()


def bench(arch="mamba2-130m", requests=32, batch=4, arrival_ms=5.0,
          seed=0, smoke=False):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         cfg.dtype)
    scfg = ServeConfig(max_batch=batch, prefill_buckets=(16,),
                       max_new_tokens=max(OUTPUT_MIX), seed=seed)
    workload = make_workload(np.random.default_rng(seed), requests,
                             cfg.vocab_size, arrival_ms / 1e3)

    results = {}
    for name, engine_cls, poll in (("wave", Engine, _wave_poll),
                                   ("continuous", ContinuousEngine,
                                    _cont_poll)):
        engine = engine_cls(model, params, scfg)
        _warmup(engine, cfg.vocab_size, np.random.default_rng(seed + 1))
        decode_compiles_warm = engine.counters["decode_compiles"]
        done, wall = _drain(engine, workload, poll)
        goodput = sum(len(r.out_tokens) for r in done if r.done) / wall
        m = engine.metrics.summary()
        # Compile counters report "unavailable" on jax versions without
        # a jit cache-size probe; only difference real counts.
        c0, c1 = decode_compiles_warm, engine.counters["decode_compiles"]
        recompiles = (c1 - c0 if isinstance(c0, int) and isinstance(c1, int)
                      else "unavailable")
        results[name] = {
            "goodput_tok_s": round(goodput, 2), "wall_s": round(wall, 3),
            "occupancy": round(m["slot_occupancy"], 3),
            "ttft_mean_s": round(m["ttft_mean_s"], 4),
            "decode_recompiles": recompiles,
        }
        emit(f"serve_{name}_goodput_tok_s", wall * 1e6 / max(len(done), 1),
             round(goodput, 2))
        emit(f"serve_{name}_occupancy", 0.0, round(m["slot_occupancy"], 3))
        assert len(done) == requests, (name, len(done))

    ratio = results["continuous"]["goodput_tok_s"] / \
        results["wave"]["goodput_tok_s"]
    results["continuous_over_wave_goodput"] = round(ratio, 3)
    emit("serve_continuous_over_wave_goodput", 0.0, round(ratio, 3))

    rc = results["continuous"]["decode_recompiles"]
    assert rc == 0 or rc == "unavailable", \
        "continuous engine retraced decode after warmup"
    if not smoke:
        # The goodput margin needs the full straggler workload; the smoke
        # run only checks the engines drain and never recompile.
        assert ratio >= 1.5, (
            f"continuous goodput only {ratio:.2f}x wave "
            f"(continuous={results['continuous']['goodput_tok_s']:.1f} "
            f"tok/s, wave={results['wave']['goodput_tok_s']:.1f} tok/s)")
    return results


def run(smoke: bool = False) -> dict:
    """Harness entrypoint; the returned dict is ``BENCH_serve.json``."""
    if smoke:
        return bench(requests=10, arrival_ms=2.0, smoke=True)
    return bench()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--arrival-ms", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    results = bench(args.arch, args.requests, args.batch, args.arrival_ms,
                    args.seed)
    for name, r in results.items():
        print(f"{name:11s} goodput={r['goodput']:8.1f} tok/s  "
              f"occupancy={r['occupancy']:.2f}  "
              f"ttft={r['ttft_mean_s'] * 1e3:7.1f} ms  "
              f"wall={r['wall']:.1f} s")


if __name__ == "__main__":
    main()
