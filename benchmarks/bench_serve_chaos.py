"""Robustness benchmarks: hardening overhead, overload backpressure,
and chaos blast radius (docs/robustness.md).

Three arms on the reduced mamba2 config, embedded as BENCH_serve.json's
``robustness`` block:

* **probe_overhead** — the healthy-path cost of serving hardened: the
  poison probe, bounded-queue admission check, overload tracker and
  in-flight deadline scan all RUN every poll but never trip.  Interleaved
  best-of-``reps`` pairs (plain vs hardened on the same saturated drain,
  alternating order per rep so background drift cancels) bound the
  overhead; outputs must stay byte-identical.  Full mode asserts <= 3%.
* **overload** — offered load far above capacity (several submissions
  per poll against a service rate of well under one request per poll)
  into a bounded admission queue.  Asserts the protection actually
  protects: explicit rejections happen, the observed queue depth never
  exceeds the bound, degraded mode enters AND clears (hysteresis), and
  every *accepted* request still completes.
* **chaos** — a seeded poison/stall/fail plan armed after warmup (the
  ``scripts/smoke_chaos.py`` scenario): exactly one quarantine and one
  backend fallback (``cumba -> naive``) fire, every healthy request's
  greedy output is byte-identical to a fault-free control run, and zero
  recompile sentinels trip.

    PYTHONPATH=src:. python -m benchmarks.bench_serve_chaos [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.bench_serve_continuous import _warmup
from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build_model
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, ServeConfig

# The full hardened serving posture with thresholds no healthy run can
# reach: every check executes in the hot path, none ever trips, so the
# wall-clock delta over a plain config is pure instrumentation cost.
HARDENED = dict(poison_probe="logits", poison_check_every=1,
                max_queue_depth=100_000, overload_queue_depth=100_000,
                shed_inflight=True)


def bench_probe_overhead(arch="mamba2-130m", requests=48, batch=4, reps=6,
                         seed=0, smoke=False):
    """Healthy-path overhead of the fault-tolerance machinery, measured
    two ways:

    * **per-poll (asserted)** — the per-poll hook chain a hardened
      engine actually adds (poison probe over a real all-finite logits
      batch, the in-flight deadline scan, the overload tracker), timed
      in a tight loop and divided by the plain engine's measured mean
      poll time.  Host-side numpy only, so the figure is stable on a
      shared box; full mode asserts <= 3%.
    * **end-to-end (reported)** — plain vs hardened drains of the same
      saturated workload.  The two arms share ONE warm engine each
      (engine construction dominates run-to-run variance); each rep
      drains both back-to-back, alternating order, and the estimate is
      the median of the per-rep paired ratios.  Scheduler noise on a
      shared box is +/-8% at this window, far above the effect, so this
      arm only sanity-bounds the total (a per-poll device sync slipped
      into the hardened path would still show) and witnesses greedy
      identity + never-tripping thresholds.
    """
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         cfg.dtype)
    rng = np.random.default_rng(seed)
    prompts = [(rng.integers(1, cfg.vocab_size,
                             int(rng.integers(4, 17))).tolist(),
                int(rng.choice((16, 32))))
               for _ in range(requests)]

    def build(hardened):
        scfg = ServeConfig(max_batch=batch, prefill_buckets=(16,),
                           max_new_tokens=32, seed=seed,
                           strict_recompile=True,
                           **(HARDENED if hardened else {}))
        engine = ContinuousEngine(model, params, scfg)
        _warmup(engine, cfg.vocab_size, np.random.default_rng(seed + 1))
        return engine

    def drain(engine):
        for prompt, max_new in prompts:
            engine.submit(prompt, max_new)
        t0 = time.perf_counter()
        done = engine.run()
        wall = time.perf_counter() - t0
        assert len(done) == requests, len(done)
        return wall, {r.uid: list(r.out_tokens) for r in done}

    engines = {False: build(False), True: build(True)}
    polls0 = engines[False].metrics.polls
    walls = {False: [], True: []}
    outputs = {}
    for r in range(reps):
        for hardened in ((False, True) if r % 2 == 0 else (True, False)):
            wall, out = drain(engines[hardened])
            walls[hardened].append(wall)
            outputs[hardened] = out
    ratios = [h / p for h, p in zip(walls[True], walls[False])]
    e2e_overhead = float(np.median(ratios)) - 1.0
    polls_per_drain = (engines[False].metrics.polls - polls0) / reps
    poll_s = min(walls[False]) / polls_per_drain

    m = engines[True].metrics
    assert outputs[True] == outputs[False], \
        "hardening changed greedy outputs on the healthy path"
    assert m.poison_probes > 0, "poison probe never ran"
    assert m.rejected == 0 and m.quarantined == 0 and \
        m.overload_entries == 0, (
            "hardened thresholds tripped on a healthy run: "
            f"rejected={m.rejected} quarantined={m.quarantined} "
            f"overload_entries={m.overload_entries}")

    # Per-poll hook chain, timed in isolation on the (idle, warm)
    # hardened engine with the healthy-path inputs the drain fed it.
    eng = engines[True]
    lg = np.zeros((batch, cfg.vocab_size), np.float32)
    live = list(range(batch))
    iters = 2000
    t0 = time.perf_counter()
    for _ in range(iters):
        eng._probe_rows(live, lg, 0.0, "probe_bench")
        eng._shed_inflight(time.time())
        eng._update_overload()
    hook_s = (time.perf_counter() - t0) / iters
    overhead = hook_s / poll_s

    results = {
        "wall_plain_s": round(min(walls[False]), 4),
        "wall_hardened_s": round(min(walls[True]), 4),
        "e2e_overhead_median": round(e2e_overhead, 4),
        "hook_us_per_poll": round(hook_s * 1e6, 2),
        "poll_us": round(poll_s * 1e6, 1),
        "overhead": round(overhead, 4),
        "poison_probes": m.poison_probes,
        "greedy_identical": True,
    }
    emit("serve_chaos_probe_overhead", 0.0, round(overhead, 4))
    if not smoke:
        assert overhead <= 0.03, (
            f"hardening hook chain is {overhead:.1%} of a poll "
            f"({hook_s * 1e6:.1f}us of {poll_s * 1e6:.1f}us), over the "
            f"3% budget")
        assert e2e_overhead <= 0.30, (
            f"end-to-end hardened drain {e2e_overhead:.1%} slower than "
            f"plain — far above hook cost + scheduler noise; something "
            f"expensive entered the hardened path")
    return results


def bench_overload(arch="mamba2-130m", requests=24, batch=2,
                   per_poll=3, queue_cap=4, seed=0, smoke=False):
    """Bounded-queue backpressure under sustained overload.

    ``per_poll`` submissions are offered every engine poll; service is
    roughly ``batch / max_new`` completions per poll (~0.25 here), so the
    offered load is an order of magnitude above capacity — the queue must
    saturate and submit() must refuse.  The driver records what the
    engine's own counters cannot see from outside: the max queue depth it
    ever observed and the accepted/rejected split it was handed back."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         cfg.dtype)
    scfg = ServeConfig(max_batch=batch, prefill_buckets=(16,),
                       max_new_tokens=8, seed=seed,
                       max_queue_depth=queue_cap,
                       overload_queue_depth=max(queue_cap - 1, 1))
    engine = ContinuousEngine(model, params, scfg)
    _warmup(engine, cfg.vocab_size, np.random.default_rng(seed + 1))

    rng = np.random.default_rng(seed)
    pending = [rng.integers(1, cfg.vocab_size,
                            int(rng.integers(4, 17))).tolist()
               for _ in range(requests)]
    accepted, rejected, qmax, done = [], 0, 0, []
    while pending or engine.busy:
        for _ in range(min(per_poll, len(pending))):
            uid = engine.submit(pending.pop(), 8)
            if uid is None:
                rejected += 1
            else:
                accepted.append(uid)
        qmax = max(qmax, len(engine.scheduler))
        if engine.busy:
            done.extend(engine.poll())

    m = engine.metrics
    assert rejected > 0, "overload never rejected a request"
    assert m.rejected == rejected, (m.rejected, rejected)
    assert qmax <= queue_cap, \
        f"queue depth {qmax} exceeded the bound {queue_cap}"
    assert m.overload_entries >= 1, "degraded mode never entered"
    assert m.overload_entries == m.overload_exits, (
        f"degraded mode did not clear: {m.overload_entries} entries, "
        f"{m.overload_exits} exits")
    assert len(done) == len(accepted) and \
        all(r.status == "ok" for r in done), (
            f"accepted work lost under overload: {len(done)} done of "
            f"{len(accepted)} accepted")
    results = {
        "offered": requests,
        "accepted": len(accepted),
        "rejected": rejected,
        "max_queue_depth_seen": qmax,
        "queue_cap": queue_cap,
        "overload_entries": m.overload_entries,
        "overload_exits": m.overload_exits,
        "accepted_completed": len(done),
    }
    emit("serve_overload_rejected_frac", 0.0,
         round(rejected / requests, 3))
    return results


def bench_chaos(arch="mamba2-130m", requests=6, seed=0, smoke=False):
    """Blast radius of a seeded poison/stall/fail plan: the smoke-chaos
    scenario as a measured arm.  Asserted identically in both modes —
    chaos correctness is not timing-dependent."""
    cfg = get_config(arch, reduced=True).replace(
        param_dtype="float32").with_decode_mode("cumba")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         cfg.dtype)
    lengths = [int(x) for x in
               np.random.default_rng(seed).integers(4, 30, requests)]

    def one_run(chaos):
        eng = ContinuousEngine(model, params, ServeConfig(
            max_batch=2, prefill_buckets=(16, 32), max_new_tokens=8,
            seed=seed, poison_probe="logits", strict_recompile=True))
        rng = np.random.default_rng(seed)
        try:
            # Warmup visits both prefill buckets; any shape first seen
            # after reset_stats() would count as a post-warmup retrace.
            for length in (6, 20, 10, 28):
                eng.submit(rng.integers(1, cfg.vocab_size, length).tolist())
            eng.run()
            eng.reset_stats()
            if chaos:
                base = eng.poll_index
                eng.set_fault_plan(
                    f"poison@{base + 2}:slot=0;"
                    f"stall@{base + 4}:program=decode,stall_s=0.05;"
                    f"fail@{base + 6}:program=decode")
            for length in lengths:
                eng.submit(rng.integers(1, cfg.vocab_size, length).tolist())
            done = {r.uid: r for r in eng.run()}
        finally:
            eng.close()
        trips = {k: s.trips for k, s in eng.sentinels.items()}
        return done, eng, trips

    base, _, _ = one_run(chaos=False)
    done, eng, trips = one_run(chaos=True)

    healthy = [r for r in done.values() if r.status == "ok"]
    poisoned = [r for r in done.values() if r.status == "poisoned"]
    assert len(poisoned) == 1, [r.status for r in done.values()]
    for r in healthy:
        assert r.out_tokens == base[r.uid].out_tokens, (
            f"healthy request {r.uid} diverged under chaos")
    fired = eng._injector.summary()["fired"]
    assert fired == {"poison": 1, "fail": 1, "stall": 1}, fired
    m = eng.metrics
    assert m.quarantined == 1 and m.backend_fallbacks == 1, (
        m.quarantined, m.backend_fallbacks)
    assert eng.model.cfg.xamba.decode == "naive", eng.model.cfg.xamba.decode
    assert not any(trips.values()), f"post-warmup recompiles: {trips}"
    results = {
        "requests": requests,
        "healthy_identical": len(healthy),
        "quarantined": m.quarantined,
        "backend_fallbacks": m.backend_fallbacks,
        "fallback_chain": "cumba->naive",
        "faults_fired": fired,
        "recompile_trips": sum(trips.values()),
    }
    emit("serve_chaos_healthy_identical", 0.0,
         f"{len(healthy)}/{requests}")
    return results


def run(smoke: bool = False) -> dict:
    """Harness entrypoint; the returned dict is BENCH_serve.json's
    ``robustness`` block."""
    if smoke:
        return {
            "probe_overhead": bench_probe_overhead(requests=8, reps=1,
                                                   smoke=True),
            "overload": bench_overload(requests=12, smoke=True),
            "chaos": bench_chaos(requests=4, smoke=True),
        }
    return {
        "probe_overhead": bench_probe_overhead(),
        "overload": bench_overload(),
        "chaos": bench_chaos(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    results = run(smoke=args.smoke)
    po, ov, ch = (results["probe_overhead"], results["overload"],
                  results["chaos"])
    print(f"probe_overhead={po['overhead']:.2%}  "
          f"(plain {po['wall_plain_s']:.3f}s vs "
          f"hardened {po['wall_hardened_s']:.3f}s)")
    print(f"overload: {ov['rejected']}/{ov['offered']} rejected, "
          f"qmax={ov['max_queue_depth_seen']}<= cap {ov['queue_cap']}, "
          f"degraded {ov['overload_entries']} in / "
          f"{ov['overload_exits']} out")
    print(f"chaos: {ch['healthy_identical']}/{ch['requests']} healthy "
          f"identical, {ch['quarantined']} quarantined, "
          f"fallback {ch['fallback_chain']}, "
          f"{ch['recompile_trips']} recompiles")


if __name__ == "__main__":
    main()
