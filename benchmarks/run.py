"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; ``--json`` additionally writes
machine-readable ``BENCH_decode.json`` / ``BENCH_serve.json`` (tokens/s
per family, speedups, compile counts) so the perf trajectory is tracked
across PRs.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only fig1,kpi,...]
    PYTHONPATH=src python -m benchmarks.run --json --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

BENCHES = ("fig1", "fig4a", "fig4c", "table1", "kpi", "roofline", "serve")
# Benchmarks with a --smoke-aware run(smoke=...) and a JSON artifact.
JSON_OUT = {"kpi": "BENCH_decode.json", "serve": "BENCH_serve.json",
            "table1": "BENCH_quality.json"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(BENCHES))
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_decode.json / BENCH_serve.json")
    ap.add_argument("--out-dir", default=".",
                    help="directory for the --json artifacts")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced iteration counts (CI); restricts the "
                         "default set to the JSON-producing benchmarks")
    args = ap.parse_args()
    want = [w for w in args.only.split(",") if w]
    if not want:
        want = list(JSON_OUT) if args.smoke else list(BENCHES)

    print("name,us_per_call,derived")
    failures = 0
    for key in want:
        try:
            if key == "fig1":
                from benchmarks import bench_fig1_op_breakdown as m
            elif key == "fig4a":
                from benchmarks import bench_fig4a_cumba_reduba as m
            elif key == "fig4c":
                from benchmarks import bench_fig4c_actiba as m
            elif key == "table1":
                from benchmarks import bench_table1_quality as m
            elif key == "kpi":
                from benchmarks import bench_kpi_decode as m
            elif key == "roofline":
                from benchmarks import roofline as m
            elif key == "serve":
                from benchmarks import bench_serve_continuous as m
            else:
                raise ValueError(f"unknown benchmark {key!r}")
            kwargs = {"smoke": args.smoke} if key in JSON_OUT else {}
            result = m.run(**kwargs)
            if args.json and key in JSON_OUT:
                os.makedirs(args.out_dir, exist_ok=True)
                path = os.path.join(args.out_dir, JSON_OUT[key])
                with open(path, "w") as f:
                    json.dump(result, f, indent=2, sort_keys=True)
                    f.write("\n")
                print(f"# wrote {path}", file=sys.stderr)
        except Exception:  # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"{key},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
