"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig1,fig4a,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = ("fig1", "fig4a", "fig4c", "table1", "kpi", "roofline", "serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    want = [w for w in args.only.split(",") if w] or list(BENCHES)

    print("name,us_per_call,derived")
    failures = 0
    for key in want:
        try:
            if key == "fig1":
                from benchmarks import bench_fig1_op_breakdown as m
            elif key == "fig4a":
                from benchmarks import bench_fig4a_cumba_reduba as m
            elif key == "fig4c":
                from benchmarks import bench_fig4c_actiba as m
            elif key == "table1":
                from benchmarks import bench_table1_quality as m
            elif key == "kpi":
                from benchmarks import bench_kpi_decode as m
            elif key == "roofline":
                from benchmarks import roofline as m
            elif key == "serve":
                from benchmarks import bench_serve_continuous as m
            else:
                raise ValueError(f"unknown benchmark {key!r}")
            m.run()
        except Exception:  # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"{key},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
