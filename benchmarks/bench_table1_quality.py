"""Table 1 reproduction (mechanism): accuracy side of the approximation
trades — ActiBA's PWL activations and the W8 weight-only quantization.

Offline (no lm-eval datasets), the *mechanisms* are measured directly and
written to ``BENCH_quality.json`` so every accuracy/perf trade in
``BENCH_decode.json`` has its quality column on record:

* **PWL** — approximation error per activation per segment count, and
  end-to-end logit divergence / top-1 agreement between the exact and
  PLU-mapped mamba(-2)-130m (the quantity whose smallness moves Table 1's
  benchmark accuracies by <0.1%).
* **W8** — per family: logit MSE / max-abs error of the int8-per-channel
  model vs fp32, the free-running greedy divergence length (first token
  where the quantized continuation departs), and teacher-forced argmax
  agreement (the feedback-free view: with random-init near-tie logits the
  free-running length is a pessimistic lower bound — see
  ``tests/test_quant.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import pwl
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn import quant
from repro.nn.params import init_params

W8_FAMILIES = ("mamba2-130m", "mamba-130m", "recurrentgemma-2b", "gemma-2b")


def _greedy_tokens(model, params, toks, n):
    """Free-running greedy continuation via the decode path: (b, n)."""
    cache = model.init_cache(toks.shape[0], toks.shape[1] + n, jnp.float32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(cur)]
    dv = model.decode_view(params)
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for t in range(1, n):
        logits, cache = step(dv, cur[:, None], cache,
                             jnp.int32(toks.shape[1] + t - 1))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(cur))
    return np.stack(out, 1)


def _forced_logits(model, params, toks, stream):
    """Prefill + teacher-forced decode logits along ``stream`` — the
    family-uniform serving path (RecurrentGemma has no stateless
    ``forward``), and the one W8 actually accelerates."""
    b, L = toks.shape
    cache = model.init_cache(b, L + stream.shape[1], jnp.float32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    out = [np.asarray(logits)]
    dv = model.decode_view(params)
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for t in range(stream.shape[1] - 1):
        logits, cache = step(dv, stream[:, t][:, None], cache,
                             jnp.int32(L + t))
        out.append(np.asarray(logits))
    return np.stack(out, 1)


@functools.lru_cache(maxsize=None)
def w8_quality_metrics(archs=W8_FAMILIES, *, n_new: int = 64,
                       seed: int = 0) -> dict:
    """Per-family W8-vs-fp32 quality block (reduced configs, fp32 ref).

    Memoized: one ``benchmarks.run --json`` invocation records the block
    both in ``BENCH_decode.json`` (next to the w8 perf arms) and in
    ``BENCH_quality.json`` without paying the sweep twice."""
    out = {}
    for arch in archs:
        cfg = get_config(arch, reduced=True).replace(param_dtype="float32")
        model = build_model(cfg)
        params = init_params(build_model(cfg).param_specs(),
                             jax.random.PRNGKey(seed), jnp.float32)
        qp = quant.quantize_params(params)
        tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (4, 16),
                                    1, cfg.vocab_size)
        stream = jax.random.randint(jax.random.PRNGKey(seed + 3),
                                    (4, n_new), 1, cfg.vocab_size)
        exact = _forced_logits(model, params, tokens, stream)
        approx = _forced_logits(model, qp, tokens, stream)
        mse = float(np.mean((exact - approx) ** 2))
        max_abs = float(np.abs(exact - approx).max())
        forced_agree = float((exact.argmax(-1) == approx.argmax(-1)).mean())

        prompt = jax.random.randint(jax.random.PRNGKey(seed + 2), (4, 16),
                                    1, cfg.vocab_size)
        g_f = _greedy_tokens(model, params, prompt, n_new)
        g_q = _greedy_tokens(model, qp, prompt, n_new)
        same = g_f == g_q
        div_len = [int(np.argmin(r)) if not r.all() else n_new
                   for r in same]
        out[arch] = {
            "logit_mse": round(mse, 6),
            "logit_max_abs": round(max_abs, 5),
            "forced_top1_agree": round(forced_agree, 4),
            "greedy_divergence_len_mean": round(float(np.mean(div_len)), 1),
            "greedy_divergence_len_min": int(np.min(div_len)),
            "greedy_horizon": n_new,
        }
        emit(f"table1.w8.{arch}", 0.0,
             f"logit_mse={mse:.6f};forced_top1={forced_agree:.4f};"
             f"div_len={np.mean(div_len):.1f}/{n_new}")
    return out


def run(smoke: bool = False) -> dict:
    """Harness entrypoint; the returned dict is ``BENCH_quality.json``."""
    result = {"benchmark": "quality", "pwl_err": {}, "e2e_actiba": {},
              "w8": {}}
    for name in ("silu", "softplus", "gelu", "sigmoid"):
        for k in ((16,) if smoke else (8, 16, 32, 64)):
            e = pwl.pwl_error(pwl.numpy_fn(name),
                              pwl.get_table(name, segments=k))
            emit(f"table1.pwl_err.{name}.k{k}", 0.0,
                 f"max_abs={e['max_abs']:.5f};mean_abs={e['mean_abs']:.6f}")
            result["pwl_err"][f"{name}.k{k}"] = {
                "max_abs": round(float(e["max_abs"]), 6),
                "mean_abs": round(float(e["mean_abs"]), 7)}

    # end-to-end ActiBA logit divergence on the paper's two models
    for arch in ("mamba2-130m", "mamba-130m"):
        cfg = get_config(arch, reduced=True).replace(param_dtype="float32")
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             jnp.float32)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                    cfg.vocab_size)
        exact = np.asarray(model.forward(params, tokens), np.float32)
        for k in ((16,) if smoke else (16, 32)):
            cfg2 = cfg.replace(xamba=XambaConfig.full(segments=k))
            model2 = build_model(cfg2)
            approx = np.asarray(model2.forward(params, tokens), np.float32)
            # KL(exact || approx) over the vocab + top-1 agreement
            lse_e = exact - exact.max(-1, keepdims=True)
            pe = np.exp(lse_e) / np.exp(lse_e).sum(-1, keepdims=True)
            lse_a = approx - approx.max(-1, keepdims=True)
            pa = np.exp(lse_a) / np.exp(lse_a).sum(-1, keepdims=True)
            kl = float((pe * (np.log(pe + 1e-9) - np.log(pa + 1e-9)))
                       .sum(-1).mean())
            top1 = float((exact.argmax(-1) == approx.argmax(-1)).mean())
            emit(f"table1.e2e.{arch}.k{k}", 0.0,
                 f"kl={kl:.5f};top1_agree={top1:.4f}")
            result["e2e_actiba"][f"{arch}.k{k}"] = {
                "kl": round(kl, 6), "top1_agree": round(top1, 4)}

    archs = W8_FAMILIES[:2] if smoke else W8_FAMILIES
    result["w8"] = w8_quality_metrics(archs, n_new=32 if smoke else 64)
    return result


if __name__ == "__main__":
    run()
