"""Table 1 reproduction (mechanism): ActiBA quality preservation.

Offline (no lm-eval datasets), Table 1's *mechanism* is measured directly:
(1) the PWL approximation error per activation per segment count, and
(2) end-to-end logit divergence / top-1 agreement between the exact and
PLU-mapped mamba(-2)-130m — the quantity whose smallness makes the
benchmark accuracies in Table 1 move by <0.1%.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import pwl
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn.params import init_params


def run() -> list:
    rows = []
    for name in ("silu", "softplus", "gelu", "sigmoid"):
        for k in (8, 16, 32, 64):
            e = pwl.pwl_error(pwl.numpy_fn(name),
                              pwl.get_table(name, segments=k))
            rows.append(emit(f"table1.pwl_err.{name}.k{k}", 0.0,
                             f"max_abs={e['max_abs']:.5f};"
                             f"mean_abs={e['mean_abs']:.6f}"))

    # end-to-end logit divergence on the paper's two models
    for arch in ("mamba2-130m", "mamba-130m"):
        cfg = get_config(arch, reduced=True).replace(param_dtype="float32")
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             jnp.float32)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                    cfg.vocab_size)
        exact = np.asarray(model.forward(params, tokens), np.float32)
        for k in (16, 32):
            cfg2 = cfg.replace(xamba=XambaConfig.full(segments=k))
            model2 = build_model(cfg2)
            approx = np.asarray(model2.forward(params, tokens), np.float32)
            # KL(exact || approx) over the vocab + top-1 agreement
            lse_e = exact - exact.max(-1, keepdims=True)
            pe = np.exp(lse_e) / np.exp(lse_e).sum(-1, keepdims=True)
            lse_a = approx - approx.max(-1, keepdims=True)
            pa = np.exp(lse_a) / np.exp(lse_a).sum(-1, keepdims=True)
            kl = float((pe * (np.log(pe + 1e-9) - np.log(pa + 1e-9)))
                       .sum(-1).mean())
            top1 = float((exact.argmax(-1) == approx.argmax(-1)).mean())
            rows.append(emit(f"table1.e2e.{arch}.k{k}", 0.0,
                             f"kl={kl:.5f};top1_agree={top1:.4f}"))
    return rows


if __name__ == "__main__":
    run()
