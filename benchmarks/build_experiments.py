"""Regenerate the auto tables in EXPERIMENTS.md from dry-run artifacts.

Usage: PYTHONPATH=src python benchmarks/build_experiments.py
Replaces the blocks between ``<!-- AUTO:<name> -->`` markers.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks import roofline

ROOT = Path(__file__).resolve().parents[1]
EXP = ROOT / "EXPERIMENTS.md"


def dryrun_summary() -> str:
    recs = roofline.load()
    ok = [r for r in recs if r.get("ok") and not r.get("skipped")]
    skip = [r for r in recs if r.get("skipped")]
    fail = [r for r in recs if not r.get("ok")]
    lines = [f"* cells compiled OK: **{len(ok)}** "
             f"(+{len(skip)} recorded skips, {len(fail)} failures)"]
    fits = sum(1 for r in ok if r["memory"]["fits_16gb_hbm"])
    lines.append(f"* fits 16 GB v5e HBM: {fits}/{len(ok)} "
                 f"(non-fitting cells are decode-cache outliers; see §Perf)")
    for r in fail:
        lines.append(f"  * FAIL: {r['arch']} {r['shape']} {r['mesh']}: "
                     f"{r.get('error','?')[:120]}")
    return "\n".join(lines)


def skips_table() -> str:
    recs = [r for r in roofline.load() if r.get("skipped")]
    seen = set()
    out = ["| arch | shape | reason |", "|---|---|---|"]
    for r in recs:
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        out.append(f"| {r['arch']} | {r['shape']} | "
                   f"{r['skip_reason'][:90]}... |")
    return "\n".join(out)


def variants_table(prefix: str) -> str:
    """Hillclimb variant rows: artifacts tagged <arch>__<shape>__<mesh>-<tag>."""
    rows = ["| variant | compute s | memory s | collective s | "
            "bottleneck | roofline frac | HBM GB |",
            "|---|---|---|---|---|---|---|"]
    art = roofline.ART
    base = art / f"{prefix}.json"
    items = []
    if base.exists():
        items.append(("baseline", json.load(open(base))))
    for p in sorted(art.glob(f"{prefix}-*.json")):
        tag = p.stem.split("-")[-1]
        items.append((tag, json.load(open(p))))
    for tag, r in items:
        if not r.get("ok"):
            rows.append(f"| {tag} | FAIL | | | | | |")
            continue
        rl = r["roofline"]
        rows.append(
            f"| {tag} | {rl['compute_s']:.3f} | {rl['memory_s']:.3f} | "
            f"{rl['collective_s']:.3f} | {rl['bottleneck'].replace('_s','')} "
            f"| {rl['roofline_fraction']:.3f} | "
            f"{r['memory']['total_gb']:.1f} |")
    return "\n".join(rows)


def build():
    text = EXP.read_text()

    def sub(name, content):
        nonlocal text
        pattern = (f"(<!-- AUTO:{name} -->).*?(<!-- /AUTO:{name} -->)")
        text = re.sub(pattern, lambda m: m.group(1) + "\n" + content +
                      "\n" + m.group(2), text, flags=re.S)

    sub("summary", dryrun_summary())
    sub("skips", skips_table())
    sub("roofline_single", roofline.table("single"))
    sub("roofline_multi", roofline.table("multi"))
    sub("perf_mamba2", variants_table("mamba2-2.7b__train_4k__single"))
    sub("perf_grok", variants_table("grok-1-314b__train_4k__single"))
    sub("perf_internlm2", variants_table("internlm2-20b__train_4k__single"))
    sub("perf_qwen3", variants_table("qwen3-moe-30b-a3b__train_4k__single"))
    EXP.write_text(text)
    print("EXPERIMENTS.md rebuilt")


if __name__ == "__main__":
    build()
