"""KPI reproduction: decode tokens/s through the fused stacked-layer path.

Two views:

* **per-family decode tokens/s** (reduced configs, the numbers tracked
  across PRs in ``BENCH_decode.json``): the *baseline* arm reproduces the
  pre-refactor program structure — rolled scan over stacked layers with
  in-program weight slicing (mamba) / per-layer Python dispatch over the
  grouped weights (rgemma), seq-axis (b, 1, d) operands, fresh state
  pytree every step — against the *fused* arm: pre-sliced decode view,
  token-major fused step, cache donated into the jitted program.  The
  baseline's contraction runs the paper's ``naive`` mul+ReduceSum chain
  (the deleted step used a dot-based contraction — a few percent at
  these shapes; the speedup comes from scan structure, layout and
  donation).  Both arms share one stacked weight tree.
* **full-size mamba KPI** (paper: 100 -> 260 tok/s with ActiBA on the NPU
  vs a 50 tok/s KPI target): full 130M models, baseline vs xamba variants
  (skipped under ``--smoke``).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn import quant
from repro.nn.params import init_params
from repro.serve.state_pool import format_compile_count, jit_cache_size

FAMILIES = ("mamba-130m", "mamba2-130m", "recurrentgemma-2b")


def _w8(xamba: XambaConfig) -> XambaConfig:
    return dataclasses.replace(xamba, quant="w8")


def _make_variant(cfg, params, *, donate: bool, batch: int,
                  decode_view: bool = False):
    """Build a ready-to-time decode-step closure for ``cfg``."""
    model = build_model(cfg)
    cache = model.init_cache(batch, 64, jnp.float32)
    tok = jnp.ones((batch, 1), jnp.int32)
    if decode_view:
        params = model.decode_view(params)   # engine-style pre-sliced view
    donate_kw = {"donate_argnums": (2,)} if donate else {}
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c, jnp.int32(4)),
                   **donate_kw)
    box = {"cache": cache}

    def call():
        logits, box["cache"] = step(params, tok, box["cache"])
        jax.block_until_ready(logits)

    return call, step


def _time_interleaved(calls, iters=24, warmup=3):
    """Median seconds per call for each variant, with the variants'
    timed calls ROUND-ROBIN interleaved: background load on a shared box
    drifts over seconds, so timing A fully before B biases the ratio —
    alternating samples cancels the drift."""
    for call in calls:
        for _ in range(warmup):
            call()
    ts = [[] for _ in calls]
    for _ in range(iters):
        for i, call in enumerate(calls):
            t0 = time.perf_counter()
            call()
            ts[i].append(time.perf_counter() - t0)
    return [float(np.median(t)) for t in ts]


def bench_families(smoke: bool = False, batch: int = 1) -> dict:
    iters = 12 if smoke else 40
    out = {}
    for arch in FAMILIES:
        base_cfg = get_config(arch, reduced=True).replace(
            param_dtype="float32")
        # Pre-refactor reproduction — what the decode program was before
        # this subsystem existed: mamba families ran a ROLLED scan over
        # stacked layers (in-program weight slicing, XLA while loop);
        # recurrentgemma Python-looped per layer, slicing the grouped
        # weights in-program.  Dense ``naive`` step math, no donation.
        pre_scan = arch.startswith("mamba")
        pre_cfg = base_cfg.replace(scan_layers=pre_scan,
                                   xamba=XambaConfig.baseline())
        # Fused: unrolled stacked scan / pre-sliced decode view,
        # dispatched (MXU) step, cache donated into the program.
        fused_cfg = base_cfg.replace(scan_layers=True,
                                     xamba=XambaConfig.optimized())

        # Stacked (mamba) / group-stacked (rgemma) weights serve both arms.
        pre_params = init_params(build_model(pre_cfg).param_specs(),
                                 jax.random.PRNGKey(0), jnp.float32)
        fused_params = pre_params

        # W8 arm: the fused configuration on int8 per-channel weights
        # (XLA dot_general-on-int8 backend) — same program structure,
        # quarter the weight bytes.
        w8_cfg = fused_cfg.replace(xamba=_w8(fused_cfg.xamba))
        w8_params = quant.quantize_params_for_mode(fused_params, "w8")

        call_pre, _ = _make_variant(pre_cfg, pre_params, donate=False,
                                    batch=batch)
        call_fused, step_fused = _make_variant(fused_cfg, fused_params,
                                               donate=True, batch=batch,
                                               decode_view=True)
        call_w8, step_w8 = _make_variant(w8_cfg, w8_params, donate=True,
                                         batch=batch, decode_view=True)
        t_pre, t_fused, t_w8 = _time_interleaved(
            [call_pre, call_fused, call_w8], iters=iters)
        compiles = jit_cache_size(step_fused)
        speedup = t_pre / t_fused
        out[arch] = {
            "prerefactor_tok_s": round(batch / t_pre, 1),
            "fused_tok_s": round(batch / t_fused, 1),
            "w8_tok_s": round(batch / t_w8, 1),
            "speedup": round(speedup, 2),
            "decode_mode": fused_cfg.xamba.decode,
            "decode_compiles": format_compile_count(compiles),
            "w8_decode_compiles": format_compile_count(
                jit_cache_size(step_w8)),
        }
        emit(f"kpi.decode.{arch}.prerefactor", t_pre * 1e6,
             f"tokens_per_s={batch / t_pre:.1f}")
        emit(f"kpi.decode.{arch}.fused", t_fused * 1e6,
             f"tokens_per_s={batch / t_fused:.1f};speedup={speedup:.2f}x")
        emit(f"kpi.decode.{arch}.w8", t_w8 * 1e6,
             f"tokens_per_s={batch / t_w8:.1f}")
    return out


def bench_prefill(smoke: bool = False, batch: int = 2,
                  seqlen: int = 128) -> dict:
    """Prefill arm: whole-sequence prompt ingestion, fused vs unfused.

    The *unfused* arm runs ``XambaConfig.prefill="naive"`` — the legacy
    op chain (separate in-projection, causal conv, activations, SSD
    core, gated norm, each a distinct XLA computation with HBM
    round-trips between them).  The *fused* arm runs the default
    ``prefill="cumba"`` single-pass pipeline (`kernels/prefill_chunk`).
    Only the mamba2 (SSD) family has a fused prefill pipeline; the
    mamba1/recurrentgemma rows are CONTROL arms — their prefill path
    ignores the mode, so their ratio should sit at ~1.0 and any drift
    bounds the timing noise floor for the mamba2 ratio.
    """
    iters = 8 if smoke else 24
    out = {}
    for arch in FAMILIES:
        base_cfg = get_config(arch, reduced=True).replace(
            param_dtype="float32")
        arms = {
            mode: base_cfg.replace(xamba=dataclasses.replace(
                XambaConfig.optimized(), prefill=mode))
            for mode in ("naive", "cumba")
        }
        params = init_params(build_model(arms["naive"]).param_specs(),
                             jax.random.PRNGKey(0), jnp.float32)
        toks = jnp.asarray(
            np.random.default_rng(0).integers(
                1, base_cfg.vocab_size, (batch, seqlen)), jnp.int32)

        calls, logits_by = [], {}
        for mode, cfg in arms.items():
            model = build_model(cfg)
            cache = model.init_cache(batch, seqlen, jnp.float32)
            pf = jax.jit(lambda p, t, c, m=model:
                         m.prefill(p, {"tokens": t}, c))

            def call(pf=pf, cache=cache):
                logits, _ = pf(params, toks, cache)
                jax.block_until_ready(logits)

            calls.append(call)
            logits_by[mode] = pf(params, toks, cache)[0]
        t_naive, t_fused = _time_interleaved(calls, iters=iters)
        toks_total = batch * seqlen
        greedy_same = bool(
            (jnp.argmax(logits_by["naive"], -1)
             == jnp.argmax(logits_by["cumba"], -1)).all())
        out[arch] = {
            "unfused_tok_s": round(toks_total / t_naive, 1),
            "fused_tok_s": round(toks_total / t_fused, 1),
            "speedup": round(t_naive / t_fused, 2),
            "greedy_match": greedy_same,
            "control_arm": not arch.startswith("mamba2"),
        }
        emit(f"kpi.prefill.{arch}.unfused", t_naive * 1e6,
             f"tokens_per_s={toks_total / t_naive:.1f}")
        emit(f"kpi.prefill.{arch}.fused", t_fused * 1e6,
             f"tokens_per_s={toks_total / t_fused:.1f};"
             f"speedup={t_naive / t_fused:.2f}x")
    out["note"] = ("fused = XambaConfig.prefill='cumba' single-pass SSD "
                   "prefill pipeline (kernels/prefill_chunk); unfused = "
                   "prefill='naive' legacy op chain.  Only mamba2 has a "
                   "fused prefill path — other families are control arms "
                   "(ratio ~1.0 bounds timing noise).  batch=%d seqlen=%d"
                   % (batch, seqlen))
    return out


def bench_kpi_full() -> dict:
    """Full 130M models through the decode path, per XAMBA variant.

    The headline ``xamba`` arm is ``XambaConfig.optimized()`` — the exact
    CumBA/ReduBA remap, which is the configuration a deployment should
    run on this backend.  ActiBA is timed as a separate ``xamba_actiba``
    arm and is EXPECTED to be slower here: it emulates the NPU's
    PLU/C-LUT datapath as K-segment piecewise-linear chains (`core/pwl`),
    which costs ~K extra vector ops per activation on a backend whose
    native SiLU/softplus are single fused ops.  The paper's 2.6x ActiBA
    win is an NPU-hardware property, not reproducible as wall-clock on
    CPU/TPU — see docs/benchmarks.md.  (Earlier revisions folded ActiBA
    into the headline arm, which is why BENCH_decode.json once showed
    mamba2 "xamba" at 4.0 tok/s vs 9.6 baseline.)

    Full-size single-token programs are also acutely sensitive to how
    XLA-CPU schedules the layer stack: at 130M scale mamba1's fused 2D
    step regresses ~1.7x when the decode cache is scan-stacked (mamba2's
    regresses ~2.6x when it is per-layer), at IDENTICAL compiled
    flops/bytes — a backend program-quality artifact, not an algorithmic
    cost (reduced-size configs show the fused win in both layouts).  Each
    family therefore runs the serving layout its deployment would pick,
    recorded as ``decode_layout``.

    The precision arms pin the W8 claim: ``bf16`` is the optimized remap
    on bfloat16 params (the standard low-precision serving format — on
    XLA-CPU its gemms run through an upconvert path, so it is SLOWER than
    fp32 here; on TPU/NPU it is the bandwidth-efficient deployment arm)
    and ``w8`` is the optimized remap on int8 per-channel weights via
    dot_general-on-int8 (``nn/quant.py``).  The headline quantization
    ratio is ``w8_vs_bf16`` — int8 vs the comparable reduced-precision
    deployment arm; fp32 ``xamba`` stays the absolute-fastest arm on this
    CPU backend because its gemms avoid any convert (see
    docs/quantization.md for the honest accounting).
    """
    # scan_layers per family: the layout whose fused step does not regress
    # at full size on this backend (see docstring).
    layout = {"mamba-130m": False, "mamba2-130m": True}
    out = {}
    for arch in ("mamba-130m", "mamba2-130m"):
        variants = (("baseline", XambaConfig.baseline(), "float32", None),
                    ("xamba", XambaConfig.optimized(), "float32", None),
                    ("xamba_actiba", XambaConfig.full(segments=16),
                     "float32", None),
                    ("bf16", XambaConfig.optimized(), "bfloat16", None),
                    ("w8", _w8(XambaConfig.optimized()), "float32", "w8"))
        calls, steps = [], {}
        for vname, xamba, dtype, qmode in variants:
            cfg = get_config(arch).replace(param_dtype=dtype,
                                           xamba=xamba,
                                           scan_layers=layout[arch])
            params = init_params(build_model(cfg).param_specs(),
                                 jax.random.PRNGKey(0), cfg.dtype)
            if qmode:
                params = quant.quantize_params_for_mode(params, qmode)
            call, step = _make_variant(cfg, params, donate=True, batch=1,
                                       decode_view=True)
            calls.append(call)
            steps[vname] = step
        times = dict(zip([v[0] for v in variants],
                         _time_interleaved(calls, iters=8)))
        for vname, t in times.items():
            out[f"{arch}.{vname}"] = round(1.0 / t, 1)
            emit(f"kpi.decode.{arch}.{vname}", t * 1e6,
                 f"tokens_per_s={1.0 / t:.1f}")
        out[f"{arch}.w8_vs_bf16"] = round(times["bf16"] / times["w8"], 2)
        w8_compiles = jit_cache_size(steps["w8"])
        out[f"{arch}.w8_decode_recompiles_after_warmup"] = (
            w8_compiles - 1 if w8_compiles >= 0 else "unavailable")
        out[f"{arch}.decode_layout"] = (
            "scan_stacked" if layout[arch] else "per_layer")
    out["note"] = ("xamba = exact CumBA/ReduBA remap (the non-regressing "
                   "configuration); xamba_actiba = + PWL activation "
                   "emulation of the NPU LUT datapath, slower than native "
                   "activations on this backend by construction; "
                   "bf16 = optimized remap on bfloat16 params (XLA-CPU "
                   "emulates bf16 gemms — the low-precision deployment "
                   "reference, not a CPU speed recommendation); w8 = int8 "
                   "per-channel weights (nn/quant.py), headline ratio "
                   "w8_vs_bf16; decode_layout = the per-family cache "
                   "layout that avoids the XLA-CPU full-size scheduling "
                   "regression")
    return out


def _spec_arm(model, params, *, draft_params, spec_k, warm, timed,
              max_new, baseline_out=None):
    """One end-to-end engine arm: warmup requests (all program traces),
    ``reset_stats``, then timed requests through ``run()``.  Returns the
    emitted streams plus tokens/s and the burst metrics."""
    from repro.serve import ContinuousEngine, ServeConfig
    eng = ContinuousEngine(model, params, ServeConfig(
        max_batch=1, prefill_buckets=(16,), max_new_tokens=max_new,
        speculate_k=spec_k), draft_params=draft_params)
    try:
        for p in warm:
            eng.submit(p)
        eng.run()
        eng.reset_stats()
        t0 = time.perf_counter()
        for p in timed:
            eng.submit(p)
        done = eng.run()
        wall = time.perf_counter() - t0
    finally:
        eng.close()
    out = [r.out_tokens for r in done]
    toks = sum(len(t) for t in out)
    m = eng.metrics.summary()
    trips = {k: s.trips for k, s in eng.sentinels.items()}
    row = {"tok_s": round(toks / wall, 2),
           "recompile_trips": sum(trips.values())}
    if spec_k:
        row.update({
            "k": spec_k,
            "accept_rate": round(m["spec_accept_rate"], 3),
            "tokens_per_verify": round(m["spec_tokens_per_verify"], 2),
            "rollbacks": m["spec_rollbacks"],
        })
    if baseline_out is not None:
        row["greedy_identical"] = bool(out == baseline_out)
    return out, row


def bench_speculative(smoke: bool = False) -> dict:
    """Self-speculative decoding block: end-to-end serve tokens/s with
    ``ServeConfig.speculate_k`` bursts vs the same engine without them.

    The *headline* arms target the bf16 deployment reference — the same
    comparison the repo's W8 claim is pinned to (``w8_vs_bf16``,
    docs/quantization.md): on XLA-CPU, bf16 gemms run through an
    emulation path, so bf16 is the slow deployment-format arm while
    fp32 (and the w8 path *relative to bf16*) are the cheap arms.  Two
    drafts are swept: ``w8`` (int8 per-channel weights with fp32 scales
    — the paper-faithful draft) and ``fp32_master`` (the bf16 weights'
    fp32 masters — the cheapest high-agreement draft this backend has;
    it stands in for the NPU pairing where w8 is the fast arm).  The
    ``fp32_control`` pair runs the same machinery against the fp32
    non-speculative arm and is EXPECTED to lose (< 1.0x): fp32 is the
    fastest single-token step on this backend, nothing drafts cheaper
    than it, and the k-token verify chunk costs ~k fp32 steps — the
    honest accounting for why the headline lives on the bf16 arm.

    ``greedy_identical`` is True when the speculative arm emitted
    byte-identical streams to its non-speculative baseline.  The fp32
    pairs are identical by construction (tier-1 asserts it across
    families); full-size bf16 arms can flip occasional argmaxes because
    the batched verify chunk and the single-token step accumulate in
    different orders under bf16 — the emitted stream is the verify
    chunk's greedy stream either way.

    k is chosen against the measured draft/verify divergence: BENCH
    ``w8_quality.greedy_divergence_len_mean`` (w8 vs fp32: ~12 mamba1 /
    ~27 mamba2) bounds the useful window from above; the bf16-verifier
    divergence is shorter (the measured ``accept_rate`` here), which is
    why k=4 beats k=8 end-to-end.
    """
    from repro.nn import quant as _quant

    def _cast(tree, dt):
        return jax.tree.map(
            lambda a: a.astype(dt) if a.dtype in (jnp.float32, jnp.bfloat16)
            else a, tree)

    rng = np.random.default_rng(0)
    out = {}
    if smoke:
        # Reduced fp32 smoke: exercises the path (identity + accept
        # metrics), not the speedup — at reduced size the fp32 step is
        # the fastest arm so the spec arm loses by design (see note).
        cfg = get_config("mamba2-130m", reduced=True).replace(
            param_dtype="float32")
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             cfg.dtype)
        prompts = [rng.integers(1, cfg.vocab_size, 12).tolist()
                   for _ in range(5)]
        base_out, base = _spec_arm(model, params, draft_params=None,
                                   spec_k=0, warm=prompts[:1],
                                   timed=prompts[1:], max_new=8)
        _, spec = _spec_arm(model, params, draft_params=None, spec_k=4,
                            warm=prompts[:1], timed=prompts[1:], max_new=8,
                            baseline_out=base_out)
        spec["speedup"] = round(spec["tok_s"] / base["tok_s"], 2)
        out["mamba2-130m_reduced_fp32"] = {"nonspec": base, "spec_w8": spec}
        out["note"] = ("smoke arm: reduced fp32 only — correctness and "
                       "accept-rate plumbing, not the speedup headline "
                       "(full run benches the bf16 deployment arm)")
        return out

    layout = {"mamba-130m": False, "mamba2-130m": True}
    emitted_streams = {}
    for arch in ("mamba-130m", "mamba2-130m"):
        cfg = get_config(arch).replace(param_dtype="bfloat16",
                                       scan_layers=layout[arch])
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             cfg.dtype)
        p32 = _cast(params, jnp.float32)
        drafts = {"fp32_master": p32,
                  "w8": _quant.quantize_params_for_mode(p32, "w8")}
        prompts = [rng.integers(1, cfg.vocab_size, 12).tolist()
                   for _ in range(3)]
        warm, timed = prompts[:1], prompts[1:]
        fam = {}
        base_out, fam["nonspec_bf16"] = _spec_arm(
            model, params, draft_params=None, spec_k=0, warm=warm,
            timed=timed, max_new=10)
        best = 0.0
        for dname, k in (("fp32_master", 4), ("fp32_master", 8), ("w8", 4)):
            _, row = _spec_arm(model, params, draft_params=drafts[dname],
                               spec_k=k, warm=warm, timed=timed,
                               max_new=10, baseline_out=base_out)
            row["speedup"] = round(
                row["tok_s"] / fam["nonspec_bf16"]["tok_s"], 2)
            row["draft"] = dname
            fam[f"spec_{dname}_k{k}"] = row
            best = max(best, row["speedup"])
            emit(f"kpi.speculative.{arch}.{dname}.k{k}",
                 1e6 / max(row["tok_s"], 1e-9),
                 f"tokens_per_s={row['tok_s']};accept={row['accept_rate']};"
                 f"speedup={row['speedup']}x")
        fam["headline_speedup"] = best

        # fp32 control pair: speculation vs the fastest arm on this
        # backend — expected < 1.0x (see docstring).
        cfg32 = get_config(arch).replace(param_dtype="float32",
                                         scan_layers=layout[arch])
        model32 = build_model(cfg32)
        params32 = init_params(model32.param_specs(), jax.random.PRNGKey(0),
                               cfg32.dtype)
        c_out, ctrl_base = _spec_arm(model32, params32, draft_params=None,
                                     spec_k=0, warm=warm, timed=timed,
                                     max_new=10)
        _, ctrl_spec = _spec_arm(
            model32, params32,
            draft_params=_quant.quantize_params_for_mode(params32, "w8"),
            spec_k=4, warm=warm, timed=timed, max_new=10,
            baseline_out=c_out)
        ctrl_spec["speedup"] = round(
            ctrl_spec["tok_s"] / ctrl_base["tok_s"], 2)
        fam["fp32_control"] = {"nonspec": ctrl_base, "spec_w8_k4": ctrl_spec}
        out[arch] = fam
        emitted_streams[arch] = base_out
    out["note"] = (
        "end-to-end continuous-engine tokens/s (warmup + reset_stats, "
        "then timed run), batch=1 at the pinned decode_layout.  Headline "
        "arms draft for the bf16 deployment reference (the w8_vs_bf16 "
        "comparison precedent): on XLA-CPU bf16 gemms are emulated, so "
        "the k-token verify chunk costs ~1.2 bf16 steps while drafts run "
        "on the fast fp32/w8 paths.  fp32_control shows the same "
        "machinery against the fastest (fp32) arm losing by design — on "
        "the NPU the roles invert and w8 is the fast draft arm.  k swept "
        "against w8_quality.greedy_divergence_len_mean (see docstring).")
    return out


def run(smoke: bool = False) -> dict:
    """Harness entrypoint; the returned dict is ``BENCH_decode.json``."""
    families = bench_families(smoke=smoke)
    result = {
        "benchmark": "decode",
        "batch": 1,
        "families": families,
        "speedup_reduced_mamba2": families["mamba2-130m"]["speedup"],
        "prefill": bench_prefill(smoke=smoke),
    }
    # The accuracy column of the W8 trade rides along with the perf
    # numbers (full sweep + JSON in benchmarks/bench_table1_quality.py).
    from benchmarks.bench_table1_quality import w8_quality_metrics
    result["w8_quality"] = w8_quality_metrics(
        ("mamba2-130m", "mamba-130m"), n_new=32 if smoke else 64)
    result["speculative"] = bench_speculative(smoke=smoke)
    if not smoke:
        result["kpi_full_tok_s"] = bench_kpi_full()
    return result


if __name__ == "__main__":
    run()
