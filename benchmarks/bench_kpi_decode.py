"""KPI reproduction: decoding tokens/s for mamba-130m (paper: 100 -> 260
tok/s with ActiBA on the NPU, vs a 50 tok/s KPI target).

CPU wall-clock tokens/s for the full 130M models through the serving
engine's decode path, per XAMBA variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core.xamba import XambaConfig
from repro.models import build_model
from repro.nn.params import init_params


def run() -> list:
    rows = []
    for arch in ("mamba-130m", "mamba2-130m"):
        for vname, xamba in (("baseline", XambaConfig.baseline()),
                             ("xamba", XambaConfig.full(segments=16))):
            cfg = get_config(arch).replace(param_dtype="float32",
                                           xamba=xamba)
            model = build_model(cfg)
            params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                                 jnp.float32)
            cache = model.init_cache(1, 64, jnp.float32)
            tok = jnp.ones((1, 1), jnp.int32)

            step = jax.jit(lambda p, t, c: model.decode_step(p, t, c,
                                                             jnp.int32(4)))
            t = time_fn(step, params, tok, cache, iters=8)
            rows.append(emit(f"kpi.decode.{arch}.{vname}", t * 1e6,
                             f"tokens_per_s={1.0 / t:.1f}"))
    return rows


if __name__ == "__main__":
    run()
