# Tier-1 verification + serving smoke, runnable locally and from CI.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test smoke-serve bench-serve ci

test:
	$(PY) -m pytest -x -q

smoke-serve:
	$(PY) -m repro.launch.serve --arch mamba2-130m --reduced \
	    --engine continuous --requests 4 --batch 2 --max-new 4

bench-serve:
	PYTHONPATH=src:. $(PY) -m benchmarks.bench_serve_continuous

ci: test smoke-serve
