# Tier-1 verification + serving smoke, runnable locally and from CI.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test smoke-serve smoke-prefill-chunk smoke-decode smoke-quickstart \
    linkcheck bench-serve bench-json ci

test:
	$(PY) -m pytest -x -q

smoke-serve:
	$(PY) -m repro.launch.serve --arch mamba2-130m --reduced \
	    --engine continuous --requests 4 --batch 2 --max-new 4

smoke-prefill-chunk:
	$(PY) -m repro.launch.serve --arch mamba2-130m --reduced \
	    --engine continuous --requests 4 --batch 2 --max-new 4 \
	    --prefill-chunk 8

smoke-quickstart:
	$(PY) examples/quickstart.py

linkcheck:
	$(PY) scripts/check_doc_links.py

smoke-decode:
	$(PY) -m pytest tests/test_decode_step.py -q

bench-serve:
	PYTHONPATH=src:. $(PY) -m benchmarks.bench_serve_continuous

bench-json:
	PYTHONPATH=src:. $(PY) -m benchmarks.run --json --smoke

ci: test smoke-decode smoke-serve smoke-prefill-chunk smoke-quickstart \
    linkcheck bench-json
