# Tier-1 verification + serving smoke, runnable locally and from CI.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-cov smoke-serve smoke-prefill-chunk smoke-prefill-fused \
    smoke-prefix smoke-trace smoke-spec smoke-chaos smoke-decode \
    smoke-quant smoke-quickstart smoke-flight linkcheck bench-serve \
    bench-json bench-diff hlo-diff ci

test:
	$(PY) -m pytest -x -q --durations=15

# CI variant: tier-1 under pytest-cov (not a local dependency — CI
# installs it from requirements-dev.txt); coverage.xml is uploaded as a
# build artifact.
test-cov:
	$(PY) -m pytest -x -q --durations=15 --cov=repro \
	    --cov-report=term --cov-report=xml

smoke-serve:
	$(PY) -m repro.launch.serve --arch mamba2-130m --reduced \
	    --engine continuous --requests 4 --batch 2 --max-new 4

smoke-prefill-chunk:
	$(PY) -m repro.launch.serve --arch mamba2-130m --reduced \
	    --engine continuous --requests 4 --batch 2 --max-new 4 \
	    --prefill-chunk 8

# Fused SSD prefill pipeline smoke (docs/architecture.md, Prefill modes):
# a chunked continuous-serve run through the one-kernel Pallas pipeline
# in interpret mode, asserting greedy outputs byte-identical to the
# unfused chain and compile-once counters (one prefill_chunk program,
# one decode program, zero recompiles).
smoke-prefill-fused:
	$(PY) scripts/smoke_prefill_fused.py

# W8 quantization smoke: the interpret-mode parity slice only (kernel vs
# oracle + mamba2 w8_pallas_interpret vs w8 model parity — `make test`
# already runs the full suite) + a quantized continuous-serve run.
smoke-quant:
	$(PY) -m pytest tests/test_quant.py -q \
	    -k "qmatmul_kernel or pallas_backend"
	$(PY) -m repro.launch.serve --arch mamba2-130m --reduced \
	    --engine continuous --requests 4 --batch 2 --max-new 4 \
	    --prefill-chunk 8 --quant w8

# Prefix-state cache smoke: a tiny shared-system-prompt serve run that
# asserts >= 1 cross-request cache hit, byte-identical greedy outputs
# cache on/off, and 0 decode recompiles (benchmarks/bench_serve_prefix.py
# raises on any violation).
smoke-prefix:
	PYTHONPATH=src:. $(PY) -m benchmarks.bench_serve_prefix --smoke

# Observability smoke (docs/observability.md): a traced chunked serve
# run, then trace_report --check validates the trace — per-phase
# self-times reconcile with wall within 5% and the compile-once programs
# (decode, prefill_chunk) never retraced after warmup (the recompile
# sentinel would also have raised at the offending step via
# --strict-recompile).  The trace lands in TRACE_DIR (default: a fresh
# mktemp dir, so the repo root stays clean); CI points TRACE_DIR at
# runner temp and uploads serve_trace.json from there.
smoke-trace:
	@d="$(TRACE_DIR)"; d="$${d:-$$(mktemp -d)}"; \
	echo "trace dir: $$d"; \
	$(PY) -m repro.launch.serve --arch mamba2-130m --reduced \
	    --engine continuous --requests 6 --batch 2 --max-new 6 \
	    --prefill-chunk 8 --metrics-every 4 --strict-recompile \
	    --trace "$$d/serve_trace.json" && \
	$(PY) -m repro.launch.trace_report "$$d/serve_trace.json" --check

# Self-speculative decoding smoke: greedy outputs byte-identical spec on
# vs off, accept_rate > 0, and zero post-warmup recompiles
# (scripts/smoke_speculative.py raises on any violation).
smoke-spec:
	$(PY) scripts/smoke_speculative.py

# Chaos smoke (docs/robustness.md): a seeded poison/stall/fail plan armed
# after warmup — every healthy request stays greedy-identical to a
# fault-free control run, exactly one quarantine + one backend fallback
# fire, and zero recompile sentinels trip (scripts/smoke_chaos.py raises
# on any violation).
smoke-chaos:
	$(PY) scripts/smoke_chaos.py

smoke-quickstart:
	$(PY) examples/quickstart.py

# Flight-recorder smoke (docs/observability.md): an injected fault must
# auto-dump the request ring to JSONL and `trace_report --flight` must
# parse it back.
smoke-flight:
	$(PY) scripts/smoke_flight.py

linkcheck:
	$(PY) scripts/check_doc_links.py

smoke-decode:
	$(PY) -m pytest tests/test_decode_step.py -q

bench-serve:
	PYTHONPATH=src:. $(PY) -m benchmarks.bench_serve_continuous

bench-json:
	PYTHONPATH=src:. $(PY) -m benchmarks.run --json --smoke

# Perf-regression gate (docs/benchmarks.md): diff FRESH_DIR's
# BENCH_*.json (default: repo root, i.e. whatever bench-json just wrote)
# against the committed smoke baselines under the per-metric
# direction+tolerance schema; exits nonzero on any regression.
FRESH_DIR ?= .
bench-diff:
	$(PY) scripts/bench_diff.py --fresh-dir $(FRESH_DIR)

# Per-op HLO fingerprint diff of any registered serve program under both
# cache layouts (the ROADMAP layout-cliff open item; full size by
# default — add ARGS="--reduced" for a fast structural smoke,
# ARGS="--schedule" for the op-order + buffer-assignment view,
# PROGRAM=prefill_chunk (or prefill / verify_chunk) for the other serve
# programs, ARGS="--check-budgets" to gate the pinned layout against the
# registry quality budget).
PROGRAM ?= decode
hlo-diff:
	$(PY) -m repro.launch.hlo_analysis --arch mamba2-130m \
	    --program $(PROGRAM) $(ARGS)
	$(PY) -m repro.launch.hlo_analysis --arch mamba-130m \
	    --program $(PROGRAM) $(ARGS)

ci: test smoke-decode smoke-serve smoke-prefill-chunk smoke-prefill-fused \
    smoke-prefix smoke-trace smoke-spec smoke-chaos smoke-quant \
    smoke-quickstart smoke-flight linkcheck bench-json bench-diff
