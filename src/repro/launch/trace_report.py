"""Trace-report CLI: fold a serve trace into the answers we keep needing.

``launch/serve --trace PATH`` (or any engine with ``ServeConfig.trace``)
records the span taxonomy of ``serve/tracing.py``; this tool folds a
saved trace — Chrome JSON or the JSONL event log — into:

* **per-phase wall breakdown** — where every microsecond of wall went:
  decode / prefill / admission / snapshot moves / other host work / idle
  gaps.  Self-times are computed by interval nesting on the engine
  track (a span's children don't double-count), so the phase total must
  reconcile with the trace's wall extent — ``--check`` fails the run if
  coverage drifts more than 5%.
* **TTFT decomposition** — per request: queue wait (arrival ->
  admission) vs staging (admission -> first token, i.e. its prefill
  chunks and the waits between them).  First tokens come from the final
  prefill chunk's logits, so the first decode step contributes 0 by
  construction — the report says so rather than inventing a third bar.
* **queue-time waterfall** — per-request segment table ordered by
  arrival: who waited, where.
* **slot-timeline utilization** — staging/decode busy fraction per slot.
* **recompile sentinel audit** — any ``recompile`` instant in the trace
  is a post-warmup retrace; ``--check`` asserts the compile-once
  programs (decode, prefill_chunk) never tripped.

    python -m repro.launch.trace_report serve_trace.json [--json] [--check]

``benchmarks/bench_serve_continuous.bench_phase`` uses the same
``analyze()`` to produce BENCH_serve.json's ``phase_breakdown`` block.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional

from repro.serve.metrics import _percentile
from repro.serve.tracing import TID_ENGINE, TID_HOST, TID_QUEUE, TID_SLOT0

# Leaf span name -> report phase.  Container spans ("poll", "serve.run",
# "admit") contribute their *self* time: "admit" self-time is admission
# bookkeeping outside the prefix lookup / snapshot restore nested in it.
PHASE_OF = {
    "decode_step": "decode",
    "prefill_chunk": "prefill",
    "prefill_bucket": "prefill",
    "admit": "admission",
    "prefix_lookup": "admission",
    "snapshot_restore": "snapshot",
    "snapshot_export": "snapshot",
    "pool_insert": "snapshot",
    "pool_reset": "snapshot",
    "poll": "host_other",
    "serve.run": "host_other",
    "host_gap": "idle",
}
CHECK_PROGRAMS = ("decode", "prefill_chunk")   # must compile exactly once


def load_events(path: str) -> List[dict]:
    """Load a trace: Chrome JSON (``{"traceEvents": [...]}``) or the
    JSONL event log (one event object per line)."""
    with open(path) as f:
        text = f.read()
    try:
        data = json.loads(text)
    except json.JSONDecodeError:   # one object per line
        return [json.loads(line) for line in text.splitlines() if line.strip()]
    return data["traceEvents"] if isinstance(data, dict) else data


def _spans(events: List[dict], tid: Optional[int] = None) -> List[dict]:
    return [e for e in events if e.get("ph") == "X" and
            (tid is None or e.get("tid") == tid)]


def self_times_s(events: List[dict]) -> Dict[str, float]:
    """Per-name self time (seconds) of the engine+host tracks' spans.

    Both tracks come from one Python thread of synchronous context
    managers, so their spans are properly nested (``host_gap`` covers
    exactly the time between two ``poll`` spans, inside any enclosing
    ``serve.run``); a stack walk over the merged tracks subtracts each
    span's duration from its enclosing span's self time."""
    spans = sorted((e for e in events if e.get("ph") == "X" and
                    e.get("tid") in (TID_ENGINE, TID_HOST)),
                   key=lambda e: (e["ts"], -e["dur"]))
    out: Dict[str, float] = defaultdict(float)
    stack: List[dict] = []
    for ev in spans:
        while stack and ev["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - 1e-9:
            stack.pop()
        out[ev["name"]] += ev["dur"] / 1e6
        if stack:
            out[stack[-1]["name"]] -= ev["dur"] / 1e6
        stack.append(ev)
    return dict(out)


def wall_extent_s(events: List[dict]) -> float:
    """Trace wall: extent of the engine+host tracks' complete events."""
    spans = [e for e in events if e.get("ph") == "X" and
             e.get("tid") in (TID_ENGINE, TID_HOST)]
    if not spans:
        return 0.0
    t0 = min(e["ts"] for e in spans)
    t1 = max(e["ts"] + e["dur"] for e in spans)
    return (t1 - t0) / 1e6


def phase_breakdown(events: List[dict]) -> Dict[str, Any]:
    """Phase -> seconds, plus the reconciliation against wall extent."""
    selfs = self_times_s(events)
    phases: Dict[str, float] = defaultdict(float)
    for name, s in selfs.items():
        phases[PHASE_OF.get(name, "host_other")] += s
    wall = wall_extent_s(events)
    total = sum(phases.values())
    return {
        "wall_s": round(wall, 6),
        "phase_total_s": round(total, 6),
        # total / wall: 1.0 = every microsecond attributed to a phase.
        "coverage": round(total / wall, 4) if wall else 0.0,
        "phases_s": {k: round(v, 6) for k, v in sorted(phases.items())},
        "phases_frac": {k: round(v / wall, 4) if wall else 0.0
                        for k, v in sorted(phases.items())},
    }


def request_table(events: List[dict]) -> List[Dict[str, Any]]:
    """Per-request segments: queue wait, staging (prefill), decode
    residency, end-to-end — from the queue/slot-track spans."""
    rows: Dict[int, Dict[str, Any]] = {}

    def row(uid: int) -> Dict[str, Any]:
        return rows.setdefault(uid, {"uid": uid, "arrival_us": None,
                                     "queue_s": 0.0, "staging_s": 0.0,
                                     "decode_s": 0.0, "tokens": None})

    for ev in events:
        uid = (ev.get("args") or {}).get("uid")
        if uid is None:
            continue
        if ev.get("ph") == "X":
            dur = ev["dur"] / 1e6
            if ev["name"] == "queue":
                r = row(uid)
                r["queue_s"] += dur
                r["arrival_us"] = ev["ts"]
            elif ev["name"] == "staging":
                r = row(uid)
                r["staging_s"] += dur
                r["slot"] = ev["tid"] - TID_SLOT0
            elif ev["name"] == "decode":
                row(uid)["decode_s"] += dur
        elif ev.get("ph") == "i" and ev["name"] == "finish":
            r = row(uid)
            r["tokens"] = ev["args"].get("tokens")
            r["latency_s"] = ev["args"].get("latency_s")
    out = list(rows.values())
    out.sort(key=lambda r: (r["arrival_us"] is None, r["arrival_us"],
                            r["uid"]))
    return out


def ttft_decomposition(table: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Queueing vs prefill share of TTFT across requests.  First tokens
    are sampled from the final prefill chunk's logits, so the first
    decode step's share is 0 by construction (reported explicitly)."""
    qs = [r["queue_s"] for r in table if r["staging_s"] > 0]
    ss = [r["staging_s"] for r in table if r["staging_s"] > 0]
    if not qs:
        return {"requests": 0}
    ttfts = [a + b for a, b in zip(qs, ss)]
    tot = sum(ttfts)
    return {
        "requests": len(qs),
        "ttft_mean_s": round(sum(ttfts) / len(ttfts), 6),
        "ttft_p95_s": round(_percentile(ttfts, 0.95), 6),
        "queue_mean_s": round(sum(qs) / len(qs), 6),
        "prefill_mean_s": round(sum(ss) / len(ss), 6),
        "queue_frac": round(sum(qs) / tot, 4) if tot else 0.0,
        "prefill_frac": round(sum(ss) / tot, 4) if tot else 0.0,
        "first_decode_frac": 0.0,
    }


def slot_utilization(events: List[dict]) -> Dict[str, Any]:
    wall = wall_extent_s(events)
    busy: Dict[int, Dict[str, float]] = defaultdict(
        lambda: {"staging_s": 0.0, "decode_s": 0.0})
    for ev in _spans(events):
        if ev["tid"] >= TID_SLOT0 and ev["name"] in ("staging", "decode"):
            busy[ev["tid"] - TID_SLOT0][ev["name"] + "_s"] += ev["dur"] / 1e6
    slots = {}
    for slot, b in sorted(busy.items()):
        total = b["staging_s"] + b["decode_s"]
        slots[str(slot)] = {
            "staging_s": round(b["staging_s"], 6),
            "decode_s": round(b["decode_s"], 6),
            "busy_frac": round(total / wall, 4) if wall else 0.0,
        }
    return {"wall_s": round(wall, 6), "slots": slots}


def recompile_trips(events: List[dict]) -> Dict[str, int]:
    trips: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "recompile":
            trips[ev["args"].get("program", "?")] += \
                ev["args"].get("new_traces", 1)
    return dict(trips)


def snapshots(events: List[dict]) -> List[dict]:
    return [ev["args"] for ev in events
            if ev.get("ph") == "i" and ev.get("name") == "metrics_snapshot"]


# Robustness instants (docs/robustness.md) the report tallies.  All are
# zero-duration, so their presence never perturbs the phase-coverage
# reconciliation --check asserts.
FAULT_EVENTS = ("quarantine", "backend_fallback", "overload_enter",
                "overload_exit", "watchdog_hang", "watchdog_recover",
                "reject", "shed", "retry", "snapshot_poison_refused")


def fault_events(events: List[dict]) -> Dict[str, int]:
    """Tally of fault-tolerance instants in the trace (quarantines,
    fallbacks, overload transitions, sheds...)."""
    out: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") in FAULT_EVENTS:
            out[ev["name"]] += 1
    return dict(out)


def analyze(events: List[dict]) -> Dict[str, Any]:
    table = request_table(events)
    return {
        "phase_breakdown": phase_breakdown(events),
        "ttft_decomposition": ttft_decomposition(table),
        "requests": table,
        "slot_utilization": slot_utilization(events),
        "recompile_trips": recompile_trips(events),
        "fault_events": fault_events(events),
        "metrics_snapshots": len(snapshots(events)),
    }


# ---------------------------------------------------------------------------
def _fmt_s(s: float) -> str:
    return f"{s * 1e3:9.2f} ms"


def print_report(rep: Dict[str, Any], max_requests: int = 20) -> None:
    pb = rep["phase_breakdown"]
    print("== per-phase wall breakdown ==")
    for phase, s in sorted(pb["phases_s"].items(), key=lambda kv: -kv[1]):
        print(f"  {phase:<11s} {_fmt_s(s)}  {pb['phases_frac'][phase]:6.1%}")
    print(f"  {'total':<11s} {_fmt_s(pb['phase_total_s'])}  vs wall "
          f"{_fmt_s(pb['wall_s'])}  (coverage {pb['coverage']:.1%})")

    td = rep["ttft_decomposition"]
    if td.get("requests"):
        print("\n== TTFT decomposition ==")
        print(f"  requests {td['requests']}   mean "
              f"{_fmt_s(td['ttft_mean_s'])}   p95 {_fmt_s(td['ttft_p95_s'])}")
        print(f"  queueing {td['queue_frac']:6.1%}   prefill "
              f"{td['prefill_frac']:6.1%}   first decode step "
              f"{td['first_decode_frac']:.1%} (first token comes from the "
              "final prefill chunk)")

    table = rep["requests"]
    if table:
        print(f"\n== queue-time waterfall (first {max_requests} "
              "by arrival) ==")
        print(f"  {'uid':>5s} {'queue':>10s} {'prefill':>10s} "
              f"{'decode':>10s} {'tokens':>6s}")
        for r in table[:max_requests]:
            print(f"  {r['uid']:5d} {r['queue_s'] * 1e3:8.2f}ms "
                  f"{r['staging_s'] * 1e3:8.2f}ms "
                  f"{r['decode_s'] * 1e3:8.2f}ms "
                  f"{r['tokens'] if r['tokens'] is not None else '?':>6}")

    su = rep["slot_utilization"]
    if su["slots"]:
        print("\n== slot-timeline utilization ==")
        for slot, b in su["slots"].items():
            bar = "#" * int(round(b["busy_frac"] * 40))
            print(f"  slot {slot}: {b['busy_frac']:6.1%} busy "
                  f"(staging {b['staging_s'] * 1e3:7.1f} ms, decode "
                  f"{b['decode_s'] * 1e3:7.1f} ms) {bar}")

    trips = rep["recompile_trips"]
    print(f"\nrecompile trips: {trips or 'none'}   metrics snapshots: "
          f"{rep['metrics_snapshots']}")
    faults = rep.get("fault_events") or {}
    if faults:
        print("fault events: " + ", ".join(
            f"{k}={v}" for k, v in sorted(faults.items())))


def check(rep: Dict[str, Any], tolerance: float = 0.05) -> List[str]:
    """Validation gate for CI (``--check``): phase total reconciles with
    wall within ``tolerance`` and the compile-once programs never
    retraced after warmup."""
    problems = []
    pb = rep["phase_breakdown"]
    if pb["wall_s"] <= 0:
        problems.append("empty trace: no engine/host spans")
    elif abs(pb["coverage"] - 1.0) > tolerance:
        problems.append(
            f"phase total {pb['phase_total_s']:.4f}s does not reconcile "
            f"with wall {pb['wall_s']:.4f}s "
            f"(coverage {pb['coverage']:.1%}, tolerance {tolerance:.0%})")
    for prog in CHECK_PROGRAMS:
        n = rep["recompile_trips"].get(prog, 0)
        if n:
            problems.append(f"compile-once program {prog!r} retraced "
                            f"{n} time(s) after warmup")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fold a serve trace (Chrome JSON or JSONL) into phase "
                    "breakdowns, TTFT decomposition, and slot timelines.")
    ap.add_argument("trace", help="trace path from launch/serve --trace")
    ap.add_argument("--json", action="store_true",
                    help="print the full report as JSON instead of tables")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless phases reconcile with wall "
                         "(<=5%% drift) and decode/prefill_chunk never "
                         "retraced")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="--check reconciliation tolerance (default 0.05)")
    ap.add_argument("--max-requests", type=int, default=20,
                    help="waterfall rows to print")
    args = ap.parse_args(argv)

    rep = analyze(load_events(args.trace))
    if args.json:
        json.dump(rep, sys.stdout, indent=2)
        print()
    else:
        print_report(rep, max_requests=args.max_requests)
    if args.check:
        problems = check(rep, args.tolerance)
        for p in problems:
            print(f"CHECK FAILED: {p}", file=sys.stderr)
        if problems:
            return 1
        print(f"check OK: coverage {rep['phase_breakdown']['coverage']:.1%},"
              f" 0 post-warmup recompiles of {', '.join(CHECK_PROGRAMS)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
