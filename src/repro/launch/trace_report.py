"""Trace-report CLI: fold a serve trace into the answers we keep needing.

``launch/serve --trace PATH`` (or any engine with ``ServeConfig.trace``)
records the span taxonomy of ``serve/tracing.py``; this tool folds a
saved trace — Chrome JSON or the JSONL event log — into:

* **per-phase wall breakdown** — where every microsecond of wall went:
  decode / prefill / admission / snapshot moves / other host work / idle
  gaps.  Self-times are computed by interval nesting on the engine
  track (a span's children don't double-count), so the phase total must
  reconcile with the trace's wall extent — ``--check`` fails the run if
  coverage drifts more than 5%.
* **TTFT decomposition** — per request: queue wait (arrival ->
  admission) vs staging (admission -> first token, i.e. its prefill
  chunks and the waits between them).  First tokens come from the final
  prefill chunk's logits, so the first decode step contributes 0 by
  construction — the report says so rather than inventing a third bar.
* **queue-time waterfall** — per-request segment table ordered by
  arrival: who waited, where.
* **slot-timeline utilization** — staging/decode busy fraction per slot.
* **recompile sentinel audit** — any ``recompile`` instant in the trace
  is a post-warmup retrace; ``--check`` asserts the compile-once
  programs (decode, prefill_chunk) never tripped.

    python -m repro.launch.trace_report serve_trace.json [--json] [--check]

``benchmarks/bench_serve_continuous.bench_phase`` uses the same
``analyze()`` to produce BENCH_serve.json's ``phase_breakdown`` block.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional

from repro.serve.metrics import _percentile
from repro.serve.tracing import TID_ENGINE, TID_HOST, TID_QUEUE, TID_SLOT0

# Leaf span name -> report phase.  Container spans ("poll", "serve.run",
# "admit") contribute their *self* time: "admit" self-time is admission
# bookkeeping outside the prefix lookup / snapshot restore nested in it.
PHASE_OF = {
    "decode_step": "decode",
    "prefill_chunk": "prefill",
    "prefill_bucket": "prefill",
    "admit": "admission",
    "prefix_lookup": "admission",
    "snapshot_restore": "snapshot",
    "snapshot_export": "snapshot",
    "pool_insert": "snapshot",
    "pool_reset": "snapshot",
    "poll": "host_other",
    "serve.run": "host_other",
    "host_gap": "idle",
}
CHECK_PROGRAMS = ("decode", "prefill_chunk")   # must compile exactly once

# Engine-track span name -> the compiled program it times, for the
# program-level breakdown.  Engine spans carry the registry's program id
# in ``args.program`` (``serve/program_registry.py``); this map covers
# the pool spans, whose names already identify the compiled row op
# (``snapshot_restore``/``snapshot_export`` run the same compiled
# scatter/gather as slot turnover — model.import_state/export_state).
# These spans never nest in one another, so full durations sum cleanly.
PROGRAM_OF_SPAN = {
    "decode_step": "decode",
    "draft": "draft",
    "verify": "verify",
    "prefill_chunk": "prefill_chunk",
    "prefill_bucket": "prefill",
    "pool_insert": "pool_insert",
    "pool_reset": "pool_reset",
    "snapshot_export": "pool_extract",
    "snapshot_restore": "pool_insert",
}


def load_events(path: str) -> List[dict]:
    """Load a trace: Chrome JSON (``{"traceEvents": [...]}``) or the
    JSONL event log (one event object per line)."""
    with open(path) as f:
        text = f.read()
    try:
        data = json.loads(text)
    except json.JSONDecodeError:   # one object per line
        return [json.loads(line) for line in text.splitlines() if line.strip()]
    return data["traceEvents"] if isinstance(data, dict) else data


def _spans(events: List[dict], tid: Optional[int] = None) -> List[dict]:
    return [e for e in events if e.get("ph") == "X" and
            (tid is None or e.get("tid") == tid)]


def self_times_s(events: List[dict]) -> Dict[str, float]:
    """Per-name self time (seconds) of the engine+host tracks' spans.

    Both tracks come from one Python thread of synchronous context
    managers, so their spans are properly nested (``host_gap`` covers
    exactly the time between two ``poll`` spans, inside any enclosing
    ``serve.run``); a stack walk over the merged tracks subtracts each
    span's duration from its enclosing span's self time."""
    spans = sorted((e for e in events if e.get("ph") == "X" and
                    e.get("tid") in (TID_ENGINE, TID_HOST)),
                   key=lambda e: (e["ts"], -e["dur"]))
    out: Dict[str, float] = defaultdict(float)
    stack: List[dict] = []
    for ev in spans:
        while stack and ev["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - 1e-9:
            stack.pop()
        out[ev["name"]] += ev["dur"] / 1e6
        if stack:
            out[stack[-1]["name"]] -= ev["dur"] / 1e6
        stack.append(ev)
    return dict(out)


def wall_extent_s(events: List[dict]) -> float:
    """Trace wall: extent of the engine+host tracks' complete events."""
    spans = [e for e in events if e.get("ph") == "X" and
             e.get("tid") in (TID_ENGINE, TID_HOST)]
    if not spans:
        return 0.0
    t0 = min(e["ts"] for e in spans)
    t1 = max(e["ts"] + e["dur"] for e in spans)
    return (t1 - t0) / 1e6


def phase_breakdown(events: List[dict]) -> Dict[str, Any]:
    """Phase -> seconds, plus the reconciliation against wall extent."""
    selfs = self_times_s(events)
    phases: Dict[str, float] = defaultdict(float)
    for name, s in selfs.items():
        phases[PHASE_OF.get(name, "host_other")] += s
    wall = wall_extent_s(events)
    total = sum(phases.values())
    return {
        "wall_s": round(wall, 6),
        "phase_total_s": round(total, 6),
        # total / wall: 1.0 = every microsecond attributed to a phase.
        "coverage": round(total / wall, 4) if wall else 0.0,
        "phases_s": {k: round(v, 6) for k, v in sorted(phases.items())},
        "phases_frac": {k: round(v / wall, 4) if wall else 0.0
                        for k, v in sorted(phases.items())},
    }


def program_breakdown(events: List[dict],
                      cards: Optional[Dict[str, Any]] = None
                      ) -> Dict[str, Any]:
    """Wall attribution per *compiled program*: wall, calls, tokens/s —
    plus achieved-vs-roofline utilization when program cards are given.

    Program spans never nest in each other (verified by the span
    taxonomy: pool spans nest only under host sections like ``admit`` /
    ``spec_copy``), so per-program wall is the plain sum of span
    durations.  ``_host`` (scheduling/self time of non-program spans)
    and ``_idle`` (host gaps) pseudo-rows come from the same interval
    -nesting self-times as ``phase_breakdown``, so the rows reconcile
    with the trace wall — ``coverage`` reports the ratio.

    ``cards`` maps program name -> card dict (or ``ProgramCard``); a
    program's ``utilization`` is its modeled best-case seconds per call
    (the binding roofline term) over the measured mean call — the
    fraction of the roofline the program actually achieves."""
    progs: Dict[str, Dict[str, Any]] = {}
    for ev in _spans(events, TID_ENGINE):
        name = ev["name"]
        if name not in PROGRAM_OF_SPAN:
            continue
        args = ev.get("args") or {}
        pid = args.get("program")
        prog = (pid.split(":", 1)[1] if isinstance(pid, str) and ":" in pid
                else PROGRAM_OF_SPAN[name])
        row = progs.setdefault(prog, {"id": None, "wall_s": 0.0,
                                      "calls": 0, "tokens": 0})
        if pid:
            row["id"] = pid
        row["wall_s"] += ev["dur"] / 1e6
        row["calls"] += 1
        row["tokens"] += int(args.get("tokens") or 0)

    wall = wall_extent_s(events)
    selfs = self_times_s(events)
    host = sum(s for name, s in selfs.items()
               if name not in PROGRAM_OF_SPAN and name != "host_gap")
    idle = selfs.get("host_gap", 0.0)

    def card_get(card, key):
        if card is None:
            return None
        if isinstance(card, dict):
            return card.get(key)
        return getattr(card, key, None)

    out_rows: Dict[str, Dict[str, Any]] = {}
    for prog, row in progs.items():
        r: Dict[str, Any] = {
            "id": row["id"],
            "wall_s": round(row["wall_s"], 6),
            "frac": round(row["wall_s"] / wall, 4) if wall else 0.0,
            "calls": row["calls"],
            "mean_call_ms": round(row["wall_s"] / row["calls"] * 1e3, 4)
            if row["calls"] else 0.0,
        }
        if row["tokens"]:
            r["tokens"] = row["tokens"]
            r["tokens_per_s"] = round(row["tokens"] / row["wall_s"], 2) \
                if row["wall_s"] else 0.0
        card = (cards or {}).get(prog)
        roof = card_get(card, "roofline_s")
        if roof and row["calls"]:
            mean_call_s = row["wall_s"] / row["calls"]
            r["roofline_s_per_call"] = roof
            r["utilization"] = round(roof / mean_call_s, 4) \
                if mean_call_s else 0.0
        out_rows[prog] = r

    program_total = sum(r["wall_s"] for r in out_rows.values())
    total = program_total + host + idle
    return {
        "wall_s": round(wall, 6),
        "program_total_s": round(program_total, 6),
        "coverage": round(total / wall, 4) if wall else 0.0,
        "programs": dict(sorted(out_rows.items(),
                                key=lambda kv: -kv[1]["wall_s"])),
        "_host_s": round(host, 6),
        "_idle_s": round(idle, 6),
    }


def request_table(events: List[dict]) -> List[Dict[str, Any]]:
    """Per-request segments: queue wait, staging (prefill), decode
    residency, end-to-end — from the queue/slot-track spans."""
    rows: Dict[int, Dict[str, Any]] = {}

    def row(uid: int) -> Dict[str, Any]:
        return rows.setdefault(uid, {"uid": uid, "arrival_us": None,
                                     "queue_s": 0.0, "staging_s": 0.0,
                                     "decode_s": 0.0, "tokens": None})

    for ev in events:
        uid = (ev.get("args") or {}).get("uid")
        if uid is None:
            continue
        if ev.get("ph") == "X":
            dur = ev["dur"] / 1e6
            if ev["name"] == "queue":
                r = row(uid)
                r["queue_s"] += dur
                r["arrival_us"] = ev["ts"]
            elif ev["name"] == "staging":
                r = row(uid)
                r["staging_s"] += dur
                r["slot"] = ev["tid"] - TID_SLOT0
            elif ev["name"] == "decode":
                row(uid)["decode_s"] += dur
        elif ev.get("ph") == "i" and ev["name"] == "finish":
            r = row(uid)
            r["tokens"] = ev["args"].get("tokens")
            r["latency_s"] = ev["args"].get("latency_s")
    out = list(rows.values())
    out.sort(key=lambda r: (r["arrival_us"] is None, r["arrival_us"],
                            r["uid"]))
    return out


def ttft_decomposition(table: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Queueing vs prefill share of TTFT across requests.  First tokens
    are sampled from the final prefill chunk's logits, so the first
    decode step's share is 0 by construction (reported explicitly)."""
    qs = [r["queue_s"] for r in table if r["staging_s"] > 0]
    ss = [r["staging_s"] for r in table if r["staging_s"] > 0]
    if not qs:
        return {"requests": 0}
    ttfts = [a + b for a, b in zip(qs, ss)]
    tot = sum(ttfts)
    return {
        "requests": len(qs),
        "ttft_mean_s": round(sum(ttfts) / len(ttfts), 6),
        "ttft_p95_s": round(_percentile(ttfts, 0.95), 6),
        "queue_mean_s": round(sum(qs) / len(qs), 6),
        "prefill_mean_s": round(sum(ss) / len(ss), 6),
        "queue_frac": round(sum(qs) / tot, 4) if tot else 0.0,
        "prefill_frac": round(sum(ss) / tot, 4) if tot else 0.0,
        "first_decode_frac": 0.0,
    }


def slot_utilization(events: List[dict]) -> Dict[str, Any]:
    wall = wall_extent_s(events)
    busy: Dict[int, Dict[str, float]] = defaultdict(
        lambda: {"staging_s": 0.0, "decode_s": 0.0})
    for ev in _spans(events):
        if ev["tid"] >= TID_SLOT0 and ev["name"] in ("staging", "decode"):
            busy[ev["tid"] - TID_SLOT0][ev["name"] + "_s"] += ev["dur"] / 1e6
    slots = {}
    for slot, b in sorted(busy.items()):
        total = b["staging_s"] + b["decode_s"]
        slots[str(slot)] = {
            "staging_s": round(b["staging_s"], 6),
            "decode_s": round(b["decode_s"], 6),
            "busy_frac": round(total / wall, 4) if wall else 0.0,
        }
    return {"wall_s": round(wall, 6), "slots": slots}


def recompile_trips(events: List[dict]) -> Dict[str, int]:
    trips: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "recompile":
            trips[ev["args"].get("program", "?")] += \
                ev["args"].get("new_traces", 1)
    return dict(trips)


def recompile_audit(events: List[dict]) -> Dict[str, Any]:
    """Trips per program plus the registry program id each sentinel
    carried (``serve/program_registry.py``), so the audit names the
    offending compiled program, not just a sentinel label."""
    trips: Dict[str, int] = defaultdict(int)
    ids: Dict[str, str] = {}
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "recompile":
            prog = ev["args"].get("program", "?")
            trips[prog] += ev["args"].get("new_traces", 1)
            pid = ev["args"].get("program_id")
            if pid:
                ids[prog] = pid
    return {"trips": dict(trips), "program_ids": ids}


def snapshots(events: List[dict]) -> List[dict]:
    return [ev["args"] for ev in events
            if ev.get("ph") == "i" and ev.get("name") == "metrics_snapshot"]


# Robustness instants (docs/robustness.md) the report tallies.  All are
# zero-duration, so their presence never perturbs the phase-coverage
# reconciliation --check asserts.
FAULT_EVENTS = ("quarantine", "backend_fallback", "overload_enter",
                "overload_exit", "watchdog_hang", "watchdog_recover",
                "reject", "shed", "retry", "snapshot_poison_refused")


def fault_events(events: List[dict]) -> Dict[str, int]:
    """Tally of fault-tolerance instants in the trace (quarantines,
    fallbacks, overload transitions, sheds...)."""
    out: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") in FAULT_EVENTS:
            out[ev["name"]] += 1
    return dict(out)


def analyze(events: List[dict],
            cards: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    table = request_table(events)
    audit = recompile_audit(events)
    return {
        "phase_breakdown": phase_breakdown(events),
        "program_breakdown": program_breakdown(events, cards),
        "ttft_decomposition": ttft_decomposition(table),
        "requests": table,
        "slot_utilization": slot_utilization(events),
        "recompile_trips": audit["trips"],
        "recompile_program_ids": audit["program_ids"],
        "fault_events": fault_events(events),
        "metrics_snapshots": len(snapshots(events)),
    }


# ---------------------------------------------------------------------------
def _fmt_s(s: float) -> str:
    return f"{s * 1e3:9.2f} ms"


def print_report(rep: Dict[str, Any], max_requests: int = 20) -> None:
    pb = rep["phase_breakdown"]
    print("== per-phase wall breakdown ==")
    for phase, s in sorted(pb["phases_s"].items(), key=lambda kv: -kv[1]):
        print(f"  {phase:<11s} {_fmt_s(s)}  {pb['phases_frac'][phase]:6.1%}")
    print(f"  {'total':<11s} {_fmt_s(pb['phase_total_s'])}  vs wall "
          f"{_fmt_s(pb['wall_s'])}  (coverage {pb['coverage']:.1%})")

    prb = rep.get("program_breakdown") or {}
    if prb.get("programs"):
        print("\n== per-program wall breakdown ==")
        for prog, r in prb["programs"].items():
            extra = ""
            if r.get("tokens_per_s") is not None:
                extra += f"  {r['tokens_per_s']:10.1f} tok/s"
            if r.get("utilization") is not None:
                extra += f"  {r['utilization']:6.1%} of roofline"
            label = f"{prog} ({r['id']})" if r.get("id") else prog
            print(f"  {label:<24s} {_fmt_s(r['wall_s'])}  {r['frac']:6.1%}"
                  f"  x{r['calls']:<5d}{extra}")
        print(f"  {'(host)':<24s} {_fmt_s(prb['_host_s'])}")
        print(f"  {'(idle)':<24s} {_fmt_s(prb['_idle_s'])}")
        print(f"  total {_fmt_s(prb['program_total_s'])} in programs vs "
              f"wall {_fmt_s(prb['wall_s'])} "
              f"(coverage {prb['coverage']:.1%})")

    td = rep["ttft_decomposition"]
    if td.get("requests"):
        print("\n== TTFT decomposition ==")
        print(f"  requests {td['requests']}   mean "
              f"{_fmt_s(td['ttft_mean_s'])}   p95 {_fmt_s(td['ttft_p95_s'])}")
        print(f"  queueing {td['queue_frac']:6.1%}   prefill "
              f"{td['prefill_frac']:6.1%}   first decode step "
              f"{td['first_decode_frac']:.1%} (first token comes from the "
              "final prefill chunk)")

    table = rep["requests"]
    if table:
        print(f"\n== queue-time waterfall (first {max_requests} "
              "by arrival) ==")
        print(f"  {'uid':>5s} {'queue':>10s} {'prefill':>10s} "
              f"{'decode':>10s} {'tokens':>6s}")
        for r in table[:max_requests]:
            print(f"  {r['uid']:5d} {r['queue_s'] * 1e3:8.2f}ms "
                  f"{r['staging_s'] * 1e3:8.2f}ms "
                  f"{r['decode_s'] * 1e3:8.2f}ms "
                  f"{r['tokens'] if r['tokens'] is not None else '?':>6}")

    su = rep["slot_utilization"]
    if su["slots"]:
        print("\n== slot-timeline utilization ==")
        for slot, b in su["slots"].items():
            bar = "#" * int(round(b["busy_frac"] * 40))
            print(f"  slot {slot}: {b['busy_frac']:6.1%} busy "
                  f"(staging {b['staging_s'] * 1e3:7.1f} ms, decode "
                  f"{b['decode_s'] * 1e3:7.1f} ms) {bar}")

    trips = rep["recompile_trips"]
    ids = rep.get("recompile_program_ids") or {}
    shown = ({f"{k} ({ids[k]})" if k in ids else k: v
              for k, v in trips.items()} if trips else None)
    print(f"\nrecompile trips: {shown or 'none'}   metrics snapshots: "
          f"{rep['metrics_snapshots']}")
    faults = rep.get("fault_events") or {}
    if faults:
        print("fault events: " + ", ".join(
            f"{k}={v}" for k, v in sorted(faults.items())))


def check(rep: Dict[str, Any], tolerance: float = 0.05) -> List[str]:
    """Validation gate for CI (``--check``): phase total reconciles with
    wall within ``tolerance`` and the compile-once programs never
    retraced after warmup."""
    problems = []
    pb = rep["phase_breakdown"]
    if pb["wall_s"] <= 0:
        problems.append("empty trace: no engine/host spans")
    elif abs(pb["coverage"] - 1.0) > tolerance:
        problems.append(
            f"phase total {pb['phase_total_s']:.4f}s does not reconcile "
            f"with wall {pb['wall_s']:.4f}s "
            f"(coverage {pb['coverage']:.1%}, tolerance {tolerance:.0%})")
    ids = rep.get("recompile_program_ids") or {}
    for prog in CHECK_PROGRAMS:
        n = rep["recompile_trips"].get(prog, 0)
        if n:
            label = f"{prog!r} ({ids[prog]})" if prog in ids else repr(prog)
            problems.append(f"compile-once program {label} retraced "
                            f"{n} time(s) after warmup")
    return problems


def print_flight(dumps: List[dict]) -> None:
    """Human-facing render of flight-recorder dumps
    (``serve/flight_recorder.py`` JSONL: header + fault + ring)."""
    if not dumps:
        print("no flight dumps in file")
        return
    for d in dumps:
        h = d["header"]
        fault = d.get("fault") or {}
        facts = ", ".join(f"{k}={v}" for k, v in sorted(fault.items())
                          if k != "kind")
        print(f"== flight dump {h.get('flight_dump')} — "
              f"{h.get('kind', '?')}" + (f" ({facts})" if facts else "") +
              f" — last {h.get('entries', 0)} of "
              f"{h.get('recorded_total', '?')} request(s) ==")
        if d["requests"]:
            print(f"  {'uid':>5s} {'status':<16s} {'slot':>4s} "
                  f"{'queue':>9s} {'staging':>9s} {'decode':>9s} "
                  f"{'tokens':>6s} {'retries':>7s}")

        def ms(x):
            return f"{x * 1e3:7.1f}ms" if x is not None else "        ?"

        for r in d["requests"]:
            print(f"  {r.get('uid', '?'):>5} {r.get('status', '?'):<16s} "
                  f"{r.get('slot') if r.get('slot') is not None else '?':>4} "
                  f"{ms(r.get('queue_s'))} {ms(r.get('staging_s'))} "
                  f"{ms(r.get('decode_s'))} {r.get('tokens', '?'):>6} "
                  f"{r.get('retries', 0):>7}")
        print()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fold a serve trace (Chrome JSON or JSONL) into phase "
                    "breakdowns, TTFT decomposition, and slot timelines.")
    ap.add_argument("trace", nargs="?",
                    help="trace path from launch/serve --trace")
    ap.add_argument("--json", action="store_true",
                    help="print the full report as JSON instead of tables")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless phases reconcile with wall "
                         "(<=5%% drift) and decode/prefill_chunk never "
                         "retraced")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="--check reconciliation tolerance (default 0.05)")
    ap.add_argument("--max-requests", type=int, default=20,
                    help="waterfall rows to print")
    ap.add_argument("--cards", metavar="PATH",
                    help="program-card JSON (name -> card dict, e.g. from "
                         "hlo_analysis --dump or a BENCH program_cards "
                         "block) to fold roofline utilization into the "
                         "program breakdown")
    ap.add_argument("--flight", metavar="PATH",
                    help="render a flight-recorder JSONL dump "
                         "(launch/serve --flight-path) instead of a trace")
    args = ap.parse_args(argv)

    if args.flight:
        from repro.serve.flight_recorder import load_flight
        dumps = load_flight(args.flight)
        if args.json:
            json.dump(dumps, sys.stdout, indent=2)
            print()
        else:
            print_flight(dumps)
        # --check semantics for flight mode: the file must contain at
        # least one well-formed dump.
        if args.check and not dumps:
            print("CHECK FAILED: no flight dumps parsed", file=sys.stderr)
            return 1
        return 0
    if not args.trace:
        ap.error("trace path required (or use --flight PATH)")

    cards = None
    if args.cards:
        with open(args.cards) as f:
            cards = json.load(f)
    rep = analyze(load_events(args.trace), cards=cards)
    if args.json:
        json.dump(rep, sys.stdout, indent=2)
        print()
    else:
        print_report(rep, max_requests=args.max_requests)
    if args.check:
        problems = check(rep, args.tolerance)
        for p in problems:
            print(f"CHECK FAILED: {p}", file=sys.stderr)
        if problems:
            return 1
        print(f"check OK: coverage {rep['phase_breakdown']['coverage']:.1%},"
              f" 0 post-warmup recompiles of {', '.join(CHECK_PROGRAMS)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
