"""Training driver: ``python -m repro.launch.train --arch mamba2-130m ...``

Wires every substrate together: config registry -> model -> sharded state ->
synthetic data pipeline -> jitted train step -> health monitor -> async
atomic checkpoints -> restart loop.  On this CPU box use ``--reduced``
(small config) or the defaults compile forever; on a real pod point
``--mesh`` at the production mesh.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, ckpt
from repro.configs import get_config
from repro.data import DataConfig, PrefetchIterator, SyntheticLM
from repro.distributed import api as dist_api
from repro.distributed.sharding import make_shardings
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.nn.params import init_params
from repro.optim import AdamWConfig, ScheduleConfig
from repro.runtime import StepMonitor
from repro.train import TrainConfig, make_train_step

log = logging.getLogger("repro.train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="", help="e.g. 2x2:data,model")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    data = SyntheticLM(dcfg)

    train_cfg = TrainConfig(
        optimizer=AdamWConfig(),
        schedule=ScheduleConfig(base_lr=args.lr, warmup_steps=args.warmup,
                                total_steps=max(args.steps, 2)),
        microbatches=args.microbatches)

    mesh = None
    if args.mesh:
        shape_s, axes_s = args.mesh.split(":")
        mesh = make_mesh([int(x) for x in shape_s.split("x")],
                         axes_s.split(","))

    rng = jax.random.PRNGKey(args.seed)
    params = init_params(model.param_specs(), rng, cfg.dtype)
    from repro.optim import adamw
    state = {"params": params, "opt": adamw.init(params, train_cfg.optimizer)}

    step_fn = make_train_step(model, train_cfg, mesh)
    start_step = 0
    ckptr = None
    if args.ckpt_dir:
        ckptr = AsyncCheckpointer(args.ckpt_dir)
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state, start_step, extra = ckpt.restore(args.ckpt_dir, state)
            data = SyntheticLM(dcfg, start_step=extra.get("data_step",
                                                          start_step))
            log.info("resumed from step %d", start_step)

    if mesh is not None:
        sh, report = make_shardings(model.param_specs(), mesh)
        log.info("sharding: %s", report.summary())
        state["params"] = jax.tree.map(jax.device_put, state["params"], sh)
        state["opt"]["m"] = jax.tree.map(jax.device_put, state["opt"]["m"], sh)
        state["opt"]["v"] = jax.tree.map(jax.device_put, state["opt"]["v"], sh)
        jitted = jax.jit(step_fn)
    else:
        jitted = jax.jit(step_fn)

    monitor = StepMonitor()
    it = PrefetchIterator(iter(data))

    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.time()
            state, metrics = jitted(state, batch)
            metrics = jax.tree.map(float, jax.device_get(metrics))
            rec = monitor.observe(step, time.time() - t0)
            if step % args.log_every == 0 or step == args.steps - 1:
                log.info("step %4d  loss %.4f  acc %.3f  gnorm %.2f  "
                         "%.2fs%s", step, metrics["loss"],
                         metrics["accuracy"], metrics["grad_norm"],
                         rec.seconds, "  [straggler]" if rec.straggler else "")
            if ckptr and (step + 1) % args.ckpt_every == 0:
                ckptr.save(step + 1, state, {"data_step": data.step})
    if ckptr:
        ckptr.save(args.steps, state, {"data_step": data.step})
        ckptr.wait()
    log.info("done: %s", monitor.summary())
    return state, monitor


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
