"""Compiled-HLO analysis: collective byte accounting + roofline terms.

``compiled.cost_analysis()`` has no collective traffic, so we parse the
partitioned module text: build an instruction -> shape table from every
definition line, then sum *operand* bytes for each collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
including the ``-start`` async forms.  Shapes in the partitioned module are
per-device shards, so totals here are per-device — consistent with
cost_analysis' per-device FLOPs/bytes (verified in the de-risk pass; see
DESIGN.md §7).

Two collective figures are reported:
  * ``operand_bytes``  — the prompt's definition (sum of operand sizes);
  * ``wire_bytes``     — ring-algorithm modeled bytes actually serialized per
    device: AR 2(n-1)/n, AG (n-1)x operand, RS (n-1)/n, A2A (n-1)/n, CP 1x.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (simple one-link model)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\])")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype, 4)
    if dims.strip():
        for d in dims.split(","):
            size *= int(d)
    return size


def _tuple_bytes(inner: str) -> int:
    """'(f32[8,4]{...}, u32[]...)' contents -> total bytes."""
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", inner):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    operand_bytes: Dict[str, int]
    wire_bytes: Dict[str, int]
    details: List[dict]

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    shapes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name = m.group(1)
            if m.group(2) is not None:        # tuple shape
                shapes[name] = _tuple_bytes(m.group(2))
            else:
                shapes[name] = _shape_bytes(m.group(3), m.group(4))

    counts: Dict[str, int] = {}
    op_bytes: Dict[str, int] = {}
    wire: Dict[str, int] = {}
    details: List[dict] = []
    for line in hlo_text.splitlines():
        cm = _COLL_RE.search(line)
        if not cm:
            continue
        op = cm.group(1)
        # operands: everything inside the first (...) after the opcode
        start = line.index(cm.group(0)) + len(cm.group(0))
        depth, end = 1, start
        while end < len(line) and depth:
            if line[end] == "(":
                depth += 1
            elif line[end] == ")":
                depth -= 1
            end += 1
        operand_names = _OPERAND_RE.findall(line[start:end - 1])
        b = sum(shapes.get(o, 0) for o in operand_names)

        n = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                n = len(gl.group(1).split(","))
        factor = {
            "all-reduce": 2 * (n - 1) / max(n, 1),
            "all-gather": (n - 1),
            "reduce-scatter": (n - 1) / max(n, 1),
            "all-to-all": (n - 1) / max(n, 1),
            "collective-permute": 1.0,
        }[op]
        counts[op] = counts.get(op, 0) + 1
        op_bytes[op] = op_bytes.get(op, 0) + b
        wire[op] = wire.get(op, 0) + int(b * factor)
        details.append({"op": op, "operand_bytes": b, "group_size": n})
    return CollectiveStats(counts, op_bytes, wire, details)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_operand_bytes: float,
                   collective_wire_bytes: float) -> dict:
    """Three roofline terms in seconds (per the assignment's formulas; all
    inputs are per-device, which equals global/chips)."""
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_operand_bytes / ICI_BW
    collective_wire_s = collective_wire_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s,
             "collective_wire_s": collective_wire_s}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom
    denom = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = compute_s / denom if denom else 0.0
    return terms
