"""Compiled-HLO analysis: collective byte accounting, roofline terms, and
per-op program fingerprints (``make hlo-diff``).

``compiled.cost_analysis()`` has no collective traffic, so we parse the
partitioned module text: build an instruction -> shape table from every
definition line, then sum *operand* bytes for each collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
including the ``-start`` async forms.  Shapes in the partitioned module are
per-device shards, so totals here are per-device — consistent with
cost_analysis' per-device FLOPs/bytes (verified in the de-risk pass; see
DESIGN.md §7).

Two collective figures are reported:
  * ``operand_bytes``  — the prompt's definition (sum of operand sizes);
  * ``wire_bytes``     — ring-algorithm modeled bytes actually serialized per
    device: AR 2(n-1)/n, AG (n-1)x operand, RS (n-1)/n, A2A (n-1)/n, CP 1x.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (simple one-link model)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\])")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype, 4)
    if dims.strip():
        for d in dims.split(","):
            size *= int(d)
    return size


def _tuple_bytes(inner: str) -> int:
    """'(f32[8,4]{...}, u32[]...)' contents -> total bytes."""
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", inner):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    operand_bytes: Dict[str, int]
    wire_bytes: Dict[str, int]
    details: List[dict]

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    shapes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name = m.group(1)
            if m.group(2) is not None:        # tuple shape
                shapes[name] = _tuple_bytes(m.group(2))
            else:
                shapes[name] = _shape_bytes(m.group(3), m.group(4))

    counts: Dict[str, int] = {}
    op_bytes: Dict[str, int] = {}
    wire: Dict[str, int] = {}
    details: List[dict] = []
    for line in hlo_text.splitlines():
        cm = _COLL_RE.search(line)
        if not cm:
            continue
        op = cm.group(1)
        # operands: everything inside the first (...) after the opcode
        start = line.index(cm.group(0)) + len(cm.group(0))
        depth, end = 1, start
        while end < len(line) and depth:
            if line[end] == "(":
                depth += 1
            elif line[end] == ")":
                depth -= 1
            end += 1
        operand_names = _OPERAND_RE.findall(line[start:end - 1])
        b = sum(shapes.get(o, 0) for o in operand_names)

        n = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                n = len(gl.group(1).split(","))
        factor = {
            "all-reduce": 2 * (n - 1) / max(n, 1),
            "all-gather": (n - 1),
            "reduce-scatter": (n - 1) / max(n, 1),
            "all-to-all": (n - 1) / max(n, 1),
            "collective-permute": 1.0,
        }[op]
        counts[op] = counts.get(op, 0) + 1
        op_bytes[op] = op_bytes.get(op, 0) + b
        wire[op] = wire.get(op, 0) + int(b * factor)
        details.append({"op": op, "operand_bytes": b, "group_size": n})
    return CollectiveStats(counts, op_bytes, wire, details)


# ----------------------------------------------------------------------------
# Per-op program fingerprints (the XLA-CPU layout-cliff diagnostic)
# ----------------------------------------------------------------------------
#
# ROADMAP open item: at 130M scale the fused single-token decode program
# regresses 1.7-2.6x depending on the decode-cache layout (scan-stacked vs
# per-layer) at IDENTICAL compiled flops/bytes.  The cost model cannot see
# it, so the first diagnostic is structural: histogram the compiled module
# per opcode (instruction count + defined bytes) and diff the two layouts.
# A program-quality cliff shows up as op-mix drift — fusion counts, copy /
# transpose insertions, concatenates — rather than byte deltas.

_OP_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*"
    r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z][a-z0-9\-]*)\(")


def op_fingerprint(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """``{opcode: {"count", "bytes"}}`` over every instruction definition;
    ``bytes`` sums each defining instruction's output shape (tuple shapes
    flattened).  Deterministic for a fixed compiled module, so two dumps
    diff cleanly."""
    out: Dict[str, Dict[str, int]] = {}
    for line in hlo_text.splitlines():
        om = _OP_DEF_RE.match(line)
        if not om:
            continue
        op = om.group(1)
        b = 0
        dm = _DEF_RE.match(line)
        if dm:
            if dm.group(2) is not None:
                b = _tuple_bytes(dm.group(2))
            else:
                b = _shape_bytes(dm.group(3), dm.group(4))
        slot = out.setdefault(op, {"count": 0, "bytes": 0})
        slot["count"] += 1
        slot["bytes"] += b
    return out


def fingerprint_diff(a: Dict[str, Dict[str, int]],
                     b: Dict[str, Dict[str, int]]) -> List[dict]:
    """Per-op rows where the two fingerprints disagree, biggest
    |count delta| first (count drift is the program-quality signal;
    byte-identical programs can still schedule very differently)."""
    rows = []
    for op in sorted(set(a) | set(b)):
        ca, cb = a.get(op, {"count": 0, "bytes": 0}), \
            b.get(op, {"count": 0, "bytes": 0})
        if ca == cb:
            continue
        rows.append({"op": op,
                     "count_a": ca["count"], "count_b": cb["count"],
                     "bytes_a": ca["bytes"], "bytes_b": cb["bytes"]})
    rows.sort(key=lambda r: (-abs(r["count_a"] - r["count_b"]),
                             -abs(r["bytes_a"] - r["bytes_b"])))
    return rows


def schedule_fingerprint(hlo_text: str) -> List[Tuple[str, int]]:
    """Ordered ``(opcode, output_bytes)`` sequence over every instruction
    definition in module order.  Post-optimization HLO prints computations
    in (approximate) schedule order, so two modules with identical op
    *counts* but different op *order* — the part ``op_fingerprint`` is
    blind to — diff cleanly here."""
    out: List[Tuple[str, int]] = []
    for line in hlo_text.splitlines():
        om = _OP_DEF_RE.match(line)
        if not om:
            continue
        b = 0
        dm = _DEF_RE.match(line)
        if dm:
            b = (_tuple_bytes(dm.group(2)) if dm.group(2) is not None
                 else _shape_bytes(dm.group(3), dm.group(4)))
        out.append((om.group(1), b))
    return out


def schedule_diff(a: List[Tuple[str, int]],
                  b: List[Tuple[str, int]]) -> dict:
    """Order-sensitive comparison of two schedule fingerprints.

    ``similarity`` is difflib's ratio over the opcode sequences;
    ``first_divergence`` is the instruction index where the op streams
    first disagree (with a few ops of context from each side); ``moved``
    summarizes the largest replaced/inserted/deleted blocks — runs of
    ops one schedule has where the other has something else, which is
    where copy/bitcast insertion and fusion-boundary drift show up even
    at identical op counts and bytes."""
    import difflib

    ops_a = [op for op, _ in a]
    ops_b = [op for op, _ in b]
    sm = difflib.SequenceMatcher(a=ops_a, b=ops_b, autojunk=False)
    first = next((i for i, (x, y) in enumerate(zip(ops_a, ops_b))
                  if x != y), min(len(ops_a), len(ops_b)))
    moved = []
    for tag, i1, i2, j1, j2 in sm.get_opcodes():
        if tag == "equal":
            continue
        moved.append({
            "tag": tag, "at_a": i1, "at_b": j1,
            "ops_a": ops_a[i1:i2][:6], "ops_b": ops_b[j1:j2][:6],
            "len_a": i2 - i1, "len_b": j2 - j1,
            "bytes_a": sum(x for _, x in a[i1:i2]),
            "bytes_b": sum(x for _, x in b[j1:j2]),
        })
    moved.sort(key=lambda r: -(r["len_a"] + r["len_b"]))
    return {
        "n_instructions_a": len(a),
        "n_instructions_b": len(b),
        "similarity": round(sm.ratio(), 4),
        "first_divergence": first,
        "context_a": ops_a[max(0, first - 2):first + 4],
        "context_b": ops_b[max(0, first - 2):first + 4],
        "n_diff_blocks": len(moved),
        "moved": moved[:12],
    }


def buffer_assignment_stats(compiled) -> dict:
    """Buffer-assignment sizes of a compiled executable (the memory side
    of program quality: two byte-identical op mixes can still assign very
    different temp/alias footprints).  Keys are bytes; absent fields on
    older jax report as None."""
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {"unavailable": True}
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes"):
        out[key] = getattr(ma, key, None)
    return out


def decode_step_compiled(arch: str, *, scan_layers: bool,
                         reduced: bool = False):
    """Compiled executable of one fused decode step for ``arch`` under the
    given decode-cache layout."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(arch, reduced=reduced).replace(
        param_dtype="float32", scan_layers=scan_layers)
    model = build_model(cfg)
    from repro.nn.params import init_params
    params = model.decode_view(
        init_params(model.param_specs(), jax.random.PRNGKey(0),
                    jnp.float32))
    cache = model.init_cache(1, 64, jnp.float32)
    tok = jnp.ones((1, 1), jnp.int32)
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c, jnp.int32(4)),
                   donate_argnums=(2,))
    return step.lower(params, tok, cache).compile()


# Serve programs the generalized analysis can lower (``--program``); the
# names match the serve engine's program registry
# (``serve/program_registry.py``), so ``make hlo-diff PROGRAM=...``
# speaks the same vocabulary as trace spans and program cards.
ANALYZABLE_PROGRAMS = ("decode", "prefill", "prefill_chunk", "verify_chunk")


def program_lowering(arch: str, program: str = "decode", *,
                     scan_layers: bool, reduced: bool = False,
                     slots: int = 1, max_seq: int = 64, bucket: int = 32,
                     chunk: int = 8, k: int = 4):
    """``(jitted fn, example_args, model cfg)`` for any analyzable serve
    program of ``arch`` under the given decode-cache layout, at the same
    shape discipline the continuous engine serves with (per-row offset
    vectors; the decode/chunk/verify cache is donated).

    ``fn.lower(*example_args).compile()`` is the compiled executable —
    :func:`program_compiled` does exactly that, and
    ``serve/program_registry.build_card`` turns the same pair into a
    program card (``--check-budgets``)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model
    from repro.nn.params import init_params

    cfg = get_config(arch, reduced=reduced).replace(
        param_dtype="float32", scan_layers=scan_layers)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         jnp.float32)
    cache = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        model.init_cache(slots, max_seq, jnp.float32))
    pos = jax.ShapeDtypeStruct((slots,), jnp.int32)
    if program == "decode":
        dparams = model.decode_view(params)
        fn = jax.jit(
            lambda p, t, c, i: model.decode_step(p, t, c, i),
            donate_argnums=(2,))
        ex = (dparams, jax.ShapeDtypeStruct((slots, 1), jnp.int32),
              cache, pos)
    elif program == "prefill":
        fn = jax.jit(lambda p, b, c: model.prefill(p, b, c))
        ex = (params,
              {"tokens": jax.ShapeDtypeStruct((slots, bucket), jnp.int32)},
              cache)
    elif program == "prefill_chunk":
        fn = jax.jit(
            lambda p, t, c, o: model.prefill_chunk(p, t, c, o),
            donate_argnums=(2,))
        ex = (params, jax.ShapeDtypeStruct((slots, chunk), jnp.int32),
              cache, pos)
    elif program in ("verify_chunk", "verify"):
        fn = jax.jit(
            lambda p, t, c, o: model.verify_chunk(p, t, c, o),
            donate_argnums=(2,))
        ex = (params, jax.ShapeDtypeStruct((slots, k), jnp.int32),
              cache, pos)
    else:
        raise ValueError(
            f"unknown program {program!r}; analyzable: "
            f"{', '.join(ANALYZABLE_PROGRAMS)}")
    return fn, ex, cfg


def program_compiled(arch: str, program: str = "decode", *,
                     scan_layers: bool, reduced: bool = False, **shapes):
    """Compiled executable of any analyzable serve program (see
    :func:`program_lowering`)."""
    fn, ex, _ = program_lowering(arch, program, scan_layers=scan_layers,
                                 reduced=reduced, **shapes)
    return fn.lower(*ex).compile()


def decode_step_hlo(arch: str, *, scan_layers: bool,
                    reduced: bool = False) -> str:
    """Compiled (post-optimization) HLO text of one fused decode step for
    ``arch`` under the given decode-cache layout."""
    return decode_step_compiled(arch, scan_layers=scan_layers,
                                reduced=reduced).as_text()


def main(argv=None):
    """``python -m repro.launch.hlo_analysis --arch mamba2-130m``: dump
    the per-op fingerprint of the fused decode step under BOTH cache
    layouts and print the diff — the concrete first step on the layout
    -cliff open item (``make hlo-diff``).  ``--schedule`` adds the
    order-sensitive view: op-schedule divergence + buffer-assignment
    sizes (two programs with near-identical op mixes can still schedule
    and assign very differently — that is exactly what the cost model
    cannot see)."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--program", default="decode",
                    choices=ANALYZABLE_PROGRAMS,
                    help="which serve program to lower and diff "
                         "(registry names; default: decode)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (fast; the cliff itself only "
                         "shows at full size)")
    ap.add_argument("--schedule", action="store_true",
                    help="also diff op ORDER (schedule fingerprint) and "
                         "buffer-assignment sizes, not just op counts")
    ap.add_argument("--check-budgets", action="store_true",
                    help="build the program card under BOTH layouts and "
                         "check it against the registry quality budget; "
                         "exit 1 on any violation (full-size mamba2 "
                         "decode trips on the per_layer cliff)")
    ap.add_argument("--dump", default=None,
                    help="write the two fingerprints + diff as JSON here")
    args = ap.parse_args(argv)

    if args.check_budgets:
        from repro.serve.program_registry import (PINNED_SCAN_LAYERS,
                                                  budget_for, build_card)
        failed = False
        for name, scan in (("scan_stacked", True), ("per_layer", False)):
            fn, ex, cfg = program_lowering(args.arch, args.program,
                                           scan_layers=scan,
                                           reduced=args.reduced)
            budget = budget_for(cfg, args.program)
            card = build_card(args.program, f"hlo:{args.program}", fn, ex,
                              budget=budget)
            pinned = PINNED_SCAN_LAYERS.get(args.arch) == scan
            tag = " (pinned serve layout)" if pinned else ""
            print(f"{args.arch}/{args.program} [{name}]{tag}: "
                  f"copies={card.copies} "
                  f"temp={card.temp_bytes / 1e6:.1f}MB "
                  f"flops={card.flops:.3g} "
                  f"bytes={card.bytes_accessed:.3g}")
            if budget is None:
                print("  no budget for this config (reduced or "
                      "unbudgeted program) -- informational only")
                continue
            violations = card.check_budget()
            for v in violations:
                print(f"  BUDGET VIOLATION: {v}")
            if not violations:
                print(f"  within budget (max_copies={budget.max_copies}, "
                      f"max_temp={budget.max_temp_bytes / 1e6:.0f}MB)")
            # only the layout the serve engine actually pins is gated:
            # the other one is expected to trip (that is the cliff).
            if violations and pinned:
                failed = True
        if failed:
            raise SystemExit(1)
        return None

    fps = {}
    scheds = {}
    bufs = {}
    for name, scan in (("scan_stacked", True), ("per_layer", False)):
        compiled = program_compiled(args.arch, args.program,
                                    scan_layers=scan,
                                    reduced=args.reduced)
        text = compiled.as_text()
        fps[name] = op_fingerprint(text)
        total = sum(v["count"] for v in fps[name].values())
        print(f"{args.arch}/{args.program} [{name}]: {total} instructions, "
              f"{len(fps[name])} opcodes")
        if args.schedule:
            scheds[name] = schedule_fingerprint(text)
            bufs[name] = buffer_assignment_stats(compiled)
    diff = fingerprint_diff(fps["scan_stacked"], fps["per_layer"])
    print(f"\nop-mix drift (scan_stacked vs per_layer), "
          f"{len(diff)} differing opcodes:")
    print(f"{'op':<24}{'n(scan)':>9}{'n(layer)':>9}"
          f"{'MB(scan)':>10}{'MB(layer)':>10}")
    for r in diff[:20]:
        print(f"{r['op']:<24}{r['count_a']:>9}{r['count_b']:>9}"
              f"{r['bytes_a'] / 1e6:>10.2f}{r['bytes_b'] / 1e6:>10.2f}")

    sdiff = None
    if args.schedule:
        sdiff = schedule_diff(scheds["scan_stacked"], scheds["per_layer"])
        print(f"\nschedule diff (scan_stacked vs per_layer): "
              f"similarity {sdiff['similarity']}, first divergence at "
              f"instruction {sdiff['first_divergence']} "
              f"({sdiff['context_a']} vs {sdiff['context_b']}), "
              f"{sdiff['n_diff_blocks']} differing blocks")
        for r in sdiff["moved"][:8]:
            print(f"  {r['tag']:<8} @a{r['at_a']}/b{r['at_b']} "
                  f"len {r['len_a']}->{r['len_b']} "
                  f"bytes {r['bytes_a']}->{r['bytes_b']} "
                  f"a={r['ops_a']} b={r['ops_b']}")
        print("buffer assignment (bytes):")
        for name in ("scan_stacked", "per_layer"):
            print(f"  {name}: {bufs[name]}")
    if args.dump:
        with open(args.dump, "w") as f:
            json.dump({"arch": args.arch, "program": args.program,
                       "fingerprints": fps,
                       "diff": diff, "schedule_diff": sdiff,
                       "buffer_assignment": bufs or None}, f, indent=2)
        print(f"\nwrote {args.dump}")
    return diff


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_operand_bytes: float,
                   collective_wire_bytes: float) -> dict:
    """Three roofline terms in seconds (per the assignment's formulas; all
    inputs are per-device, which equals global/chips)."""
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_operand_bytes / ICI_BW
    collective_wire_s = collective_wire_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s,
             "collective_wire_s": collective_wire_s}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom
    denom = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = compute_s / denom if denom else 0.0
    return terms
if __name__ == "__main__":
    main()
