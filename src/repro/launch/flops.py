"""Analytic MODEL_FLOPS estimates (the 'useful compute' numerator).

MODEL_FLOPS = 6 * N * D (train) / 2 * N * D (inference forward) with
N = *active* params (MoE counts top-k experts only), plus the standard
attention term 2 * 2 * b * h * s^2/2 * head_dim (causal halves it) that the
6ND rule omits.  The ratio MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat
recompute and dispatch overhead in the compiled module.
"""
from __future__ import annotations

from repro.models.base import ModelConfig
from repro.configs.shapes import ShapeSpec


def active_param_count(cfg: ModelConfig, total_params: int) -> int:
    if not cfg.moe:
        return total_params
    # expert tensors: wi + wg + wo = 3 * d * f per expert per layer
    per_expert_layer = 3 * cfg.d_model * cfg.moe_d_ff
    total_expert = cfg.n_layers * cfg.n_experts * per_expert_layer
    active_expert = cfg.n_layers * cfg.n_experts_per_token * per_expert_layer
    return total_params - total_expert + active_expert


def attention_flops(cfg: ModelConfig, batch: int, seq: int,
                    causal: bool = True) -> float:
    if cfg.family in ("mamba", "mamba2"):
        # SSD/scan state math: ~ 2 * (3 or so) * b * l * h * p * n; use the
        # dominant intra-chunk term 2*b*l*chunk*h*p + state terms.
        h = (cfg.expand * cfg.d_model) // cfg.ssm_head_dim \
            if cfg.family == "mamba2" else cfg.expand * cfg.d_model
        n = cfg.d_state
        p = cfg.ssm_head_dim if cfg.family == "mamba2" else 1
        chunk = min(cfg.chunk_size, seq)
        per_layer = 2 * batch * seq * h * p * (chunk + 2 * n)
        return float(per_layer * cfg.n_layers)
    n_attn = cfg.n_layers
    if cfg.family == "recurrentgemma":
        pattern = cfg.block_pattern or ("recurrent", "recurrent", "attention")
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if pattern[i % len(pattern)] == "attention")
    eff = seq
    if cfg.sliding_window:
        eff = min(seq, cfg.sliding_window * 2)
    s2 = seq * eff / (2 if causal else 1)
    return float(n_attn * 2 * 2 * batch * cfg.n_heads * s2 * cfg.head_dim)


def model_flops(cfg: ModelConfig, shape: ShapeSpec, total_params: int
                ) -> float:
    n_active = active_param_count(cfg, total_params)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens + \
            3.0 * attention_flops(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens + \
            attention_flops(cfg, shape.global_batch, shape.seq_len)
    # decode: one token against a seq_len cache
    tokens = shape.global_batch
    attn = 0.0
    if cfg.family not in ("mamba", "mamba2"):
        eff = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        n_attn = cfg.n_layers
        if cfg.family == "recurrentgemma":
            pattern = cfg.block_pattern or ("recurrent", "recurrent",
                                            "attention")
            n_attn = sum(1 for i in range(cfg.n_layers)
                         if pattern[i % len(pattern)] == "attention")
        attn = n_attn * 2 * 2 * shape.global_batch * cfg.n_heads * eff * \
            cfg.head_dim
    return 2.0 * n_active * tokens + attn
