"""Serving driver: ``python -m repro.launch.serve --arch mamba2-130m
--reduced [--engine continuous]`` — batched requests through the
static-shape serve subsystem (wave or continuous-batching engine).

``--trace PATH`` records per-request span traces (Chrome/Perfetto JSON
at PATH plus a ``.jsonl`` event log next to it; fold them with
``python -m repro.launch.trace_report PATH``); ``--metrics-every N``
emits a metrics snapshot every N polls.  See docs/observability.md.
"""
from __future__ import annotations

import argparse
import logging
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.core.xamba import DECODE_MODES, PREFILL_MODES, QUANT_MODES
from repro.models import build_model
from repro.nn import quant
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, Engine, ServeConfig

log = logging.getLogger("repro.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=("wave", "continuous"),
                    default="wave")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--policy", choices=("fcfs", "priority"),
                    default="fcfs")
    ap.add_argument("--decode-mode", default=None, choices=DECODE_MODES,
                    help="XambaConfig.decode mode for the fused "
                         "single-token step")
    ap.add_argument("--prefill-mode", default=None, choices=PREFILL_MODES,
                    help="XambaConfig.prefill mode for the fused "
                         "multi-token SSD prefill pipeline (naive = the "
                         "unfused op chain)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: prompts advance this many "
                         "tokens per engine step, interleaved with decode "
                         "(continuous engine only; default: monolithic "
                         "bucketed prefill)")
    ap.add_argument("--prefill-token-budget", type=int, default=0,
                    help="max prefill tokens per poll under --prefill-chunk "
                         "(0 = one chunk call per poll)")
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    help="prefix-state cache budget in MB: admissions "
                         "reuse chunk-boundary state snapshots of "
                         "previously-served prompt prefixes (continuous "
                         "engine, requires --prefill-chunk; 0 = off)")
    ap.add_argument("--prefix-chunk", type=int, default=None,
                    help="snapshot granularity in tokens (multiple of "
                         "--prefill-chunk; default: one snapshot per "
                         "prefill chunk)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared 'system prompt' tokens "
                         "to every request (exercises the prefix cache)")
    ap.add_argument("--speculate-k", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per "
                         "burst with w8 params, verify them in one batched "
                         "full-precision step, roll back rejected drafts "
                         "via O(1) state snapshots (continuous engine "
                         "only; outputs stay byte-identical; 0 = off)")
    ap.add_argument("--quant", default="none", choices=QUANT_MODES,
                    help="W8 weight-only quantization: int8 per-channel "
                         "weights through prefill, chunked prefill and "
                         "decode (state pools and caches stay fp)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a span trace: Chrome/Perfetto JSON at "
                         "PATH + a JSONL event log at PATH with a .jsonl "
                         "suffix (analyze with repro.launch.trace_report)")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="emit a metrics snapshot every N engine polls "
                         "(0 = off; snapshots also land in the trace)")
    ap.add_argument("--watchdog-s", type=float, default=0.0,
                    help="flag the run as hung if no engine step completes "
                         "for this many seconds (0 = off)")
    ap.add_argument("--watchdog-action", choices=("log", "recover"),
                    default="log",
                    help="hang-watchdog escalation: 'recover' aborts the "
                         "stuck burst at the next poll and requeues its "
                         "requests with bounded retries (docs/robustness.md)")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="chaos fault-injection schedule, e.g. "
                         "'poison@5:slot=1;fail@8:program=decode;"
                         "stall@12:stall_s=0.2' (continuous engine; see "
                         "repro.runtime.faults.parse_plan)")
    ap.add_argument("--max-queue-depth", type=int, default=0,
                    help="bounded admission queue: submit() rejects once "
                         "this many requests queue (0 = unbounded)")
    ap.add_argument("--overload-queue-depth", type=int, default=0,
                    help="enter degraded overload mode at this queue depth "
                         "(prefill budget 0, speculation paused; 0 = off)")
    ap.add_argument("--overload-ttft-p95-s", type=float, default=0.0,
                    help="also enter degraded mode when TTFT p95 crosses "
                         "this many seconds (0 = off)")
    ap.add_argument("--poison-probe", choices=("off", "logits", "state"),
                    default="off",
                    help="NaN/Inf quarantine probes: 'logits' checks the "
                         "step's host logits, 'state' adds a jitted per-row "
                         "state finiteness probe")
    ap.add_argument("--poison-check-every", type=int, default=1,
                    help="run poison probes every N polls (amortizes the "
                         "'state' probe)")
    ap.add_argument("--no-backend-fallback", action="store_true",
                    help="disable the pallas->cumba->naive decode-mode "
                         "fallback on compiled-call failures")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="watchdog-recovery requeue budget per request")
    ap.add_argument("--retry-backoff-s", type=float, default=0.0,
                    help="base for exponential retry backoff (0 = requeue "
                         "immediately)")
    ap.add_argument("--shed-inflight", action="store_true",
                    help="also shed staged/decoding requests whose deadline "
                         "passed (default: deadlines only shed queued work)")
    ap.add_argument("--strict-recompile", action="store_true",
                    help="raise RecompileError if a compile-once program "
                         "(decode / prefill_chunk) retraces after warmup")
    ap.add_argument("--flight-records", type=int, default=0, metavar="N",
                    help="keep a flight-recorder ring of the last N "
                         "request timelines, dumped to --flight-path on "
                         "fault events (0 = off; continuous engine only)")
    ap.add_argument("--flight-path", default=None, metavar="PATH",
                    help="JSONL file for flight-recorder fault dumps "
                         "(render with repro.launch.trace_report "
                         "--flight PATH)")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    if args.prefill_chunk and args.engine != "continuous":
        log.warning("--prefill-chunk only applies to --engine continuous; "
                    "the wave engine keeps monolithic bucketed prefill")
    if args.speculate_k and args.engine != "continuous":
        log.warning("--speculate-k only applies to --engine continuous")
    if args.speculate_k and args.quant != "none":
        log.warning("--speculate-k with --quant %s: the draft params are "
                    "a re-quantization of already-quantized weights — the "
                    "draft/verify gap (and the speedup) collapses",
                    args.quant)

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.decode_mode:
        cfg = cfg.with_decode_mode(args.decode_mode)
    if args.prefill_mode:
        cfg = cfg.with_prefill_mode(args.prefill_mode)
    if args.quant != "none":
        cfg = cfg.with_quant(args.quant)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(args.seed),
                         cfg.dtype)
    if args.quant != "none":
        params = quant.quantize_params_for_mode(params, args.quant)
        s = quant.quant_summary(params)
        log.info("quant %s: %d tensors int8, %.1f MB (%.2fx vs fp32)",
                 args.quant, s["quantized_tensors"], s["bytes"] / 1e6,
                 s["compression"])
    scfg = ServeConfig(
        max_batch=args.batch, prefill_buckets=(32, 128),
        max_new_tokens=args.max_new, temperature=args.temperature,
        seed=args.seed, policy=args.policy,
        prefill_chunk=(args.prefill_chunk
                       if args.engine == "continuous" else None),
        prefill_token_budget=args.prefill_token_budget,
        prefix_cache_mb=(args.prefix_cache_mb
                         if args.engine == "continuous" else 0.0),
        prefix_chunk=args.prefix_chunk,
        speculate_k=(args.speculate_k
                     if args.engine == "continuous" else 0),
        trace=args.trace, metrics_every=args.metrics_every,
        watchdog_s=args.watchdog_s,
        watchdog_action=args.watchdog_action,
        strict_recompile=args.strict_recompile,
        fault_plan=(args.fault_plan
                    if args.engine == "continuous" else None),
        max_queue_depth=args.max_queue_depth,
        overload_queue_depth=args.overload_queue_depth,
        overload_ttft_p95_s=args.overload_ttft_p95_s,
        poison_probe=(args.poison_probe
                      if args.engine == "continuous" else "off"),
        poison_check_every=args.poison_check_every,
        backend_fallback=not args.no_backend_fallback,
        max_retries=args.max_retries,
        retry_backoff_s=args.retry_backoff_s,
        shed_inflight=args.shed_inflight,
        flight_records=(args.flight_records
                        if args.engine == "continuous" else 0),
        flight_path=args.flight_path)
    engine_cls = ContinuousEngine if args.engine == "continuous" else Engine
    engine = engine_cls(model, params, scfg)

    if args.prefix_cache_mb and args.engine != "continuous":
        log.warning("--prefix-cache-mb only applies to --engine continuous")

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(1, cfg.vocab_size, args.shared_prefix).tolist()
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        # Suffix lengths in whole prefill chunks keep the padded streams
        # aligned so the shared prefix actually hits (docs/prefix_cache.md).
        if args.shared_prefix and args.prefill_chunk:
            plen = max(args.prefill_chunk,
                       plen - plen % args.prefill_chunk)
        engine.submit(shared + rng.integers(1, cfg.vocab_size,
                                            plen).tolist())
    try:
        done = engine.run()
    finally:
        engine.close()
    for r in done[:4]:
        log.info("req %d: %d prompt toks -> %s%s", r.uid, len(r.prompt),
                 r.out_tokens[:8], "..." if len(r.out_tokens) > 8 else "")
    log.info("stats: %s", engine.stats(done))
    m = engine.metrics.summary()
    log.info("occupancy: %.2f  ttft_mean_s: %.4f  ttft_p99_s: %.4f  "
             "goodput_tok_s: %.1f  (wall source: %s)",
             m["slot_occupancy"], m["ttft_mean_s"], m["ttft_p99_s"],
             m["goodput_tokens_per_s"], m["wall_source"])
    if m.get("spec_bursts"):
        log.info("speculative: %d bursts  accept_rate %.3f  "
                 "tokens_per_verify %.2f  rollbacks %d",
                 m["spec_bursts"], m["spec_accept_rate"],
                 m["spec_tokens_per_verify"], m["spec_rollbacks"])
    if m["stragglers_decode"] or m["stragglers_prefill"] or \
            m["watchdog_fires"]:
        log.warning("health: %d decode stragglers, %d prefill stragglers, "
                    "%d watchdog fires", m["stragglers_decode"],
                    m["stragglers_prefill"], m["watchdog_fires"])
    if m.get("rejected") or m.get("quarantined") or m.get("shed") or \
            m.get("backend_fallbacks") or m.get("watchdog_recoveries"):
        log.warning("robustness: %d rejected, %d quarantined, %d shed %s, "
                    "%d backend fallbacks, %d watchdog recoveries, "
                    "%d retries", m.get("rejected", 0),
                    m.get("quarantined", 0), m.get("shed", 0),
                    m.get("shed_reasons", {}), m.get("backend_fallbacks", 0),
                    m.get("watchdog_recoveries", 0), m.get("retries", 0))
    trips = {k: s.trips for k, s in engine.sentinels.items() if s.trips}
    if trips:
        log.warning("recompile sentinels tripped: %s", trips)
    pcache = getattr(engine, "prefix_cache", None)
    if pcache is not None:
        s = pcache.stats()
        log.info("prefix cache: %d hits / %d misses, %d prompt tokens "
                 "skipped, %d nodes (%.2f MB resident, %d evictions)",
                 s["hits"], s["misses"], s["hit_tokens"], s["nodes"],
                 s["resident_bytes"] / 2 ** 20, s["evictions"])
    log.info("compile counters: %s", engine.counters)
    if args.trace:
        engine.tracer.save(args.trace)
        jsonl = os.path.splitext(args.trace)[0] + ".jsonl"
        engine.tracer.save_jsonl(jsonl)
        log.info("trace: %d events -> %s (+ %s); analyze with "
                 "python -m repro.launch.trace_report %s",
                 len(engine.tracer.events), args.trace, jsonl, args.trace)
    return done


if __name__ == "__main__":
    main()
