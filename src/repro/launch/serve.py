"""Serving driver: ``python -m repro.launch.serve --arch mamba2-130m
--reduced [--engine continuous]`` — batched requests through the
static-shape serve subsystem (wave or continuous-batching engine)."""
from __future__ import annotations

import argparse
import logging

import jax
import numpy as np

from repro.configs import get_config
from repro.core.xamba import DECODE_MODES, QUANT_MODES
from repro.models import build_model
from repro.nn import quant
from repro.nn.params import init_params
from repro.serve import ContinuousEngine, Engine, ServeConfig

log = logging.getLogger("repro.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=("wave", "continuous"),
                    default="wave")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--policy", choices=("fcfs", "priority"),
                    default="fcfs")
    ap.add_argument("--decode-mode", default=None, choices=DECODE_MODES,
                    help="XambaConfig.decode mode for the fused "
                         "single-token step")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: prompts advance this many "
                         "tokens per engine step, interleaved with decode "
                         "(continuous engine only; default: monolithic "
                         "bucketed prefill)")
    ap.add_argument("--prefill-token-budget", type=int, default=0,
                    help="max prefill tokens per poll under --prefill-chunk "
                         "(0 = one chunk call per poll)")
    ap.add_argument("--quant", default="none", choices=QUANT_MODES,
                    help="W8 weight-only quantization: int8 per-channel "
                         "weights through prefill, chunked prefill and "
                         "decode (state pools and caches stay fp)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    if args.prefill_chunk and args.engine != "continuous":
        log.warning("--prefill-chunk only applies to --engine continuous; "
                    "the wave engine keeps monolithic bucketed prefill")

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.decode_mode:
        cfg = cfg.with_decode_mode(args.decode_mode)
    if args.quant != "none":
        cfg = cfg.with_quant(args.quant)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(args.seed),
                         cfg.dtype)
    if args.quant != "none":
        params = quant.quantize_params_for_mode(params, args.quant)
        s = quant.quant_summary(params)
        log.info("quant %s: %d tensors int8, %.1f MB (%.2fx vs fp32)",
                 args.quant, s["quantized_tensors"], s["bytes"] / 1e6,
                 s["compression"])
    scfg = ServeConfig(
        max_batch=args.batch, prefill_buckets=(32, 128),
        max_new_tokens=args.max_new, temperature=args.temperature,
        seed=args.seed, policy=args.policy,
        prefill_chunk=(args.prefill_chunk
                       if args.engine == "continuous" else None),
        prefill_token_budget=args.prefill_token_budget)
    engine_cls = ContinuousEngine if args.engine == "continuous" else Engine
    engine = engine_cls(model, params, scfg)

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        engine.submit(rng.integers(1, cfg.vocab_size, plen).tolist())
    done = engine.run()
    for r in done[:4]:
        log.info("req %d: %d prompt toks -> %s%s", r.uid, len(r.prompt),
                 r.out_tokens[:8], "..." if len(r.out_tokens) > 8 else "")
    log.info("stats: %s", engine.stats(done))
    m = engine.metrics.summary()
    log.info("occupancy: %.2f  ttft_mean_s: %.4f  goodput_tok_s: %.1f",
             m["slot_occupancy"], m["ttft_mean_s"],
             m["goodput_tokens_per_s"])
    log.info("compile counters: %s", engine.counters)
    return done


if __name__ == "__main__":
    main()
