"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:     # older jax: meshes are implicitly Auto-typed
    AxisType = None


def _make(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-meshing)."""
    return _make(tuple(shape), tuple(axes))


def describe(mesh) -> str:
    return " x ".join(f"{k}={v}" for k, v in mesh.shape.items()) + \
        f" ({mesh.devices.size} devices)"
