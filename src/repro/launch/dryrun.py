import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the real step function (train / prefill /
decode), resolves parameter/cache/batch shardings through the logical rules,
then ``jit(...).lower(...).compile()`` against ShapeDtypeStructs — nothing is
allocated.  It records ``memory_analysis()`` (fits-in-HBM proof),
``cost_analysis()`` (FLOPs/bytes for the roofline), and the collective
schedule parsed from the compiled HLO, as one JSON artifact per cell under
``--out`` (default benchmarks/artifacts/dryrun).

Run one cell:   python -m repro.launch.dryrun --arch mamba2-2.7b \
                    --shape train_4k --mesh single
Run the matrix: python -m repro.launch.dryrun --all --jobs 3
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, shapes as shp
from repro.configs.registry import ASSIGNED, list_archs
from repro.distributed import api as dist_api
from repro.distributed.sharding import make_shardings, resolve_spec
from repro.launch import flops as flops_mod, hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.nn.params import ParamSpec, abstract_params, count_params
from repro.train import TrainConfig, abstract_state, make_train_step

DEFAULT_OUT = Path(__file__).resolve().parents[3] / \
    "benchmarks" / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

def batch_axes_for(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def shard_batch(tree, mesh, batch_axes, seq_axes=None):
    bsize = 1
    for a in batch_axes:
        bsize *= mesh.shape[a]
    ssize = mesh.shape.get(seq_axes, 1) if isinstance(seq_axes, str) else 1

    def one(x):
        spec = [None] * x.ndim
        if x.ndim and x.shape[0] % bsize == 0 and x.shape[0] >= bsize:
            spec[0] = batch_axes
        if seq_axes and x.ndim > 1 and x.shape[1] % ssize == 0 and \
                x.shape[1] >= ssize:
            spec[1] = seq_axes
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, tree)


def shard_cache(tree, mesh, cfg, batch: int):
    """Heuristic cache layout: batch dim over (pod,data); the last
    model-axis-divisible feature dim over 'model' (so 32k KV caches fit)."""
    baxes = batch_axes_for(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)

    # Leading stack axes that must stay unsharded: scan-stacked layers and
    # recurrentgemma's group-stacked serving caches.
    stack_sizes = {cfg.n_layers}
    if cfg.block_pattern:
        stack_sizes.add(cfg.n_layers // len(cfg.block_pattern))

    def one(x):
        spec = [None] * x.ndim
        used_b = False
        for d, size in enumerate(x.shape):
            if d == 0 and size in stack_sizes and cfg.scan_layers:
                continue
            if not used_b and size == batch and size % bsize == 0:
                spec[d] = baxes
                used_b = True
        for d in range(x.ndim - 1, -1, -1):
            if spec[d] is None and d > 0 and x.shape[d] % msize == 0 and \
                    x.shape[d] >= msize:
                spec[d] = "model"
                break
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, tree)


def state_shardings(model, train_cfg, mesh, extra_rules=()):
    specs = model.param_specs()
    param_sh, report = make_shardings(specs, mesh, extra_rules)
    return {
        "params": param_sh,
        "opt": {
            "step": NamedSharding(mesh, P()),
            "m": param_sh,
            "v": param_sh,
        },
    }, report


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def _lower_cell(cfg, shape, mesh, model, train_cfg):
    """Build jit(step) + abstract args for a cell; returns (jitted, args,
    report)."""
    baxes = batch_axes_for(mesh)
    if shape.kind == "train":
        step = make_train_step(model, train_cfg, mesh)
        state_abs = abstract_state(model, train_cfg)
        state_sh, report = state_shardings(model, train_cfg, mesh)
        batch_abs = shp.batch_inputs(cfg, shape)
        seq_axes = dist_api.current_layout()["seq"]
        batch_sh = shard_batch(batch_abs, mesh, baxes, seq_axes)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
        return jitted, (state_abs, batch_abs), report

    params_abs = abstract_params(model.param_specs(), cfg.dtype)
    param_sh, report = make_shardings(model.param_specs(), mesh)
    cache_abs = shp.abstract_cache(model, cfg, shape, cfg.dtype)
    cache_sh = shard_cache(cache_abs, mesh, cfg, shape.global_batch)
    if shape.kind == "prefill":
        def step(params, batch, cache):
            return model.prefill(params, batch, cache)
        batch_abs = shp.prefill_inputs(cfg, shape)
        seq_axes = dist_api.current_layout()["seq"]
        batch_sh = shard_batch(batch_abs, mesh, baxes, seq_axes)
        jitted = jax.jit(step, in_shardings=(param_sh, batch_sh, cache_sh),
                         out_shardings=(None, cache_sh))
        return jitted, (params_abs, batch_abs, cache_abs), report

    def step(params, token, cache, index):
        return model.decode_step(params, token, cache, index)
    tok_abs = shp.decode_inputs(cfg, shape)["token"]
    tok_sh = shard_batch(tok_abs, mesh, baxes)
    idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(step, in_shardings=(param_sh, tok_sh, cache_sh,
                                         NamedSharding(mesh, P())),
                     out_shardings=(None, cache_sh))
    return jitted, (params_abs, tok_abs, cache_abs, idx_abs), report


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             xamba_override=None, overrides=None) -> dict:
    from repro.core import accounting

    t0 = time.time()
    cfg = get_config(arch)
    if xamba_override is not None:
        cfg = cfg.replace(xamba=xamba_override)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = shp.SHAPES[shape_name]
    skip = shp.applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "ok": False}
    if skip:
        rec.update(ok=True, skipped=True, skip_reason=skip)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    baxes = batch_axes_for(mesh)
    # Optimizer dtype policy: >64B-param archs (grok-1) hold Adam moments in
    # bf16 so state fits v5e HBM (params bf16 + m/v bf16 = 6 bytes/param).
    from repro.optim import AdamWConfig
    probe = build_model(cfg)
    big = count_params(probe.param_specs()) > 64e9
    opt_cfg = AdamWConfig(m_dtype="bfloat16" if big else "float32",
                          v_dtype="bfloat16" if big else "float32")
    train_cfg = TrainConfig(optimizer=opt_cfg)
    # Megatron-style sequence parallelism: between TP regions the residual
    # stream is sharded over "model" along the sequence dim (SP), so
    # per-device activations scale 1/(data*model) instead of 1/data.
    # Exception: recurrentgemma's RG-LRU associative scan over a model-
    # sharded sequence axis sends the SPMD partitioner into pathological
    # compile times (>25 min); its activations are small enough (d=2560)
    # that data-parallel-only sharding fits comfortably.
    seq_axes = "model" if shape.kind in ("train", "prefill") and \
        cfg.family != "recurrentgemma" else None

    # --- pass 1: production (rolled-scan) module -> memory analysis -------
    # Scanned layer stacks force per-layer sequential scheduling, so the
    # temp-buffer peak reflects real execution; the unrolled module's peak
    # is a scheduler artifact on the CPU backend (see DESIGN.md §7).
    # Train cells that miss the 16 GB budget retry with more microbatches
    # (gradient accumulation halves live activations each doubling).
    model = build_model(cfg)
    total_params = count_params(model.param_specs())
    rec["params"] = total_params
    mb_candidates = (1, 2, 4, 8) if shape.kind == "train" else (1,)
    for mb in mb_candidates:
        train_cfg = TrainConfig(optimizer=opt_cfg, microbatches=mb)
        with mesh, dist_api.activation_layout(batch_axes=baxes,
                                              seq_axes=seq_axes):
            jitted, args, report = _lower_cell(cfg, shape, mesh, model,
                                               train_cfg)
            rec["sharding_fallbacks"] = report.fallbacks
            t1 = time.time()
            compiled_mem = jitted.lower(*args).compile()
            rec["compile_mem_s"] = round(time.time() - t1, 2)
        ma = compiled_mem.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        mem["total_bytes"] = mem["argument_bytes"] + mem["temp_bytes"]
        mem["total_gb"] = round(mem["total_bytes"] / 2**30, 3)
        mem["fits_16gb_hbm"] = mem["total_bytes"] <= 16 * 2**30
        mem["microbatches"] = mb
        rec["memory"] = mem
        print(f"memory_analysis (rolled, mb={mb}):", ma)
        del compiled_mem
        if mem["fits_16gb_hbm"]:
            break

    # --- pass 2: unrolled accounting -> cost analysis + collectives -------
    # cost_analysis counts while-loop bodies once, so the layer stack and
    # inner scans (attention kv blocks, SSD chunks) must be unrolled for
    # exact totals.  Fully-unrolled deep stacks are slow to compile on this
    # 1-core box, so we measure f(base) and f(base+period) unrolled and
    # extrapolate linearly — exact for homogeneous stacks (validated against
    # a full unroll; see EXPERIMENTS.md §Dry-run).
    def measure(n_layers_override):
        kw = {"scan_layers": False, "n_layers": n_layers_override}
        if cfg.family == "whisper":
            kw["encoder_layers"] = n_layers_override
        cfg_a = cfg.replace(**kw)
        model_a = build_model(cfg_a)
        with mesh, dist_api.activation_layout(batch_axes=baxes,
                                              seq_axes=seq_axes), \
                accounting.unroll_inner_scans():
            jitted, args, _ = _lower_cell(cfg_a, shape, mesh, model_a,
                                          train_cfg)
            compiled = jitted.lower(*args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        coll = hlo_analysis.parse_collectives(compiled.as_text())
        return {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_operand": float(coll.total_operand_bytes),
            "coll_wire": float(coll.total_wire_bytes),
            "coll_counts": coll.counts,
            "coll_operand_by_op": coll.operand_bytes,
        }

    if cfg.family == "recurrentgemma":
        base_l, period = 2, len(cfg.block_pattern or ("r", "r", "a"))
    else:
        base_l, period = 1, 1
    t1 = time.time()
    if cfg.n_layers <= base_l + period:
        m_hi = measure(cfg.n_layers)
        m_lo = None
        n_periods = 0
    else:
        m_lo = measure(base_l)
        m_hi = measure(base_l + period)
        n_periods = (cfg.n_layers - base_l) // period
    rec["compile_acct_s"] = round(time.time() - t1, 2)

    def extrap(key):
        if m_lo is None:
            return m_hi[key]
        return m_lo[key] + n_periods * (m_hi[key] - m_lo[key])

    flops_dev = extrap("flops")
    bytes_dev = extrap("bytes")
    rec["cost"] = {"flops_per_device": flops_dev,
                   "bytes_per_device": bytes_dev,
                   "acct_mode": "marginal" if m_lo else "full",
                   "acct_layers": [base_l, base_l + period],
                   "n_periods": n_periods}
    print("cost_analysis: flops=%.3e bytes=%.3e (per device, extrapolated)"
          % (flops_dev, bytes_dev))

    counts = dict(m_hi["coll_counts"])
    if m_lo is not None:
        for op in set(counts) | set(m_lo["coll_counts"]):
            hi = m_hi["coll_counts"].get(op, 0)
            lo = m_lo["coll_counts"].get(op, 0)
            counts[op] = lo + n_periods * (hi - lo)
    coll_operand = extrap("coll_operand")
    coll_wire = extrap("coll_wire")
    rec["collectives"] = {
        "counts": counts,
        "total_operand_bytes": coll_operand,
        "total_wire_bytes": coll_wire,
    }

    class _Coll:  # adapter for roofline_terms below
        total_operand_bytes = coll_operand
        total_wire_bytes = coll_wire
    coll = _Coll()

    chips = mesh.devices.size
    mf = flops_mod.model_flops(cfg, shape, total_params)
    terms = hlo_analysis.roofline_terms(
        flops_dev, bytes_dev, coll.total_operand_bytes,
        coll.total_wire_bytes)
    terms["model_flops"] = mf
    terms["hlo_flops_global"] = flops_dev * chips
    terms["useful_ratio"] = mf / (flops_dev * chips) if flops_dev else 0.0
    rec["roofline"] = terms
    rec["chips"] = chips
    rec["ok"] = True
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


# ---------------------------------------------------------------------------
# Orchestrator
# ---------------------------------------------------------------------------

def cell_path(out_dir: Path, arch, shape, mesh_kind, tag="") -> Path:
    suffix = f"-{tag}" if tag else ""
    return out_dir / f"{arch}__{shape}__{mesh_kind}{suffix}.json"


def run_matrix(archs, shape_names, mesh_kinds, out_dir: Path, jobs: int,
               skip_existing: bool, tag: str = "", extra_args=()):
    cells = []
    for arch in archs:
        cfg = get_config(arch)
        for sname in shape_names:
            if shp.applicable(cfg, shp.SHAPES[sname]):
                # record the skip without a subprocess
                rec = {"arch": arch, "shape": sname, "kind":
                       shp.SHAPES[sname].kind, "ok": True, "skipped": True,
                       "skip_reason": shp.applicable(cfg, shp.SHAPES[sname])}
                for mk in mesh_kinds:
                    rec2 = dict(rec, mesh=mk)
                    p = cell_path(out_dir, arch, sname, mk, tag)
                    p.parent.mkdir(parents=True, exist_ok=True)
                    p.write_text(json.dumps(rec2, indent=1))
                continue
            for mk in mesh_kinds:
                p = cell_path(out_dir, arch, sname, mk, tag)
                if skip_existing and p.exists():
                    try:
                        if json.loads(p.read_text()).get("ok"):
                            continue
                    except json.JSONDecodeError:
                        pass
                cells.append((arch, sname, mk, p))

    print(f"[dryrun] {len(cells)} cells to run, jobs={jobs}")
    running = []
    idx = 0
    failures = 0
    while idx < len(cells) or running:
        while idx < len(cells) and len(running) < jobs:
            arch, sname, mk, p = cells[idx]
            idx += 1
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", sname, "--mesh", mk,
                   "--out", str(out_dir)]
            if tag:
                cmd += ["--tag", tag]
            cmd += list(extra_args)
            log = p.with_suffix(".log").open("w")
            proc = subprocess.Popen(cmd, stdout=log, stderr=log)
            running.append((proc, arch, sname, mk, p, time.time()))
            print(f"[dryrun] start {arch} {sname} {mk}")
        time.sleep(3)
        still = []
        for proc, arch, sname, mk, p, ts in running:
            if proc.poll() is None:
                still.append((proc, arch, sname, mk, p, ts))
                continue
            ok = p.exists() and json.loads(p.read_text()).get("ok", False) \
                if p.exists() else False
            status = "OK" if ok else f"FAIL(rc={proc.returncode})"
            if not ok:
                failures += 1
            print(f"[dryrun] done  {arch} {sname} {mk}: {status} "
                  f"({time.time() - ts:.0f}s)")
        running = still
    print(f"[dryrun] matrix complete, {failures} failure(s)")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(shp.SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--assigned-only", action="store_true",
                    help="only the 10 assigned archs (skip 130m cells)")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--no-skip-existing", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig override key=value (perf variants)")
    args = ap.parse_args()

    def _parse_override(kv):
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                return k, cast(v)
            except ValueError:
                pass
        if v in ("true", "false"):
            return k, v == "true"
        return k, v

    overrides = dict(_parse_override(kv) for kv in args.override) or None
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all or args.arch is None:
        archs = ASSIGNED if args.assigned_only or args.all else list_archs()
        if args.arch:
            archs = [args.arch]
        rc = run_matrix(archs, list(shp.SHAPES), ["single", "multi"],
                        out_dir, args.jobs, not args.no_skip_existing,
                        args.tag)
        sys.exit(1 if rc else 0)

    rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
           "ok": False}
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, out_dir,
                       overrides=overrides)
        rec["overrides"] = overrides
    except Exception as e:  # noqa: BLE001 — recorded per-cell
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()
        print(rec["traceback"])
    path = cell_path(out_dir, args.arch, args.shape, args.mesh, args.tag)
    path.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] wrote {path} ok={rec['ok']}")
    sys.exit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
