"""Cross-pod gradient compression: int8 block-quantized all-reduce with
error feedback.

Within a pod, gradients sync over fast ICI in bf16/f32 (XLA's implicit
reduce).  Across pods the links are the slow axis, so the train step can
route the pod-axis gradient reduction through this module instead:

    q, scales = quantize_int8(g - err)         # per-block absmax scaling
    q_sum     = psum(q, 'pod')                  # 4x fewer bytes on the wire
    g_hat     = dequantize(q_sum, psum(scales)) # (scales reduced exactly)
    err'      = g_hat_local_roundtrip - g_local # error feedback -> next step

Error feedback makes the compression *unbiased over time* (residuals are
re-injected), the standard trick that keeps convergence intact at int8.
``compressed_pod_psum`` is designed to run inside ``jax.shard_map`` with
``axis_names={'pod'}`` so data/model axes stay under the compiler's
automatic partitioning.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


def shard_map(f, *, mesh, axis_names, in_specs, out_specs):
    """``jax.shard_map`` compat: newer jax takes ``axis_names``/``check_vma``;
    older jax (<= 0.4.x) exposes ``jax.experimental.shard_map`` with the
    complementary ``auto`` set and ``check_rep`` instead."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names=set(axis_names),
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block absmax int8 quantization. x: flat f32 (padded to BLOCK)."""
    xb = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)


def compressed_pod_psum(grads: PyTree, errors: PyTree,
                        axis: str = "pod") -> Tuple[PyTree, PyTree]:
    """All-reduce ``grads`` over ``axis`` in int8 with error feedback.

    Must run inside shard_map with ``axis`` manual. Returns
    (reduced_grads, new_errors); divide by axis size outside if a mean is
    wanted (we return the sum, matching psum semantics).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        gf = g.astype(jnp.float32)
        target = gf - e
        flat = target.reshape(-1)
        pad = (-flat.shape[0]) % BLOCK
        flat_p = jnp.pad(flat, (0, pad))
        q, scale = _quantize(flat_p)
        # Wire format: int8 payload (+ f32 scale per 256 elems = 1.6% extra).
        sent = _dequantize(q, scale)[:flat.shape[0]].reshape(g.shape)
        new_err = target - sent                 # residual stays local
        # int32 psum of int8 payloads is exact; dequantize with own scale
        # would lose cross-pod scale info, so reduce the dequantized f32
        # blocks' contributions via psum of (q * scale) terms:
        reduced = jax.lax.psum(sent, axis)
        return reduced, new_err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree.unflatten(tdef, [o[0] for o in outs])
    err = jax.tree.unflatten(tdef, [o[1] for o in outs])
    del n
    return red, err


def init_errors(params: PyTree) -> PyTree:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
