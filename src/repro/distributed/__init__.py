from repro.distributed import api, collectives, sharding  # noqa: F401
