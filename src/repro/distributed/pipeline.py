"""Experimental pipeline parallelism (GPipe-style looped pipeline).

Not required at the assigned 512-chip scale (TP x FSDP covers it — see
DESIGN.md §5), but provided as the PP building block for >4k-chip meshes
where a single layer's weights outgrow TP.

Pattern (MaxText-style "circular" schedule, single program):
  * stage parameters are stacked on a leading stage axis, sharded over a
    mesh axis — each device group owns one stage;
  * one buffer holds the in-flight activation of every stage; every tick
    runs all stages in parallel (vmap over the sharded stage axis) and then
    rotates the buffer one stage forward (lowers to collective-permute);
  * microbatch i enters at tick i and exits after S stages; a run of
    M microbatches costs M + S - 1 ticks (the usual bubble).

Differentiable (jax.grad through the loop = GPipe with rematerialization).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def pipeline_apply(stage_fn: Callable[[Any, Array], Array],
                   stacked_params: Any,
                   microbatches: Array) -> Array:
    """Run ``stage_fn`` as an S-stage pipeline over M microbatches.

    stage_fn: (stage_params, x) -> x, applied by every stage.
    stacked_params: pytree with leading stage axis S (shard it over a mesh
        axis for real PP; works unsharded too).
    microbatches: (M, mb, ...) inputs.
    Returns (M, mb, ...) outputs (microbatch i fully processed by all S
    stages, in order).
    """
    s_axis = jax.tree.leaves(stacked_params)[0].shape[0]
    m = microbatches.shape[0]
    mb_shape = microbatches.shape[1:]

    buf0 = jnp.zeros((s_axis,) + mb_shape, microbatches.dtype)
    out0 = jnp.zeros((m,) + mb_shape, microbatches.dtype)

    vfn = jax.vmap(stage_fn, in_axes=(0, 0))

    def tick(carry, t):
        buf, out = carry
        # inject microbatch t (if any) into stage 0's slot
        inj = jnp.where(t < m, t, m - 1)
        x_in = jax.lax.dynamic_index_in_dim(microbatches, inj, 0,
                                            keepdims=False)
        buf = jnp.where(t < m, buf.at[0].set(x_in.astype(buf.dtype)), buf)
        # all stages compute in parallel (stage axis may be mesh-sharded)
        buf = vfn(stacked_params, buf)
        # microbatch t - (S-1) exits from the last stage
        exit_ix = t - (s_axis - 1)
        out = jnp.where(
            exit_ix >= 0,
            jax.lax.dynamic_update_index_in_dim(
                out, buf[-1], jnp.maximum(exit_ix, 0), 0),
            out)
        # rotate: stage s's output becomes stage s+1's input
        buf = jnp.roll(buf, 1, axis=0)
        return (buf, out), None

    (_, out), _ = jax.lax.scan(tick, (buf0, out0),
                               jnp.arange(m + s_axis - 1))
    return out


def reference_apply(stage_fn: Callable[[Any, Array], Array],
                    stacked_params: Any, x: Array) -> Array:
    """Sequential oracle: apply the S stages in order (no pipeline)."""
    s = jax.tree.leaves(stacked_params)[0].shape[0]
    for i in range(s):
        p_i = jax.tree.map(lambda a: a[i], stacked_params)
        x = stage_fn(p_i, x)
    return x
