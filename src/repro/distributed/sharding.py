"""Logical-axis -> mesh-axis sharding with divisibility-aware fallback.

Models declare *logical* axes on every parameter ("vocab", "embed", "mlp",
"qkv", "expert", ...).  This module maps them onto the physical mesh
(single-pod ``("data","model")`` or multi-pod ``("pod","data","model")``)
using an ordered candidate table, checking

  * divisibility  (a dim of size 8 never shards over a 16-way axis), and
  * exclusivity   (each mesh axis used at most once per param),

and falling back to replication otherwise — recording every fallback so the
dry-run report shows exactly which params degraded.  This is what lets one
model definition serve GQA kv-head counts of 1/4/8/20/32 and expert counts
of 8/128 on the same mesh without per-arch sharding code.

The default layout is 2-D "FSDP x TP": feature/"embed" dims shard over the
compound data axes (ZeRO-3-style; XLA re-gathers per layer, overlapping with
compute under scan-over-layers), projection-output/vocab/expert dims shard
over "model".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.params import ParamSpec, is_spec

# Candidate mesh axes per logical axis, in preference order.  "fsdp" is a
# macro for the compound data axes present in the mesh (("pod","data") or
# ("data",)).
DEFAULT_RULES: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
    ("vocab", ("model",)),
    ("embed", ("fsdp",)),
    ("mlp", ("model",)),
    ("mlp2", (None,)),
    ("qkv", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model", None)),
    ("expert", ("model", "pod", None)),
    ("layers", (None,)),
)


@dataclasses.dataclass
class ShardingReport:
    """Which params fell back to replication on which dims (and why)."""
    fallbacks: List[Tuple[str, int, str, str]] = dataclasses.field(
        default_factory=list)

    def add(self, path: str, dim: int, logical: str, reason: str):
        self.fallbacks.append((path, dim, logical, reason))

    def summary(self) -> str:
        if not self.fallbacks:
            return "all logical axes mapped"
        lines = [f"  {p} dim{d} ({l}): {r}" for p, d, l, r in self.fallbacks]
        return f"{len(self.fallbacks)} fallback(s):\n" + "\n".join(lines)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _expand_macro(cand, mesh: Mesh):
    if cand == "fsdp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        return axes if axes else None
    return cand


def resolve_spec(spec: ParamSpec, mesh: Mesh,
                 rules: Dict[str, Tuple[Any, ...]],
                 path: str = "", report: Optional[ShardingReport] = None
                 ) -> P:
    used: set = set()
    out = []
    for d, logical in enumerate(spec.axes):
        if logical is None:
            out.append(None)
            continue
        cands = rules.get(logical, (None,))
        chosen = None
        reason = f"no candidate for {logical!r}"
        for cand in cands:
            cand = _expand_macro(cand, mesh)
            if cand is None:
                chosen, reason = None, "rule says replicate"
                break
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a not in mesh.shape for a in axes):
                reason = f"axis {axes} not in mesh"
                continue
            if any(a in used for a in axes):
                reason = f"axis {axes} already used"
                continue
            size = _axis_size(mesh, axes)
            if spec.shape[d] % size != 0:
                reason = f"{spec.shape[d]} % {size} != 0"
                continue
            chosen = cand if isinstance(cand, str) else tuple(axes)
            break
        if chosen is None:
            if report is not None and logical is not None and \
                    rules.get(logical, (None,))[0] is not None:
                report.add(path, d, logical, reason)
            out.append(None)
        else:
            for a in ((chosen,) if isinstance(chosen, str) else chosen):
                used.add(a)
            out.append(chosen)
    return P(*out)


def make_shardings(specs, mesh: Mesh,
                   extra_rules: Sequence[Tuple[str, Tuple[Any, ...]]] = (),
                   ) -> Tuple[Any, ShardingReport]:
    """specs pytree -> NamedSharding pytree (+ fallback report)."""
    rules = dict(DEFAULT_RULES)
    rules.update(dict(extra_rules))
    report = ShardingReport()
    paths_specs = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec)
    flat, treedef = paths_specs
    out = []
    for path, spec in flat:
        pstr = jax.tree_util.keystr(path)
        pspec = resolve_spec(spec, mesh, rules, pstr, report)
        out.append(NamedSharding(mesh, pspec))
    return jax.tree_util.tree_unflatten(treedef, out), report


def make_pspecs(specs, mesh: Mesh,
                extra_rules: Sequence[Tuple[str, Tuple[Any, ...]]] = ()):
    rules = dict(DEFAULT_RULES)
    rules.update(dict(extra_rules))
    return jax.tree.map(
        lambda s: resolve_spec(s, mesh, rules), specs, is_leaf=is_spec)


def shard_like(tree, shardings):
    """Device-put a concrete pytree onto the given shardings."""
    return jax.tree.map(jax.device_put, tree, shardings)
