"""Activation-sharding context: models constrain activations without
knowing the mesh.

``activation_layout`` installs a (batch_axes, seq_axes) policy; model code
calls ``shard_tokens3d`` / ``shard_tokens2d`` on block boundaries.  Outside a
policy (CPU smoke tests) these are no-ops, so the same model code runs
everywhere.  For ``long_500k`` (batch=1) the launcher installs a
sequence-sharded layout instead of a batch-sharded one (SP).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_LAYOUT: contextvars.ContextVar = contextvars.ContextVar(
    "activation_layout", default=None)


@contextlib.contextmanager
def activation_layout(batch_axes: Any = ("pod", "data"),
                      seq_axes: Any = None):
    token = _LAYOUT.set({"batch": batch_axes, "seq": seq_axes})
    try:
        yield
    finally:
        _LAYOUT.reset(token)


def current_layout() -> Optional[dict]:
    return _LAYOUT.get()


def shard_tokens2d(x):
    """(batch, seq) int arrays."""
    lay = _LAYOUT.get()
    if lay is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(lay["batch"], lay["seq"]))


def shard_tokens3d(x):
    """(batch, seq, features) activations."""
    lay = _LAYOUT.get()
    if lay is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(lay["batch"], lay["seq"], None))


def constrain_dims(x, dims: dict):
    """Constrain selected dims by layout role: {dim_index: "batch"|"seq"}.

    Used by the SSD chunk-parallel layout: (b, c, L, ...) tensors pin
    b -> batch axes and the CHUNK dim -> the seq axes, so every device owns
    whole chunks and the intra-chunk work is collective-free.
    """
    lay = _LAYOUT.get()
    if lay is None:
        return x
    spec = [None] * x.ndim
    ok = True
    for d, role in dims.items():
        axes = lay.get(role)
        if axes is None:
            continue
        size = 1
        names = (axes,) if isinstance(axes, str) else axes
        # divisibility guard (mesh sizes unknown here; XLA validates, but
        # skip constraining dims of size 1 to avoid invalid specs)
        if x.shape[d] <= 1:
            ok = False
            continue
        spec[d] = axes
        del size, names
    if not any(s is not None for s in spec):
        return x
    del ok
    return jax.lax.with_sharding_constraint(x, P(*spec))


def batch_pspec(ndim: int = 2) -> P:
    lay = _LAYOUT.get()
    batch = lay["batch"] if lay else None
    seq = lay["seq"] if lay else None
    if ndim == 1:
        return P(batch)
    return P(batch, seq, *([None] * (ndim - 2)))
