"""Fault tolerance: checkpoint/restart loop + elastic re-meshing.

``run_with_restarts`` drives a training function under a crash policy:
any exception (a lost host surfaces as one in SPMD jax) falls back to the
latest atomic checkpoint and resumes, up to ``max_restarts``.  Combined with
``reshard_state`` a restart may come back on a *different* mesh (fewer
hosts): parameters are re-device_put onto the new mesh's shardings — that
is elastic scaling down/up at checkpoint granularity, the standard
large-fleet posture.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Optional, Tuple

import jax

from repro.checkpoint import ckpt
from repro.distributed.sharding import make_shardings

log = logging.getLogger(__name__)


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    ckpt_dir: str = "/tmp/repro_ckpt"


def reshard_state(state: Any, specs: Any, new_mesh, extra_rules=()) -> Any:
    """Re-device_put a state pytree onto a new mesh (elastic re-shard)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    param_sh, _ = make_shardings(specs, new_mesh, extra_rules)
    out = dict(state)
    out["params"] = jax.tree.map(jax.device_put, state["params"], param_sh)
    out["opt"] = {
        "step": jax.device_put(state["opt"]["step"],
                               NamedSharding(new_mesh, P())),
        "m": jax.tree.map(jax.device_put, state["opt"]["m"], param_sh),
        "v": jax.tree.map(jax.device_put, state["opt"]["v"], param_sh),
    }
    return out


def run_with_restarts(train_some_steps: Callable[[Any, int], Tuple[Any, int]],
                      init_state: Any,
                      policy: RestartPolicy,
                      save_every: int = 10,
                      target_steps: int = 100) -> Tuple[Any, int, int]:
    """Drive ``train_some_steps(state, start_step) -> (state, reached_step)``
    to ``target_steps`` with checkpoint/restart. Returns
    (state, step, n_restarts)."""
    restarts = 0
    state = init_state
    step = 0
    # resume if a checkpoint exists
    last = ckpt.latest_step(policy.ckpt_dir)
    if last is not None:
        state, step, _ = ckpt.restore(policy.ckpt_dir, state)
        log.info("resumed from step %d", step)

    while step < target_steps:
        try:
            state, step = train_some_steps(state, step)
            ckpt.save(policy.ckpt_dir, step, state)
        except Exception as e:  # noqa: BLE001 — the restart boundary
            restarts += 1
            log.warning("step loop failed at ~%d: %s (restart %d/%d)",
                        step, e, restarts, policy.max_restarts)
            if restarts > policy.max_restarts:
                raise
            last = ckpt.latest_step(policy.ckpt_dir)
            if last is not None:
                state, step, _ = ckpt.restore(policy.ckpt_dir, state)
            # else: restart from the initial state
    return state, step, restarts
