"""Fault tolerance: checkpoint/restart loop + elastic re-meshing.

``run_with_restarts`` drives a training function under a crash policy:
any exception (a lost host surfaces as one in SPMD jax) falls back to the
latest atomic checkpoint and resumes, up to ``max_restarts``, with
exponential backoff between restarts (``backoff_delay_s`` — the same
helper the serve-side retry policy in ``serve/continuous.py`` uses, so
training restarts and request requeues share one backoff curve).  A step
that fails twice in a row is *crash-loop* territory — deterministic
poison, not a transient fault — and gets a distinct log line plus an
entry in the returned ``crash_loop_steps``.  Combined with
``reshard_state`` a restart may come back on a *different* mesh (fewer
hosts): parameters are re-device_put onto the new mesh's shardings — that
is elastic scaling down/up at checkpoint granularity, the standard
large-fleet posture.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax

from repro.checkpoint import ckpt
from repro.distributed.sharding import make_shardings

log = logging.getLogger(__name__)


def backoff_delay_s(attempt: int, base_s: float = 0.5,
                    cap_s: float = 30.0) -> float:
    """Shared exponential backoff: ``base * 2**(attempt-1)`` seconds for
    the ``attempt``-th retry (1-based), capped at ``cap_s``; 0 for
    ``attempt <= 0``.  Deterministic (no jitter) so retry schedules are
    reproducible in tests and benchmarks."""
    if attempt <= 0 or base_s <= 0:
        return 0.0
    return min(cap_s, base_s * (2.0 ** (attempt - 1)))


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    ckpt_dir: str = "/tmp/repro_ckpt"
    # Exponential backoff between restarts (``backoff_delay_s``); 0
    # disables the sleep (tests).
    backoff_base_s: float = 0.0
    backoff_cap_s: float = 30.0


class RestartOutcome(NamedTuple):
    """``run_with_restarts`` result: final state, step reached, restart
    count, and the steps at which a crash loop was detected (the same
    step failing twice consecutively — empty means every crash was
    transient)."""
    state: Any
    step: int
    restarts: int
    crash_loop_steps: List[int]


def reshard_state(state: Any, specs: Any, new_mesh, extra_rules=()) -> Any:
    """Re-device_put a state pytree onto a new mesh (elastic re-shard)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    param_sh, _ = make_shardings(specs, new_mesh, extra_rules)
    out = dict(state)
    out["params"] = jax.tree.map(jax.device_put, state["params"], param_sh)
    out["opt"] = {
        "step": jax.device_put(state["opt"]["step"],
                               NamedSharding(new_mesh, P())),
        "m": jax.tree.map(jax.device_put, state["opt"]["m"], param_sh),
        "v": jax.tree.map(jax.device_put, state["opt"]["v"], param_sh),
    }
    return out


def run_with_restarts(train_some_steps: Callable[[Any, int], Tuple[Any, int]],
                      init_state: Any,
                      policy: RestartPolicy,
                      save_every: int = 10,
                      target_steps: int = 100) -> RestartOutcome:
    """Drive ``train_some_steps(state, start_step) -> (state, reached_step)``
    to ``target_steps`` with checkpoint/restart.  Restarts back off
    exponentially (``policy.backoff_base_s``); a step that fails twice in
    a row is logged as a crash loop and recorded in the returned
    ``crash_loop_steps`` (the loop still retries up to ``max_restarts`` —
    the caller decides whether a crash loop is fatal).  Returns a
    :class:`RestartOutcome` ``(state, step, restarts, crash_loop_steps)``.
    """
    restarts = 0
    state = init_state
    step = 0
    last_failed_step: Optional[int] = None
    consecutive_at_step = 0
    crash_loop_steps: List[int] = []
    # resume if a checkpoint exists
    last = ckpt.latest_step(policy.ckpt_dir)
    if last is not None:
        state, step, _ = ckpt.restore(policy.ckpt_dir, state)
        log.info("resumed from step %d", step)

    while step < target_steps:
        try:
            state, step = train_some_steps(state, step)
            ckpt.save(policy.ckpt_dir, step, state)
            last_failed_step = None
            consecutive_at_step = 0
        except Exception as e:  # noqa: BLE001 — the restart boundary
            restarts += 1
            if step == last_failed_step:
                consecutive_at_step += 1
            else:
                last_failed_step = step
                consecutive_at_step = 1
            if consecutive_at_step >= 2:
                # Same step, twice in a row: a deterministic fault, not a
                # transient one — restarting harder will not help.
                if step not in crash_loop_steps:
                    crash_loop_steps.append(step)
                log.error(
                    "CRASH LOOP: step %d failed %d times consecutively "
                    "(%s) — likely deterministic; restart %d/%d", step,
                    consecutive_at_step, e, restarts, policy.max_restarts)
            else:
                log.warning("step loop failed at ~%d: %s (restart %d/%d)",
                            step, e, restarts, policy.max_restarts)
            if restarts > policy.max_restarts:
                raise
            delay = backoff_delay_s(restarts, policy.backoff_base_s,
                                    policy.backoff_cap_s)
            if delay:
                log.info("backing off %.2fs before restart", delay)
                time.sleep(delay)
            last = ckpt.latest_step(policy.ckpt_dir)
            if last is not None:
                state, step, _ = ckpt.restore(policy.ckpt_dir, state)
            # else: restart from the initial state
    return RestartOutcome(state, step, restarts, crash_loop_steps)
