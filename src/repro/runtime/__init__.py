from repro.runtime.elastic import (RestartOutcome,  # noqa: F401
                                   RestartPolicy, backoff_delay_s,
                                   reshard_state, run_with_restarts)
from repro.runtime.faults import (FaultEvent, FaultInjector,  # noqa: F401
                                  InjectedBackendError)
from repro.runtime.health import StepMonitor, Watchdog  # noqa: F401
