from repro.runtime.elastic import (RestartPolicy, reshard_state,  # noqa: F401
                                   run_with_restarts)
from repro.runtime.health import StepMonitor, Watchdog  # noqa: F401
