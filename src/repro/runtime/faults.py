"""Fault-injection harness for the serve stack (chaos testing).

XAMBA's target deployments are always-on edge services: the serve loop
must *survive* numerical poison, backend failures, stragglers and
overload, and the only way to trust that is to inject those faults into
the real engine loop and assert the blast radius.  This module is the
schedule-driven injector the chaos tests, ``scripts/smoke_chaos.py`` and
``benchmarks/bench_serve_chaos.py`` drive the :class:`ContinuousEngine`
with (threaded through ``ServeConfig.fault_plan``;
``docs/robustness.md`` has the taxonomy).

Faults are **events on the engine's poll clock** — deterministic given
the plan and seed, so a chaotic run is reproducible and comparable
byte-for-byte against a fault-free control run:

====================  =====================================================
``poison``            overwrite one slot's recurrent state with NaN/Inf
                      (numerical poison: a bad kernel, an overflow) — the
                      engine's quarantine probes must contain it
``fail``              raise :class:`InjectedBackendError` at the compiled-
                      call boundary of one program (simulated kernel /
                      backend failure) — the backend fallback chain must
                      re-dispatch
``stall``             sleep inside one compiled-call window (straggler /
                      hung device) — StepMonitor must flag it, and past
                      ``watchdog_s`` the watchdog escalation must recover
``snap_drop``         drop one prefix-cache snapshot insert (lost write)
``snap_corrupt``      corrupt one prefix-cache snapshot with NaN before
                      insert — the poison gate must refuse it
====================  =====================================================

Every fault fires **once** (``fired`` latch); ``summary()`` reports what
actually fired so tests can assert the plan executed.  The injector
raises *before* the jitted call runs, so donated arenas are never left
half-consumed by a simulated failure (see
``serve/continuous.py: _guarded_call``).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

log = logging.getLogger("repro.serve")

FAULT_KINDS = ("poison", "fail", "stall", "snap_drop", "snap_corrupt")


class InjectedBackendError(RuntimeError):
    """Simulated compiled-call failure (kernel crash, backend loss)."""


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault.  ``poll`` is the engine poll index (post-
    warmup, i.e. after ``reset_stats``) at which the fault arms; it fires
    at the first opportunity from that poll on (e.g. a ``poison`` needs a
    live slot) and then never again."""
    kind: str
    poll: int
    slot: int = 0                 # poison: target slot (clamped to live)
    program: str = "decode"       # fail/stall: which compiled program
    stall_s: float = 0.1          # stall: injected sleep
    mode: str = "nan"             # poison/snap_corrupt payload: nan | inf
    fired: bool = False

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")
        if self.mode not in ("nan", "inf"):
            raise ValueError(f"poison mode {self.mode!r} not in (nan, inf)")


def _parse_event(token: str) -> FaultEvent:
    """One spec token: ``kind@poll[:k=v[,k=v...]]`` — e.g.
    ``poison@5:slot=1,mode=inf`` or ``fail@8:program=decode``."""
    head, _, tail = token.partition(":")
    kind, _, at = head.partition("@")
    if not at:
        raise ValueError(
            f"fault spec {token!r}: expected kind@poll[:k=v,...]")
    kw = {}
    if tail:
        for pair in tail.split(","):
            k, _, v = pair.partition("=")
            if k in ("slot", "poll"):
                kw[k] = int(v)
            elif k == "stall_s":
                kw[k] = float(v)
            elif k in ("program", "mode"):
                kw[k] = v
            else:
                raise ValueError(f"fault spec {token!r}: unknown key {k!r}")
    return FaultEvent(kind=kind.strip(), poll=int(at), **kw)


def parse_plan(spec: str) -> List[FaultEvent]:
    """Parse a plan spec string: ``;``-separated event tokens (see
    :func:`_parse_event`); whitespace is ignored."""
    return [_parse_event(tok.strip())
            for tok in spec.split(";") if tok.strip()]


class FaultInjector:
    """Schedule-driven fault injector for one engine.

    ``plan`` is a sequence of :class:`FaultEvent` (or a spec string — see
    :func:`parse_plan`).  ``seed`` derives the poison payloads (the NaN/
    Inf pattern is seeded noise, not a constant, so probes cannot pass by
    accident of a special value).  The injector is host-side and cheap:
    each hook is a list scan over the (tiny) plan.
    """

    def __init__(self, plan: Iterable[FaultEvent] | str, seed: int = 0):
        if isinstance(plan, str):
            plan = parse_plan(plan)
        self.plan: List[FaultEvent] = list(plan)
        for ev in self.plan:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"fault plan entries must be FaultEvent, "
                                f"got {type(ev).__name__}")
        self.seed = seed
        self._rng = np.random.default_rng(seed)

    # -- hooks (called by ContinuousEngine) --------------------------------
    def _due(self, kind: str, poll: int,
             program: Optional[str] = None) -> Optional[FaultEvent]:
        for ev in self.plan:
            if (ev.kind == kind and not ev.fired and poll >= ev.poll and
                    (program is None or ev.program == program)):
                return ev
        return None

    def poison_targets(self, poll: int,
                       live_slots: Sequence[int]) -> List[Tuple[int, str]]:
        """Due ``poison`` events: ``[(slot, mode)]`` to corrupt this poll.
        A poison waits for a live slot (corrupting a dead row would be
        invisible); the target clamps onto the live set deterministically.
        """
        out = []
        while True:
            ev = self._due("poison", poll)
            if ev is None or not live_slots:
                return out
            slot = (ev.slot if ev.slot in live_slots
                    else live_slots[ev.slot % len(live_slots)])
            ev.fired = True
            log.warning("FAULT INJECTED: poison(%s) slot %d at poll %d",
                        ev.mode, slot, poll)
            out.append((slot, ev.mode))

    def poison_payload(self, shape, mode: str) -> np.ndarray:
        """Seeded corruption payload: noise with NaN/Inf sprinkled at
        ~25%% of positions (at least one)."""
        x = self._rng.standard_normal(shape).astype(np.float32)
        bad = self._rng.random(shape) < 0.25
        flat = bad.reshape(-1)
        if not flat.any():
            flat[self._rng.integers(flat.size)] = True
        x[bad.reshape(x.shape)] = np.nan if mode == "nan" else np.inf
        return x

    def corrupt(self, pytree, mode: str = "nan"):
        """NaN/Inf-corrupt every float leaf of a (host) state pytree."""
        import jax

        def leaf(x):
            a = np.asarray(x)
            if not np.issubdtype(a.dtype, np.floating):
                return x
            return self.poison_payload(a.shape, mode).astype(a.dtype)

        return jax.tree.map(leaf, pytree)

    def pre_call(self, program: str, poll: int) -> None:
        """Compiled-call boundary hook: stall (sleep inside the call's
        timing window) and/or raise a simulated backend failure.  Raises
        BEFORE the jitted call so donated buffers stay intact."""
        ev = self._due("stall", poll, program)
        if ev is not None:
            ev.fired = True
            log.warning("FAULT INJECTED: stall %.3fs in %s at poll %d",
                        ev.stall_s, program, poll)
            import time
            time.sleep(ev.stall_s)
        ev = self._due("fail", poll, program)
        if ev is not None:
            ev.fired = True
            log.warning("FAULT INJECTED: %s backend failure at poll %d",
                        program, poll)
            raise InjectedBackendError(
                f"injected {program} failure at poll {poll}")

    def snapshot_fault(self, poll: int) -> Optional[str]:
        """Due prefix-snapshot fault for an insert happening this poll:
        ``"drop"`` / ``"corrupt"`` / None."""
        ev = self._due("snap_drop", poll)
        if ev is not None:
            ev.fired = True
            log.warning("FAULT INJECTED: snapshot drop at poll %d", poll)
            return "drop"
        ev = self._due("snap_corrupt", poll)
        if ev is not None:
            ev.fired = True
            log.warning("FAULT INJECTED: snapshot corrupt at poll %d", poll)
            return "corrupt"
        return None

    # -- reporting ---------------------------------------------------------
    def summary(self) -> dict:
        """Fired/pending counts per kind (tests assert the plan ran)."""
        fired = {k: 0 for k in FAULT_KINDS}
        pending = {k: 0 for k in FAULT_KINDS}
        for ev in self.plan:
            (fired if ev.fired else pending)[ev.kind] += 1
        return {"fired": {k: v for k, v in fired.items() if v},
                "pending": {k: v for k, v in pending.items() if v},
                "events": len(self.plan)}


def as_injector(plan) -> Optional[FaultInjector]:
    """Coerce ``ServeConfig.fault_plan`` (None | FaultInjector | spec
    string | iterable of FaultEvent) into a FaultInjector."""
    if plan is None:
        return None
    if isinstance(plan, FaultInjector):
        return plan
    return FaultInjector(plan)
