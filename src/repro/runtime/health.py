"""Step-time health monitoring: straggler / hang detection.

In SPMD data-parallel training a straggling host slows every step (the
collectives synchronize), so detection is: robust per-step timing stats and
a policy hook.  ``StepMonitor`` keeps a rolling window, flags steps slower
than ``threshold x median`` (straggler) and exposes a deadline watchdog
(hang -> the restart loop in runtime/elastic.py takes over).  At real
multi-host scale the same monitor runs per host and the flags are
aggregated through the (out-of-band) coordination service; the policy and
statistics are identical.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    straggler: bool


class StepMonitor:
    def __init__(self, window: int = 50, straggler_factor: float = 2.0,
                 warmup_steps: int = 3):
        self.window = window
        self.factor = straggler_factor
        self.warmup = warmup_steps
        self.records: List[StepRecord] = []
        self._durations: List[float] = []

    def observe(self, step: int, seconds: float) -> StepRecord:
        baseline = self._durations[-self.window:]
        is_straggler = False
        if len(baseline) >= self.warmup:
            med = statistics.median(baseline)
            is_straggler = seconds > self.factor * med
        self._durations.append(seconds)
        rec = StepRecord(step, seconds, is_straggler)
        self.records.append(rec)
        return rec

    @property
    def straggler_steps(self) -> List[int]:
        return [r.step for r in self.records if r.straggler]

    def summary(self) -> dict:
        if not self._durations:
            return {"steps": 0}
        ds = self._durations
        return {
            "steps": len(ds),
            "mean_s": sum(ds) / len(ds),
            "median_s": statistics.median(ds),
            "max_s": max(ds),
            "stragglers": len(self.straggler_steps),
        }


class Watchdog:
    """Fires ``on_hang`` if ``pet()`` is not called within ``deadline_s``."""

    def __init__(self, deadline_s: float,
                 on_hang: Optional[Callable[[], None]] = None):
        self.deadline_s = deadline_s
        self.on_hang = on_hang
        self._last = time.monotonic()
        self._stop = threading.Event()
        self.fired = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def pet(self):
        self._last = time.monotonic()

    def _run(self):
        while not self._stop.wait(min(self.deadline_s / 4, 1.0)):
            if time.monotonic() - self._last > self.deadline_s:
                self.fired = True
                if self.on_hang:
                    self.on_hang()
                self._last = time.monotonic()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=5)
