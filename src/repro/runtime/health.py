"""Step-time health monitoring: straggler / hang detection.

In SPMD data-parallel training a straggling host slows every step (the
collectives synchronize), so detection is: robust per-step timing stats and
a policy hook.  ``StepMonitor`` keeps a rolling window, flags steps slower
than ``threshold x median`` (straggler) and exposes a deadline watchdog
(hang -> the restart loop in runtime/elastic.py, or — in the serve stack —
``ContinuousEngine``'s watchdog recovery, takes over).  At real multi-host
scale the same monitor runs per host and the flags are aggregated through
the (out-of-band) coordination service; the policy and statistics are
identical.

Memory discipline: a serve loop calls ``observe`` once per compiled call,
forever.  The monitor therefore keeps only the rolling ``window`` of
records/durations on hand (the straggler baseline never needed more) while
``summary()`` reports *cumulative* counts from O(1) accumulators — a
long-running server's monitors stay constant-size.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    straggler: bool


class StepMonitor:
    def __init__(self, window: int = 50, straggler_factor: float = 2.0,
                 warmup_steps: int = 3):
        self.window = window
        self.factor = straggler_factor
        self.warmup = warmup_steps
        # Rolling views (trimmed to ``window``)...
        self.records: List[StepRecord] = []
        self._durations: List[float] = []
        # ...and cumulative accumulators for summary().
        self.total_steps = 0
        self.total_time_s = 0.0
        self.total_stragglers = 0
        self.max_s = 0.0

    def observe(self, step: Optional[int] = None,
                seconds: float = 0.0) -> StepRecord:
        """Record one step; ``step`` defaults to the cumulative count."""
        if step is None:
            step = self.total_steps
        baseline = self._durations[-self.window:]
        is_straggler = False
        if len(baseline) >= self.warmup:
            med = statistics.median(baseline)
            is_straggler = seconds > self.factor * med
        self._durations.append(seconds)
        rec = StepRecord(step, seconds, is_straggler)
        self.records.append(rec)
        # Constant-memory rolling window (satellite fix: these two lists
        # previously grew forever under a long-running serve loop).
        if len(self._durations) > self.window:
            del self._durations[:-self.window]
            del self.records[:-self.window]
        self.total_steps += 1
        self.total_time_s += seconds
        self.max_s = max(self.max_s, seconds)
        if is_straggler:
            self.total_stragglers += 1
        return rec

    @property
    def straggler_steps(self) -> List[int]:
        """Straggler step indices within the rolling window (cumulative
        count: ``summary()['stragglers']``)."""
        return [r.step for r in self.records if r.straggler]

    def summary(self) -> dict:
        """Cumulative stats (count/mean/max/stragglers over every step
        ever observed) + the rolling window's median."""
        if not self.total_steps:
            return {"steps": 0}
        return {
            "steps": self.total_steps,
            "mean_s": self.total_time_s / self.total_steps,
            "median_s": statistics.median(self._durations),
            "max_s": self.max_s,
            "stragglers": self.total_stragglers,
        }


class Watchdog:
    """Fires ``on_hang`` if ``pet()`` is not called within ``deadline_s``.

    The callback fires at most ONCE per hang: after a fire the watchdog
    latches until the next ``pet()`` (i.e. until some step completes
    again), so a slow recovery path is not re-entered by its own trigger.
    ``fired`` stays True once any hang was ever detected; the latch is
    internal re-fire suppression.
    """

    def __init__(self, deadline_s: float,
                 on_hang: Optional[Callable[[], None]] = None):
        self.deadline_s = deadline_s
        self.on_hang = on_hang
        self._last = time.monotonic()
        self._stop = threading.Event()
        self.fired = False
        self._latched = False       # fired for the CURRENT hang; pet() clears
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def pet(self):
        self._last = time.monotonic()
        self._latched = False

    def _run(self):
        while not self._stop.wait(min(self.deadline_s / 4, 1.0)):
            if self._latched:
                continue            # same hang: recovery still running
            if time.monotonic() - self._last > self.deadline_s:
                self.fired = True
                self._latched = True
                if self.on_hang:
                    self.on_hang()

    @property
    def alive(self) -> bool:
        """Whether the watchdog thread is still running (False after a
        successful ``stop()`` join)."""
        return self._thread.is_alive()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=5)
