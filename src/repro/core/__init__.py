"""XAMBA core: the paper's techniques as composable JAX modules."""
from repro.core.xamba import XambaConfig  # noqa: F401
from repro.core import pwl, reduce, segsum, selective_scan, ssd  # noqa: F401
