"""ActiBA: piecewise-linear activation approximation (the NPU PLU/C-LUT analogue).

The NPU's Piecewise-Linear Unit evaluates ``f(x) ~= m_k * x + c_k`` on interval
``[x_k, x_{k+1}]`` from a configurable lookup table of slopes/intercepts.  TPUs
have no LUT datapath, so we evaluate the *same* piecewise-linear function in a
gather-free basis form that is exact for continuous PWL functions:

    f(x) = m_0 * x + c_0 + sum_k (m_k - m_{k-1}) * relu(x - b_k)

which is K fused multiply-adds + maxes on the VPU — and, crucially, fusable
into a producing matmul's epilogue (``kernels/matmul_pwl.py``), reproducing
the paper's drain-phase "vertical fusion".

Tables are built at trace time with numpy (compile-time constants, like the
paper's compile-time C-LUT programming), with either uniform breakpoints or
curvature-adaptive ones (knot density ~ integral of sqrt(|f''|), Flex-SFU
style), which cuts max error by ~an order of magnitude at equal K.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PWLTable:
    """Compile-time C-LUT: interior breakpoints + per-segment slope/intercept.

    ``breakpoints`` has K-1 entries for K segments; segment 0 covers
    ``(-inf, b_0]`` and segment K-1 covers ``(b_{K-2}, inf)`` (linear
    extension outside the fitted range, as the PLU does).
    """

    name: str
    breakpoints: Tuple[float, ...]  # ascending, length K-1
    slopes: Tuple[float, ...]       # length K
    intercepts: Tuple[float, ...]   # length K

    @property
    def num_segments(self) -> int:
        return len(self.slopes)

    # Basis-form coefficients (precomputed once).
    def basis(self) -> Tuple[np.ndarray, float, float]:
        m = np.asarray(self.slopes, np.float64)
        dm = m[1:] - m[:-1]                      # (K-1,)
        return dm, float(m[0]), float(self.intercepts[0])


# ----------------------------------------------------------------------------
# Fitting
# ----------------------------------------------------------------------------

def _uniform_knots(lo: float, hi: float, segments: int) -> np.ndarray:
    return np.linspace(lo, hi, segments + 1)


def _adaptive_knots(fn: Callable[[np.ndarray], np.ndarray], lo: float,
                    hi: float, segments: int, grid: int = 4097) -> np.ndarray:
    """Knot density proportional to sqrt(|f''|) (equalizes per-segment error)."""
    xs = np.linspace(lo, hi, grid)
    h = xs[1] - xs[0]
    ys = fn(xs)
    d2 = np.gradient(np.gradient(ys, h), h)
    w = np.sqrt(np.abs(d2)) + 1e-6          # avoid zero density on flat spans
    cdf = np.concatenate([[0.0], np.cumsum((w[1:] + w[:-1]) * 0.5 * h)])
    cdf /= cdf[-1]
    targets = np.linspace(0.0, 1.0, segments + 1)
    knots = np.interp(targets, cdf, xs)
    knots[0], knots[-1] = lo, hi
    # De-duplicate pathological collisions.
    for i in range(1, len(knots)):
        if knots[i] <= knots[i - 1]:
            knots[i] = knots[i - 1] + 1e-6
    return knots


def fit_pwl(fn: Callable[[np.ndarray], np.ndarray], *, name: str,
            lo: float = -10.0, hi: float = 10.0, segments: int = 32,
            adaptive: bool = True) -> PWLTable:
    """Fit a continuous interpolating PWL table to ``fn`` on ``[lo, hi]``."""
    knots = (_adaptive_knots(fn, lo, hi, segments) if adaptive
             else _uniform_knots(lo, hi, segments))
    ys = fn(knots)
    slopes, intercepts = [], []
    for k in range(segments):
        x0, x1 = knots[k], knots[k + 1]
        y0, y1 = ys[k], ys[k + 1]
        m = (y1 - y0) / (x1 - x0)
        slopes.append(float(m))
        intercepts.append(float(y0 - m * x0))
    return PWLTable(name=name, breakpoints=tuple(float(b) for b in knots[1:-1]),
                    slopes=tuple(slopes), intercepts=tuple(intercepts))


# ----------------------------------------------------------------------------
# Evaluation (gather-free basis form; jit/Pallas friendly)
# ----------------------------------------------------------------------------

def eval_pwl(table: PWLTable, x: Array) -> Array:
    """Evaluate the PWL function; exact for the table's piecewise-linear fn."""
    dm, m0, c0 = table.basis()
    out_dtype = x.dtype
    xf = x.astype(jnp.float32)
    y = m0 * xf + c0
    bps = np.asarray(table.breakpoints, np.float32)
    for k in range(dm.shape[0]):
        y = y + np.float32(dm[k]) * jnp.maximum(xf - bps[k], 0.0)
    return y.astype(out_dtype)


def eval_pwl_reference(table: PWLTable, x: np.ndarray) -> np.ndarray:
    """Segment-indexed (LUT-style) numpy evaluation — the literal NPU PLU."""
    bps = np.asarray(table.breakpoints, np.float64)
    idx = np.searchsorted(bps, x, side="right")
    m = np.asarray(table.slopes, np.float64)[idx]
    c = np.asarray(table.intercepts, np.float64)[idx]
    return m * x + c


# ----------------------------------------------------------------------------
# Error analysis (used by the Table-1 quality benchmark and property tests)
# ----------------------------------------------------------------------------

def pwl_error(fn: Callable[[np.ndarray], np.ndarray], table: PWLTable,
              lo: float | None = None, hi: float | None = None,
              n: int = 100_001) -> Dict[str, float]:
    lo = table.breakpoints[0] - 1.0 if lo is None else lo
    hi = table.breakpoints[-1] + 1.0 if hi is None else hi
    xs = np.linspace(lo, hi, n)
    exact = fn(xs)
    approx = eval_pwl_reference(table, xs)
    err = np.abs(exact - approx)
    denom = np.maximum(np.abs(exact), 1e-3)
    return {"max_abs": float(err.max()),
            "mean_abs": float(err.mean()),
            "max_rel": float((err / denom).max())}


# ----------------------------------------------------------------------------
# The activations the paper targets (+ the ones the assigned archs need)
# ----------------------------------------------------------------------------

def _np_sigmoid(x):
    return 1.0 / (1.0 + np.exp(-np.clip(x, -60, 60)))


def _np_silu(x):
    return x * _np_sigmoid(x)


def _np_softplus(x):
    return np.logaddexp(0.0, x)


def _np_gelu_tanh(x):
    return 0.5 * x * (1.0 + np.tanh(np.sqrt(2.0 / np.pi) * (x + 0.044715 * x ** 3)))


_NP_FNS: Dict[str, Callable] = {
    "silu": _np_silu,
    "softplus": _np_softplus,
    "gelu": _np_gelu_tanh,
    "sigmoid": _np_sigmoid,
}

_EXACT_FNS: Dict[str, Callable] = {
    "silu": jax.nn.silu,
    "softplus": jax.nn.softplus,
    "gelu": partial(jax.nn.gelu, approximate=True),
    "sigmoid": jax.nn.sigmoid,
}

_TABLE_CACHE: Dict[Tuple, PWLTable] = {}


def get_table(name: str, *, segments: int = 32, lo: float = -10.0,
              hi: float = 10.0, adaptive: bool = True) -> PWLTable:
    key = (name, segments, lo, hi, adaptive)
    if key not in _TABLE_CACHE:
        _TABLE_CACHE[key] = fit_pwl(_NP_FNS[name], name=name, lo=lo, hi=hi,
                                    segments=segments, adaptive=adaptive)
    return _TABLE_CACHE[key]


def numpy_fn(name: str) -> Callable[[np.ndarray], np.ndarray]:
    return _NP_FNS[name]


def activation(name: str, xamba=None) -> Callable[[Array], Array]:
    """Return ``name``'s activation under the given XambaConfig.

    With ``actiba`` enabled this is the PWL approximation (ActiBA);
    otherwise the exact function.
    """
    if xamba is not None and getattr(xamba, "actiba", False):
        table = get_table(name, segments=xamba.actiba_segments,
                          lo=xamba.actiba_range[0], hi=xamba.actiba_range[1],
                          adaptive=xamba.actiba_adaptive)
        return partial(eval_pwl, table)
    return _EXACT_FNS[name]
