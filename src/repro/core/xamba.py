"""XAMBA technique configuration.

The paper's three optimization families are exposed as a single frozen config
that is threaded through every model / layer that contains a remappable op:

* ``cumba``   — how cumulative sums / segment sums are computed
                (``naive`` = sequential-semantics cumsum, the NPU-DSP baseline;
                ``cumba`` = lower-triangular-mask matmul on the MXU;
                ``pallas`` = the Pallas kernel; ``pallas_interpret`` for CPU).
* ``reduba``  — how reductions / einsum contractions are computed
                (``naive`` = broadcast-multiply + ReduceSum, the baseline the
                paper measured through OpenVINO; ``reduba`` = dot_general /
                ones-matvec on the MXU; ``pallas`` = the Pallas kernel).
* ``actiba``  — whether expensive activations (SiLU/Swish, Softplus, GeLU,
                sigmoid) are replaced by piecewise-linear approximations
                (the NPU PLU/C-LUT analogue), and with how many segments.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

CUMSUM_MODES = ("naive", "cumba", "pallas", "pallas_interpret")
REDUCE_MODES = ("naive", "reduba", "pallas", "pallas_interpret")
DECODE_MODES = ("naive", "cumba", "pallas", "pallas_interpret")
# Multi-token prefill pipeline (conv + SiLU + softplus(dt) + SSD chunk scan
# + gated norm in one pass): ``naive`` = the historical unfused op chain
# (projection -> conv -> segsum -> chunk scan -> gate, each a separate XLA
# op group); ``cumba`` = the fused-structure single-pass XLA pipeline
# (``kernels/prefill_chunk.py: mamba2_prefill_xla``); ``pallas`` = the
# one-kernel Pallas pipeline (``_interpret`` runs it on CPU).  Applies to
# the SSD (mamba2) family; other mixers keep their existing prefill path.
PREFILL_MODES = ("naive", "cumba", "pallas", "pallas_interpret")
# Weight quantization (paper Step-3's precision trade, serving-backend
# form): ``none`` = fp weights; ``w8`` = int8 per-channel weights executed
# via dot_general-on-int8 (portable XLA path); ``w8_pallas`` = the fused
# dequant-matmul kernel (``kernels/qmatmul.py``; ``_interpret`` on CPU).
QUANT_MODES = ("none", "w8", "w8_pallas", "w8_pallas_interpret")


@dataclasses.dataclass(frozen=True)
class XambaConfig:
    """Technique flags for the XAMBA operator remappings."""

    # Step-2a: CumSum -> triangular matmul (paper Fig. 2c, "CumBA").
    cumba: str = "cumba"
    # Step-2b: ReduceSum -> MXU contraction (paper Fig. 2c, "ReduBA").
    reduba: str = "reduba"
    # Single-token decode step: ``naive`` = broadcast-mul + ReduceSum chains
    # (the dense NPU-baseline op structure), ``cumba`` = fused MXU remap,
    # ``pallas`` = the fused decode-step kernel (``kernels/decode_step.py``).
    decode: str = "cumba"
    # Multi-token prefill: ``naive`` = unfused op chain, ``cumba`` = fused
    # single-pass XLA pipeline, ``pallas`` = the one-kernel prefill
    # pipeline (``kernels/prefill_chunk.py``).
    prefill: str = "cumba"
    # Step-3: activations -> piecewise-linear (paper Fig. 2e, "ActiBA").
    actiba: bool = False
    actiba_segments: int = 32
    actiba_range: Tuple[float, float] = (-10.0, 10.0)
    # Non-uniform, curvature-adaptive breakpoints (Flex-SFU style) vs uniform.
    actiba_adaptive: bool = True
    # W8 weight-only quantization mode (``nn/quant.py``).  The mode names
    # how quantized weights *execute*; quantization itself happens to the
    # params pytree once, via ``quant.quantize_params_for_mode``.
    quant: str = "none"

    def __post_init__(self):
        if self.quant not in QUANT_MODES:
            raise ValueError(f"quant mode {self.quant!r} not in {QUANT_MODES}")
        if self.cumba not in CUMSUM_MODES:
            raise ValueError(f"cumba mode {self.cumba!r} not in {CUMSUM_MODES}")
        if self.reduba not in REDUCE_MODES:
            raise ValueError(f"reduba mode {self.reduba!r} not in {REDUCE_MODES}")
        if self.decode not in DECODE_MODES:
            raise ValueError(f"decode mode {self.decode!r} not in {DECODE_MODES}")
        if self.prefill not in PREFILL_MODES:
            raise ValueError(
                f"prefill mode {self.prefill!r} not in {PREFILL_MODES}")
        if self.actiba_segments < 2:
            raise ValueError("actiba_segments must be >= 2")

    # ---- presets -----------------------------------------------------------
    @classmethod
    def baseline(cls) -> "XambaConfig":
        """The unoptimized NPU-style execution (paper's baseline)."""
        return cls(cumba="naive", reduba="naive", decode="naive",
                   prefill="naive", actiba=False)

    @classmethod
    def optimized(cls) -> "XambaConfig":
        """CumBA + ReduBA (paper step-2, exact numerics)."""
        return cls(cumba="cumba", reduba="reduba", decode="cumba",
                   prefill="cumba", actiba=False)

    @classmethod
    def full(cls, segments: int = 32) -> "XambaConfig":
        """CumBA + ReduBA + ActiBA (paper step-2 + step-3)."""
        return cls(cumba="cumba", reduba="reduba", decode="cumba",
                   prefill="cumba", actiba=True, actiba_segments=segments)

    @classmethod
    def pallas(cls, interpret: bool = False) -> "XambaConfig":
        """Kernel-backed variants (TPU target; interpret=True on CPU)."""
        mode = "pallas_interpret" if interpret else "pallas"
        return cls(cumba=mode, reduba=mode, decode=mode, prefill=mode,
                   actiba=True)
