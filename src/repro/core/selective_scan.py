"""Mamba-1 selective scan (the paper's other profiled model).

Mamba-1's NPU bottleneck is its activations (Swish/Softplus -> ActiBA), not
cumsum; the scan itself is a per-channel linear recurrence

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * u_t ,   y_t = C_t . h_t + D u_t

which we implement three ways:

* ``associative``  — ``jax.lax.associative_scan`` (log-depth, XLA),
* ``sequential``   — ``jax.lax.scan`` oracle (exact reference),
* ``chunked``      — CumBA-style: within a chunk the decay products
                     ``prod_{k=j+1..t} a_k = exp(segsum(log a))`` are the same
                     1-semiseparable structure SSD uses, so the intra-chunk
                     part becomes matmuls (this is the Mamba-1 analogue of the
                     paper's CumSum->MatMul remap; it is exact in fp32).

``initial_state`` + ``return_final_state`` make every mode resumable:
feeding a sequence in slices, threading each call's final ``h`` into the
next call, matches one whole-sequence call (chunked prefill — see
``models/base.py: DecodeAPI.prefill_chunk``).

Shapes (Mamba-1 convention):
  u:     (batch, seqlen, dinner)
  delta: (batch, seqlen, dinner)   -- post-softplus
  A:     (dinner, dstate)          -- negative
  B, C:  (batch, seqlen, dstate)
  D:     (dinner,)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import segsum as xsegsum
from repro.core.xamba import XambaConfig

Array = jax.Array


def selective_scan(u: Array, delta: Array, A: Array, B: Array, C: Array,
                   D: Optional[Array] = None, *,
                   mode: str = "associative",
                   chunk_size: int = 128,
                   initial_state: Optional[Array] = None,
                   xamba: XambaConfig = XambaConfig(),
                   return_final_state: bool = False):
    """Returns y: (b, l, d) [and final state (b, d, n)]."""
    b, l, d = u.shape
    n = A.shape[-1]
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    # Discretize (ZOH on A, Euler on B as in Mamba).
    dA = df[..., None] * Af[None, None]                    # (b, l, d, n) log-decay
    dBu = (df * uf)[..., None] * Bf[:, :, None, :]         # (b, l, d, n)

    h0 = (jnp.zeros((b, d, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    if mode == "sequential":
        def step(h, t_in):
            dA_t, dBu_t, C_t = t_in
            h = jnp.exp(dA_t) * h + dBu_t
            y = jnp.einsum("bdn,bn->bd", h, C_t)
            return h, y
        ins = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBu, 1, 0),
               jnp.moveaxis(Cf, 1, 0))
        hT, ys = jax.lax.scan(step, h0, ins)
        y = jnp.moveaxis(ys, 0, 1)
    elif mode == "associative":
        decay = jnp.exp(dA)                                # (b, l, d, n)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        a_sc, h_sc = jax.lax.associative_scan(
            combine, (decay, dBu), axis=1)
        h_all = h_sc + a_sc * h0[:, None]                  # fold initial state
        y = jnp.einsum("bldn,bln->bld", h_all, Cf)
        hT = h_all[:, -1]
    elif mode == "chunked":
        # Pad to a chunk multiple with dt=0 steps (decay=1, input=0): exact
        # no-ops for outputs and final state, so any prefill-chunk length
        # works (mirrors core/ssd.py).
        l_orig = l
        pad = (-l) % chunk_size
        if pad:
            dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dBu = jnp.pad(dBu, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
            l = l + pad
        c = l // chunk_size
        # (b, c, L, d, n)
        dA_c = dA.reshape(b, c, chunk_size, d, n)
        dBu_c = dBu.reshape(b, c, chunk_size, d, n)
        C_c = Cf.reshape(b, c, chunk_size, n)
        # intra-chunk: h_t = sum_j exp(segsum)(t,j) dBu_j  (+ carry term)
        a_perm = jnp.transpose(dA_c, (0, 1, 3, 4, 2))      # (b, c, d, n, L)
        S = xsegsum.segsum(a_perm, mode=xamba.cumba)       # (b, c, d, n, L, L)
        Lmat = jnp.exp(S)
        h_intra = jnp.einsum("bcdnts,bcsdn->bctdn", Lmat, dBu_c)
        # chunk-level recurrence on the running state
        cum = xsegsum.cumsum(a_perm, axis=-1, mode=xamba.cumba)  # (b,c,d,n,L)
        chunk_decay = jnp.exp(cum[..., -1])                # (b, c, d, n)
        chunk_state = h_intra[:, :, -1]                    # (b, c, d, n)

        def step(h, t_in):
            cd, cs = t_in
            return cd * h + cs, h                          # emit state *entering* chunk

        (hT, h_enter) = jax.lax.scan(
            step, h0, (jnp.moveaxis(chunk_decay, 1, 0),
                       jnp.moveaxis(chunk_state, 1, 0)))
        h_enter = jnp.moveaxis(h_enter, 0, 1)              # (b, c, d, n)
        decay_in = jnp.exp(cum)                            # (b, c, d, n, L)
        h_all = h_intra + jnp.transpose(decay_in, (0, 1, 4, 2, 3)) * h_enter[:, :, None]
        y = jnp.einsum("bctdn,bctn->bctd", h_all, C_c)
        y = y.reshape(b, l, d)[:, :l_orig]
    else:
        raise ValueError(f"unknown selective_scan mode {mode!r}")

    if D is not None:
        y = y + uf * D.astype(jnp.float32)[None, None]
    y = y.astype(u.dtype)
    if return_final_state:
        return y, hT
    return y


def selective_scan_decode_step(state: Array, u_t: Array, delta_t: Array,
                               A: Array, B_t: Array, C_t: Array,
                               D: Optional[Array] = None, *,
                               mode: str = "cumba") -> Tuple[Array, Array]:
    """One-token recurrent update, XambaConfig-dispatched (``naive`` =
    mul + ReduceSum, ``cumba`` = MXU dot_general, ``pallas*`` = the fused
    Pallas step kernel).  state: (b, d, n); u_t, delta_t: (b, d);
    B_t, C_t: (b, n)."""
    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.sscan_step(state, u_t, delta_t, A, B_t, C_t, D,
                               interpret=(mode == "pallas_interpret"))
    dtf = delta_t.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * A.astype(jnp.float32)[None])
    dBu = (dtf * u_t.astype(jnp.float32))[..., None] * B_t.astype(jnp.float32)[:, None, :]
    new_state = state.astype(jnp.float32) * decay + dBu
    Cf = C_t.astype(jnp.float32)
    if mode == "naive":
        y = jnp.sum(new_state * Cf[:, None, :], axis=-1)
    else:
        y = jnp.einsum("bdn,bn->bd", new_state, Cf,
                       preferred_element_type=jnp.float32)
    if D is not None:
        y = y + u_t.astype(jnp.float32) * D.astype(jnp.float32)[None]
    return new_state, y.astype(u_t.dtype)
