"""CumBA: cumulative sums / segment sums as triangular-mask matmuls.

The paper's dominant Mamba-2 bottleneck (``CumSum_b``, >99.9% of cumsum time)
is the masked cumulative sum inside SSD's ``segsum`` — a (T, T) op per chunk
per head.  On the NPU the DSP executes it in m sequential vector-adds; CumBA
re-expresses it as ``C = M_CumBA @ X`` with a compile-time lower-triangular
mask so it lands on the MAC array.

On TPU the same split exists: ``jnp.cumsum`` lowers to a serial/reduce-window
form on the VPU, while the masked-matmul form engages the 128x128 MXU.  Modes:

* ``naive``            — ``jnp.cumsum`` (the DSP-like baseline).
* ``cumba``            — triangular-mask matmul (MXU), XLA-lowered.
* ``pallas``           — the Pallas kernel (``kernels/cumba.py``): blocked,
                         carries a running prefix so upper-triangle blocks are
                         *never scheduled* (the static-skip analogue of ZVC).
* ``pallas_interpret`` — same kernel, interpreter mode (CPU validation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_NEG_INF = -1e30  # used instead of -inf so exp() never sees nan from inf-inf


def _tri_mask(t: int, dtype) -> Array:
    """The compile-time CumBA mask: M[i, j] = 1 if j <= i else 0."""
    return jnp.tril(jnp.ones((t, t), dtype=dtype))


def cumsum(x: Array, axis: int = -1, mode: str = "cumba") -> Array:
    """Cumulative sum along ``axis`` under a CumBA mode."""
    if mode == "naive":
        return jnp.cumsum(x, axis=axis)
    x = jnp.moveaxis(x, axis, -1)
    t = x.shape[-1]
    if mode == "cumba":
        acc = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype
        mask = _tri_mask(t, x.dtype)
        out = jax.lax.dot_general(
            x, mask, (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=acc).astype(x.dtype)
    elif mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        out = kops.cumba_cumsum(x, interpret=(mode == "pallas_interpret"))
    else:
        raise ValueError(f"unknown cumsum mode {mode!r}")
    return jnp.moveaxis(out, -1, axis)


def segsum(a: Array, mode: str = "cumba") -> Array:
    """Segment sum over the trailing axis.

    ``segsum(a)[..., i, j] = sum_{k=j+1..i} a[..., k]`` for ``i >= j`` and
    ``-inf`` (well, ``_NEG_INF``) above the diagonal — i.e. the log of the
    1-semiseparable decay matrix ``L`` in SSD.

    * ``naive`` is the official Mamba-2 Listing-1 formulation: broadcast ``a``
      to (T, T), mask strictly-lower, masked cumsum down the rows — this is
      exactly the paper's ``CumSum_b`` (a (T, T) cumsum).
    * ``cumba``/``pallas`` compute the prefix sum with the triangular matmul
      and take broadcasted differences: ``S_ij = cs_i - cs_j``.
    """
    t = a.shape[-1]
    if mode == "naive":
        x = jnp.broadcast_to(a[..., :, None], a.shape + (t,))  # x[..., k, j] = a_k
        mask = jnp.tril(jnp.ones((t, t), bool), -1)            # keep k > j  (strict lower in (k, j))
        x = jnp.where(mask, x, 0.0)
        s = jnp.cumsum(x, axis=-2)                             # over k -> cs[..., i, j] = sum_{k<=i, k>j} a_k
        out = jnp.where(jnp.tril(jnp.ones((t, t), bool)), s, _NEG_INF)
        return out
    elif mode in ("cumba", "pallas", "pallas_interpret"):
        cs = cumsum(a.astype(jnp.float32), axis=-1,
                    mode="cumba" if mode == "cumba" else mode)
        out = cs[..., :, None] - cs[..., None, :]
        return jnp.where(jnp.tril(jnp.ones((t, t), bool)), out, _NEG_INF)
    raise ValueError(f"unknown segsum mode {mode!r}")


def decay_matrix(a: Array, mode: str = "cumba") -> Array:
    """``L = exp(segsum(a))`` — the semiseparable decay matrix."""
    return jnp.exp(segsum(a, mode=mode))
