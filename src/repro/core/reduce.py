"""ReduBA: reductions as MXU contractions.

On the NPU, ``ReduceSum`` over an m x n tensor costs m sequential DSP cycles;
ReduBA reformulates it as ``R = M_ReduBA @ X`` with an all-ones vector mask so
it runs on the MAC array, reusing the same mask for every call.

On TPU the analogue is: a plain ``jnp.sum`` (and the mul+ReduceSum chains that
naive einsum implementations produce) run on the VPU, while a ones-vector
``dot_general`` engages the MXU.  The framework-level consequence — which is
how the paper's insight generalizes — is that *contractions should always be
expressed as dot_generals, never as broadcast-multiply + sum*.  ``contract``
below is the mode-switched einsum used by the SSD implementation: ``naive``
deliberately lowers to mul+ReduceSum (the measured NPU baseline), ``reduba``
lowers to dot_general.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def reduce_sum(x: Array, axis: int = 0, mode: str = "reduba") -> Array:
    """Sum over one axis under a ReduBA mode."""
    if mode == "naive":
        return jnp.sum(x, axis=axis)
    x_moved = jnp.moveaxis(x, axis, -1)
    m = x_moved.shape[-1]
    if mode == "reduba":
        acc = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype
        ones = jnp.ones((m,), dtype=x.dtype)  # M_ReduBA, reused everywhere
        return jax.lax.dot_general(
            x_moved, ones, (((x_moved.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=acc).astype(x.dtype)
    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.reduba_sum(x_moved, interpret=(mode == "pallas_interpret"))
    raise ValueError(f"unknown reduce mode {mode!r}")


# ----------------------------------------------------------------------------
# Mode-switched einsum
# ----------------------------------------------------------------------------

_SPEC_RE = re.compile(r"^([a-zA-Z]+),([a-zA-Z]+)->([a-zA-Z]+)$")


def contract(spec: str, lhs: Array, rhs: Array, mode: str = "reduba",
             precision=None) -> Array:
    """Two-operand einsum that either uses the MXU (``reduba``) or a
    broadcast-multiply + ReduceSum chain (``naive`` — the paper's baseline).

    Both paths are numerically equivalent up to accumulation order; ``naive``
    exists so benchmarks can measure exactly the op structure the paper
    profiled on the NPU.
    """
    m = _SPEC_RE.match(spec.replace(" ", ""))
    if not m:
        raise ValueError(f"contract() wants 'ab,bc->ac' style spec, got {spec!r}")
    if mode in ("reduba", "pallas", "pallas_interpret"):
        # dot_general path: let XLA pick MXU-friendly contractions.
        return jnp.einsum(spec, lhs, rhs, precision=precision,
                          preferred_element_type=jnp.float32).astype(
                              jnp.result_type(lhs, rhs))
    if mode != "naive":
        raise ValueError(f"unknown contract mode {mode!r}")
    lterms, rterms, oterms = m.group(1), m.group(2), m.group(3)
    contracted = sorted((set(lterms) | set(rterms)) - set(oterms))
    # Build a common broadcast frame: output dims then contracted dims.
    frame = oterms + "".join(contracted)

    def align(x, terms):
        # Permute x's dims into frame order, then insert size-1 dims.
        order = sorted(range(len(terms)), key=lambda i: frame.index(terms[i]))
        x = jnp.transpose(x, order)
        present, xi, shape = set(terms), 0, []
        for c in frame:
            if c in present:
                shape.append(x.shape[xi])
                xi += 1
            else:
                shape.append(1)
        return x.reshape(shape)

    lb = align(lhs, lterms)
    rb = align(rhs, rterms)
    prod = (lb.astype(jnp.float32) * rb.astype(jnp.float32))
    # ReduceSum over each contracted dim — the NPU-style op chain.
    for _ in contracted:
        prod = jnp.sum(prod, axis=-1)
    return prod.astype(jnp.result_type(lhs, rhs))


def mean(x: Array, axis: int = -1, mode: str = "reduba") -> Array:
    n = x.shape[axis]
    return reduce_sum(x, axis=axis, mode=mode) / np.float32(n)
