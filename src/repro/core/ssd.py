"""Mamba-2 SSD (structured state-space duality), technique-parameterized.

Faithful to Listing 1 of Dao & Gu (2024) — the exact algorithm the paper
profiles on the NPU — with every XAMBA remapping exposed:

* the in-chunk ``segsum`` (the paper's dominant ``CumSum_b`` bottleneck) runs
  in ``naive`` / ``cumba`` / ``pallas`` mode (see ``core/segsum.py``);
* every contraction runs in ``naive`` (mul + ReduceSum — the op chain the NPU
  compiler produced and the paper measured) or ``reduba`` (dot_general / MXU)
  mode (see ``core/reduce.py``);
* a fully fused Pallas intra-chunk kernel (``kernels/ssd_chunk.py``) is used
  when ``cumba`` mode is ``pallas*`` and shapes allow.

Shapes follow the Mamba-2 convention:
  x:  (batch, seqlen, nheads, headdim)        -- values
  dt: (batch, seqlen, nheads)                 -- softplus'd step sizes
  A:  (nheads,)                                -- negative decay rates
  B:  (batch, seqlen, ngroups, dstate)        -- input projection (like K)
  C:  (batch, seqlen, ngroups, dstate)        -- output projection (like Q)

All SSD internals run in float32 (segsum differences are cancellation-prone);
inputs/outputs keep the caller's dtype.

``initial_state`` + ``return_final_state`` make the inter-chunk recurrence
resumable: feeding a sequence in slices, threading each call's final state
into the next call's ``initial_state``, is numerically equivalent to one
whole-sequence call (the serve engines' chunked prefill is exactly this —
see ``models/base.py: DecodeAPI.prefill_chunk``).
"""
from __future__ import annotations

import logging
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import reduce as xreduce
from repro.core import segsum as xsegsum
from repro.core.xamba import XambaConfig

Array = jax.Array

log = logging.getLogger("repro.ssd")


def _split_chunks(x: Array, chunk: int) -> Array:
    b, l = x.shape[0], x.shape[1]
    assert l % chunk == 0, f"seqlen {l} not divisible by chunk {chunk}"
    return x.reshape((b, l // chunk, chunk) + x.shape[2:])


def _merge_chunks(x: Array) -> Array:
    b, c, l = x.shape[:3]
    return x.reshape((b, c * l) + x.shape[3:])


def ssd(x: Array, dt: Array, A: Array, B: Array, C: Array, *,
        chunk_size: int = 256,
        initial_state: Optional[Array] = None,
        xamba: XambaConfig = XambaConfig(),
        return_final_state: bool = False,
        matmul_dtype=None,
        ) -> Array | Tuple[Array, Array]:
    """Chunked SSD forward pass. Returns y: (batch, seqlen, nheads, headdim)
    and optionally the final state (batch, nheads, headdim, dstate)."""
    in_dtype = x.dtype
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert h % g == 0, f"nheads {h} not divisible by ngroups {g}"

    # Pad the sequence to a chunk multiple: dt=0 on padded steps makes them
    # exact no-ops for both the outputs we keep and the final state.
    l_orig = l
    pad = (-l) % chunk_size
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (t.ndim - 2))
        x, dt, B, C = zpad(x), zpad(dt), zpad(B), zpad(C)
        l = l + pad

    cs_mode, rd_mode = xamba.cumba, xamba.reduba
    store_dtype = matmul_dtype or jnp.float32

    # Discretize: per-step log decay and dt-scaled input.  The wide value /
    # B / C streams are stored in ``matmul_dtype`` (bf16 in perf mode —
    # halves the dominant HBM traffic); decays stay fp32 (cancellation).
    dt_f = dt.astype(jnp.float32)
    a = dt_f * A.astype(jnp.float32)[None, None, :]        # (b, l, h), negative
    xdt = (x.astype(jnp.float32) * dt_f[..., None]).astype(store_dtype)

    # Chunk: (b, c, L, ...)
    a_c = _split_chunks(a, chunk_size)                      # (b, c, L, h)
    a_c = jnp.transpose(a_c, (0, 3, 1, 2))                  # (b, h, c, L)
    x_c = _split_chunks(xdt, chunk_size)                    # (b, c, L, h, p)
    B_c = _split_chunks(B.astype(store_dtype), chunk_size)  # (b, c, L, g, n)
    C_c = _split_chunks(C.astype(store_dtype), chunk_size)  # (b, c, L, g, n)

    hpg = h // g  # heads per group

    # Chunk-parallel layout (distributed): pin the CHUNK axis onto the mesh's
    # sequence axes so each device owns whole chunks — the intra-chunk pass
    # (the L x L work, the paper's CumSum_b home) then runs with ZERO
    # collectives instead of XLA re-sharding (b, seq) slices chunk-by-chunk.
    from repro.distributed import api as dist_api
    lay = dist_api.current_layout()
    chunk_parallel = lay is not None and lay.get("seq") is not None and \
        (l // chunk_size) > 1
    if chunk_parallel:
        x_c = dist_api.constrain_dims(x_c, {0: "batch", 1: "seq"})
        B_c = dist_api.constrain_dims(B_c, {0: "batch", 1: "seq"})
        C_c = dist_api.constrain_dims(C_c, {0: "batch", 1: "seq"})
        a_c = dist_api.constrain_dims(a_c, {0: "batch", 2: "seq"})

    A_cum = xsegsum.cumsum(a_c, axis=-1, mode=cs_mode)      # (b, h, c, L)

    # ---- 1+2. intra-chunk (diagonal blocks) + per-chunk states -----------
    # Heads are processed GROUPED (b, g, hpg, ...) so the group-shared CB
    # scores broadcast against per-head decays instead of being materialized
    # hpg times (beyond-paper optimization; algebraically identical).
    mm_dtype = matmul_dtype or jnp.float32

    def _intra(x_k, a_k, cs_k, B_k, C_k):
        """One chunk: x (b,L,h,p), a/cs (b,h,L), B/C (b,L,g,n) ->
        (y_diag (b,L,h,p), states (b,h,p,n))."""
        bq, Lk = x_k.shape[0], x_k.shape[1]
        seg = cs_k[..., :, None] - cs_k[..., None, :]       # (b, h, L, L)
        tril = jnp.tril(jnp.ones((seg.shape[-1],) * 2, bool))
        if cs_mode == "naive":
            seg = xsegsum.segsum(a_k, mode="naive")
        L_mat = jnp.exp(jnp.where(tril, seg, -1e30))        # (b, h, L, L)
        L_g = L_mat.reshape(bq, g, hpg, Lk, Lk).astype(mm_dtype)
        CB = xreduce.contract("blgn,bsgn->bgls", C_k.astype(mm_dtype),
                              B_k.astype(mm_dtype), mode=rd_mode)
        M = CB[:, :, None] * L_g                            # (b, g, q, L, S)
        x_r = x_k.reshape(bq, Lk, g, hpg, -1).astype(mm_dtype)
        y_k = xreduce.contract("bgqls,bsgqp->blgqp", M, x_r, mode=rd_mode)
        y_k = y_k.reshape(bq, Lk, h, -1).astype(jnp.float32)
        dstates = jnp.exp(cs_k[..., -1:] - cs_k)            # (b, h, L)
        xw = x_r * jnp.transpose(dstates, (0, 2, 1)) \
            .reshape(bq, Lk, g, hpg)[..., None].astype(mm_dtype)
        st_k = xreduce.contract("blgn,blgqp->bgqpn", B_k.astype(mm_dtype),
                                xw, mode=rd_mode)
        st_k = st_k.reshape(bq, h, st_k.shape[-2], n).astype(jnp.float32)
        return y_k, st_k

    nchunks_ = l // chunk_size
    # Stream chunks through a scan only when NOT chunk-parallel: with the
    # chunk axis sharded, the batched path is already one-chunk-per-device
    # memory AND avoids serializing across the mesh.
    use_scan = nchunks_ > 8 and not chunk_parallel
    # 64-multiples are MXU-viable (the compiler pads the (L, L) decay
    # block's lane dim); below that the padding overhead wins, so fall
    # back to the XLA chain — loudly, at trace time, so a pallas request
    # never silently runs unfused.
    use_kernel = cs_mode in ("pallas", "pallas_interpret")
    if use_kernel and chunk_size % 64:
        log.info("ssd_chunk kernel (%s) skipped: chunk %d not a multiple "
                 "of 64 — running the XLA chain", cs_mode, chunk_size)
        use_kernel = False
    if use_kernel:
        from repro.kernels import ops as kops
        y_diag, states = kops.ssd_chunk(
            x_c, a_c, A_cum, B_c, C_c,
            interpret=(cs_mode == "pallas_interpret"))
    elif use_scan:
        xs = (jnp.moveaxis(x_c, 1, 0), jnp.moveaxis(a_c, 2, 0),
              jnp.moveaxis(A_cum, 2, 0), jnp.moveaxis(B_c, 1, 0),
              jnp.moveaxis(C_c, 1, 0))

        @jax.checkpoint
        def body(_, blk):
            return None, _intra(*blk)

        from repro.core import accounting
        _, (y_st, st_st) = jax.lax.scan(
            body, None, xs, unroll=accounting.inner_unroll(nchunks_))
        y_diag = jnp.moveaxis(y_st, 0, 1)                   # (b, c, L, h, p)
        states = jnp.moveaxis(st_st, 0, 1)                  # (b, c, h, p, n)
    else:
        # batched over chunks: same math as _intra with a chunk axis.
        xs_all = (x_c, jnp.moveaxis(a_c, 2, 1), jnp.moveaxis(A_cum, 2, 1),
                  B_c, C_c)
        y_diag, states = jax.vmap(_intra, in_axes=(1, 1, 1, 1, 1),
                                  out_axes=(1, 1))(*xs_all)

    # ---- 3. inter-chunk recurrence (sequential over chunks) --------------
    nchunks = states.shape[1]
    chunk_decay_log = A_cum[..., -1]                        # (b, h, c) total decay per chunk
    if initial_state is None:
        init = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        init = initial_state.astype(jnp.float32)

    # Associative scan over chunks: s_c = exp(d_c) * s_{c-1} + states_c.
    decays = jnp.exp(chunk_decay_log)                       # (b, h, c)
    dec_t = jnp.moveaxis(decays, -1, 0)                     # (c, b, h)
    st_t = jnp.moveaxis(states, 1, 0)                       # (c, b, h, p, n)

    def combine(carry, nxt):
        d1, s1 = carry
        d2, s2 = nxt
        return d1 * d2, s1 * d2[..., None, None] + s2

    dec_scan, st_scan = jax.lax.associative_scan(combine, (dec_t, st_t), axis=0)
    # states *entering* chunk c = scanned state of chunk c-1 (+ decayed init).
    prev_states = jnp.concatenate([init[None], st_scan[:-1]], axis=0)
    if initial_state is not None and nchunks > 1:
        prev_states = prev_states.at[1:].add(
            init[None] * dec_scan[:-1][..., None, None])
    final_state = st_scan[-1]
    if initial_state is not None:
        final_state = final_state + init * dec_scan[-1][..., None, None]
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (b, c, h, p, n)

    # ---- 4. state -> output ----------------------------------------------
    state_decay_out = jnp.exp(A_cum)                        # (b, h, c, L)
    # grouped: C (b,c,L,g,n) x states (b,c,g,q,p,n) -> (b,c,L,g,q,p)
    ps_g = prev_states.reshape(b, nchunks, g, hpg, p, n).astype(mm_dtype)
    y_off = xreduce.contract("bclgn,bcgqpn->bclgqp", C_c.astype(mm_dtype),
                             ps_g, mode=rd_mode)
    y_off = y_off.reshape(b, nchunks, chunk_size, h, p).astype(jnp.float32)
    sdo = jnp.transpose(state_decay_out, (0, 2, 3, 1))      # (b, c, L, h)
    y_off = y_off * sdo[..., None]

    y = _merge_chunks(y_diag + y_off).astype(in_dtype)
    if pad:
        y = y[:, :l_orig]
    if return_final_state:
        return y, final_state.astype(jnp.float32)
    return y


def ssd_reference(x, dt, A, B, C, *, initial_state=None):
    """O(L) sequential recurrence oracle (exact semantics, slow).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), hpg, axis=2)  # (b, l, h, n)
    Cf = jnp.repeat(C.astype(jnp.float32), hpg, axis=2)

    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

    def step(state, t_in):
        xt, dtt, Bt, Ct = t_in                      # (b,h,p), (b,h), (b,h,n) x2
        decay = jnp.exp(dtt * Af[None, :])          # (b, h)
        dBx = (dtt[..., None, None] * Bt[:, :, None, :] * xt[..., None])
        state = state * decay[..., None, None] + dBx
        yt = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, yt

    ins = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
           jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    final, ys = jax.lax.scan(step, state0, ins)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssd_decode_step(state: Array, x_t: Array, dt_t: Array, A: Array,
                    B_t: Array, C_t: Array, *,
                    mode: str = "cumba") -> Tuple[Array, Array]:
    """Single-token recurrent update (the paper's Step-1 decode model),
    XambaConfig-dispatched like the prefill path:

    * ``naive``  — broadcast-multiply + ReduceSum chains (the dense op
      structure the NPU compiler produced and the paper measured);
    * ``cumba``  — the state->output contraction as one MXU ``dot_general``
      over grouped heads (no materialized B/C head-repeat);
    * ``pallas`` / ``pallas_interpret`` — the fused Pallas step kernel
      (``kernels/decode_step.py``).

    state: (b, h, p, n); x_t: (b, h, p); dt_t: (b, h);
    B_t, C_t: (b, g, n).  Returns (new_state, y_t: (b, h, p)).
    """
    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.ssd_step(state, x_t, dt_t, A, B_t, C_t,
                             interpret=(mode == "pallas_interpret"))
    b, h, p, n = state.shape
    g = B_t.shape[1]
    hpg = h // g
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None, :])   # (b, h)
    # Grouped layout: B/C broadcast against per-head streams instead of
    # being materialized h/g times (matches the prefill path's grouping).
    st_g = state.astype(jnp.float32).reshape(b, g, hpg, p, n)
    x_g = x_t.astype(jnp.float32).reshape(b, g, hpg, p)
    dt_g = dtf.reshape(b, g, hpg)
    Bf = B_t.astype(jnp.float32)                            # (b, g, n)
    Cf = C_t.astype(jnp.float32)
    dBx = (dt_g[..., None] * x_g)[..., None] * Bf[:, :, None, None, :]
    new_g = st_g * decay.reshape(b, g, hpg)[..., None, None] + dBx
    if mode == "naive":
        y_g = xreduce.contract("bgqpn,bgn->bgqp", new_g, Cf, mode="naive")
    else:
        y_g = xreduce.contract("bgqpn,bgn->bgqp", new_g, Cf, mode="reduba")
    new_state = new_g.reshape(b, h, p, n)
    return new_state, y_g.reshape(b, h, p).astype(x_t.dtype)
