"""Accounting mode: unroll inner lax.scans for exact HLO cost analysis.

``cost_analysis()`` counts a while-loop body once, so any scan hides
(trip_count - 1)x of its FLOPs and collective bytes.  The dry-run therefore
compiles each train cell twice:

  * rolled (production config, scans intact)  -> memory_analysis
  * unrolled (this flag on, scans expanded)   -> cost_analysis + collectives

Model code consults ``inner_unroll(n)`` when building its scans.
"""
from __future__ import annotations

import contextlib
import contextvars

_UNROLL: contextvars.ContextVar = contextvars.ContextVar(
    "unroll_inner_scans", default=False)


@contextlib.contextmanager
def unroll_inner_scans():
    token = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def inner_unroll(n_steps: int) -> int:
    """The ``unroll=`` argument for an inner lax.scan of n_steps."""
    return n_steps if _UNROLL.get() else 1
