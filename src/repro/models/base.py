"""Model configuration (all families) and shared loss/metrics utilities."""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.xamba import XambaConfig

Array = jax.Array


def chunk_positions(index, batch: int, seq: int):
    """(b, s) absolute positions for a prefill chunk whose first token sits
    at ``index`` (``()`` or ``(b,)`` int32)."""
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.full((batch,), idx)
    return idx[:, None] + jnp.arange(seq, dtype=jnp.int32)[None, :]


class DecodeAPI:
    """The serving surface every model family implements:

    * ``prefill(params, batch, cache) -> (last_logits, cache)`` — run the
      chunked/parallel form over the whole prompt at once and emit the
      recurrent state;
    * ``prefill_chunk(params, tokens, cache, index) -> (logits, cache)``
      — one fixed-size slice of the prompt, carrying state across calls:
      SSM state + conv tail (Mamba), RG-LRU ``h``, and KV rows appended at
      ``index`` (attention).  ``index`` is ``()`` or ``(b,)`` int32 — the
      number of tokens each row has already consumed; feeding a prompt
      chunk-by-chunk is numerically equivalent to one ``prefill`` call
      (≤ 1e-5 fp32, greedy-identical continuations).  This is what lets
      the continuous engine admit long prompts incrementally instead of
      stalling the decode wave on a monolithic prefill.  (Whisper's
      override mirrors its ``prefill`` and takes the ``{"tokens",
      "frames"}`` batch dict instead of a bare token array — like its
      whole-sequence prefill, it is not servable by the token-only
      engines);
    * ``decode_step(params, token, cache, index) -> (logits, cache)`` —
      the O(1) cached-state step (``index``: ``()`` or ``(b,)`` int32);
    * ``export_state(cache, index, rows)`` / ``import_state(cache, index,
      rows, snapshot)`` — host-side snapshot / restore of cache rows over
      the same pytrees ``prefill_chunk`` carries (SSM state + conv tail,
      RG-LRU ``h``, KV rows clipped to the ``index``-token prefix) — the
      prefix-state cache's primitives (``docs/prefix_cache.md``).

    ``apply`` is a deprecation shim for the pre-split call signature
    (``model.apply(params, tokens, state=...)``); external callers should
    migrate to the explicit trio above.
    """

    def prefill_chunk(self, params, tokens, cache, index):
        raise NotImplementedError(
            f"{type(self).__name__} does not implement prefill_chunk")

    def verify_chunk(self, params, tokens, cache, index):
        """``prefill_chunk`` with per-position logits: ``(b, s, vocab)``
        instead of the last position only — one batched call scores every
        token of a speculative draft window against the full-precision
        stream (``serve/speculative.py``).  Families implement it by
        re-entering their chunk trunk and skipping the ``x[:, -1]``
        slice, so state carry semantics are identical to prefill_chunk."""
        raise NotImplementedError(
            f"{type(self).__name__} does not implement verify_chunk")

    def speculative_step(self, params_draft, params_verify, token, cache,
                         index, k: int):
        """One self-speculative burst, functional and host-driven: draft
        ``k`` greedy tokens with ``params_draft`` on a throwaway copy of
        the state, verify them in one ``verify_chunk`` call with
        ``params_verify``, emit the longest verified prefix plus one
        correction token, and repair rolled-back rows by re-advancing the
        pre-burst snapshot through ``decode_step`` (the reference
        semantics the continuous engine's compiled burst must match; see
        ``serve/speculative.py`` for the accept rule).

        ``token``: ``(b, 1)`` pending next-input tokens; ``index``: ``()``
        or ``(b,)`` consumed-token counts.  Returns ``(emitted, n_emit,
        cache, new_index)`` — ``emitted`` is ``(b, k)`` int32 with only
        the first ``n_emit[i]`` entries of row ``i`` meaningful, and
        ``new_index = index + n_emit`` per row.  ``cache`` is treated
        functionally (not donated): the caller's argument stays valid.
        """
        from repro.serve.speculative import accept_lengths, emit_counts, \
            needs_rollback
        if k < 1:
            raise ValueError(f"speculative_step needs k >= 1, got {k}")
        tok0 = np.asarray(token, np.int32).reshape(-1)
        b = tok0.shape[0]
        idx = np.asarray(index, np.int32)
        if idx.ndim == 0:
            idx = np.full((b,), idx, np.int32)

        # Draft pass: decode_step is functional here (no donation), so
        # ``cache`` itself survives as the pre-burst snapshot.
        dcache = cache
        cur = tok0
        drafts = np.zeros((b, k), np.int32)
        for j in range(k):
            logits, dcache = self.decode_step(
                params_draft, jnp.asarray(cur[:, None]), dcache,
                jnp.asarray(idx + j))
            cur = np.argmax(np.asarray(logits, np.float32),
                            axis=-1).astype(np.int32)
            drafts[:, j] = cur

        # Verify pass: one chunk over [t0, d_1 .. d_{k-1}].
        vtoks = np.empty((b, k), np.int32)
        vtoks[:, 0] = tok0
        if k > 1:
            vtoks[:, 1:] = drafts[:, :k - 1]
        vlogits, vcache = self.verify_chunk(
            params_verify, jnp.asarray(vtoks), cache, jnp.asarray(idx))
        verify = np.argmax(np.asarray(vlogits, np.float32),
                           axis=-1).astype(np.int32)

        m = accept_lengths(drafts, verify)
        n_emit = emit_counts(m, k).astype(np.int32)
        # Rolled-back rows: re-advance the pre-burst row state over the
        # tokens the emitted stream actually consumed — [t0, g_0 ..
        # g_{n-2}] — through the full-precision decode step, exactly the
        # non-speculative trajectory.
        for i in np.nonzero(needs_rollback(m, k))[0]:
            snap = self.export_state(cache, None, [int(i)])
            rcache = jax.tree.map(jnp.asarray, snap)
            consume = [int(tok0[i])] + \
                [int(verify[i, j]) for j in range(int(n_emit[i]) - 1)]
            for j, t in enumerate(consume):
                _, rcache = self.decode_step(
                    params_verify, jnp.asarray([[t]], jnp.int32), rcache,
                    jnp.asarray(int(idx[i]) + j, jnp.int32))
            vcache = self.import_state(
                vcache, None, [int(i)], self.export_state(rcache, None, [0]))
        return verify, n_emit, vcache, idx + n_emit

    # ---------------- state snapshot / restore ----------------
    #
    # The inverse pair over the same pytrees ``prefill_chunk`` carries:
    # ``export_state`` gathers cache rows out as a host-side snapshot
    # (the prefix cache's unit of storage, ``serve/prefix_cache.py``),
    # ``import_state`` scatters a snapshot back into cache rows.  The
    # device work is the same jitted row gather/scatter the serve pools
    # use (``serve/state_pool.py: make_row_ops``) — one compiled program
    # per cache layout, row indices traced, never touching the donated
    # arenas except to scatter into them — while the per-family
    # clipping (``_clip_snapshot`` / ``_unclip_snapshot``) runs on the
    # host copy, so a varying ``index`` never retraces anything.

    def cache_batch_axes(self, cache):
        """Pytree of ints matching ``cache``: every leaf's batch axis
        (the layout rule ``state_pool.infer_batch_axes`` probes for,
        stated structurally per family)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not implement cache_batch_axes")

    def _clip_snapshot(self, snapshot, axes, index):
        """Drop state past the ``index``-token prefix from a host snapshot
        (byte honesty for length-proportional state; see the transformer
        override).  Default: recurrent state is O(1) — keep everything."""
        del axes, index
        return snapshot

    def _unclip_snapshot(self, snapshot, axes, index, like):
        """Inverse of ``_clip_snapshot``: rebuild full-size rows (zeros
        past the prefix — exactly what an in-place prefill would have
        left there) so the row scatter stays one compiled program."""
        del axes, index, like
        return snapshot

    def _state_row_ops(self, cache):
        """(gather, scatter) jitted row ops for this family's cache
        layout, built once per model instance (jit re-specializes per
        cache shape, e.g. pool-vs-test batch sizes, on its own)."""
        ops = getattr(self, "_state_row_ops_cache", None)
        if ops is None:
            from repro.serve.state_pool import make_row_ops
            scatter, gather, _ = make_row_ops(self.cache_batch_axes(cache))
            ops = self._state_row_ops_cache = (gather, scatter)
        return ops

    def export_state(self, cache, index, rows):
        """Host-side snapshot of ``rows``' state after ``index`` consumed
        tokens: a pytree shaped like ``cache`` with batch ``len(rows)``
        and length-proportional leaves (attention KV) clipped to the
        valid prefix (``index=None`` keeps full rows).  The gather runs
        off the live arena — the snapshot's lifetime is independent of
        any later donation of ``cache``."""
        gather, _ = self._state_row_ops(cache)
        axes = self.cache_batch_axes(cache)
        parts = [gather(cache, jnp.int32(r)) for r in rows]
        snap = parts[0] if len(parts) == 1 else jax.tree.map(
            lambda ax, *ls: jnp.concatenate(ls, axis=ax), axes, *parts)
        return self._clip_snapshot(jax.device_get(snap), axes, index)

    def import_state(self, cache, index, rows, snapshot):
        """Scatter snapshot row ``j`` into ``cache`` row ``rows[j]`` —
        the exact inverse of :meth:`export_state` over the same pytrees.
        ``cache`` is DONATED (like every serve-pool row op): callers must
        rebind the return value and drop the argument."""
        _, scatter = self._state_row_ops(cache)
        axes = self.cache_batch_axes(cache)
        full = self._unclip_snapshot(snapshot, axes, index, cache)
        full = jax.tree.map(jnp.asarray, full)
        for j, r in enumerate(rows):
            cache = scatter(cache, full, jnp.int32(j), jnp.int32(r))
        return cache

    def decode_view(self, params):
        """Decode-optimized *view* of ``params``: scan-stacked layer
        pytrees are pre-sliced into per-layer tuples ONCE (outside the
        jitted program).  XLA materializes a fresh copy of every sliced
        weight on each call when the slice happens in-program, so the
        serving engines build this view at init and feed it to the decode
        program; parameter *storage* (checkpoints, training, prefill)
        stays stacked.  Families without a stacked ``layers`` trunk
        return ``params`` unchanged (RecurrentGemma overrides for its
        group-stacked layout)."""
        layers_p = params.get("layers") if isinstance(params, dict) else None
        if layers_p is None or not getattr(self.cfg, "scan_layers", False) \
                or isinstance(layers_p, tuple):
            return params
        return dict(params, layers=tuple(
            jax.tree.map(lambda a: a[i], layers_p)
            for i in range(self.cfg.n_layers)))

    def apply(self, params, tokens, state=None, index=None):
        warnings.warn(
            "model.apply(state=...) is deprecated; call model.prefill() / "
            "model.decode_step() explicitly (see docs/architecture.md)",
            DeprecationWarning, stacklevel=2)
        batch = tokens if isinstance(tokens, dict) else {"tokens": tokens}
        toks = batch["tokens"]
        if state is None:
            fwd = getattr(self, "forward", None)
            if fwd is None:
                raise TypeError(
                    f"{type(self).__name__}.apply() without state= has no "
                    "stateless equivalent; use loss()/prefill() instead")
            return fwd(params, toks)
        if toks.shape[1] == 1:
            if index is None:
                # Defaulting to position 0 would silently misplace KV rows
                # for attention-bearing families; make the caller say it.
                raise TypeError(
                    "apply(state=...) with a single token dispatches to "
                    "decode_step and needs index= (the token's position)")
            return self.decode_step(params, toks, state, index)
        return self.prefill(params, batch, state)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config type for every assigned architecture family."""

    name: str = "model"
    family: str = "transformer"   # transformer | mamba | mamba2 |
    #                               recurrentgemma | whisper
    vocab_size: int = 32000
    d_model: int = 512
    n_layers: int = 4

    # -- attention ----------------------------------------------------------
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None
    attn_probs_bf16: bool = False  # cast softmax probs to bf16 before PV

    # -- mlp ------------------------------------------------------------------
    d_ff: int = 2048
    mlp_type: str = "swiglu"      # swiglu | geglu | mlp

    # -- norms / embeddings ---------------------------------------------------
    norm_type: str = "rmsnorm"    # rmsnorm | gemma_rmsnorm | layernorm
    embed_scale: bool = False     # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True

    # -- MoE ------------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    n_experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_renormalize: bool = True
    moe_aux_weight: float = 0.01
    # Pin the dispatch buffers' capacity dim to the batch axes.  Helps when
    # the expert count cannot shard over "model" (grok-1: 8 experts vs 16) —
    # without it XLA gathers the buffers to every device; HURTS when experts
    # are model-sharded (qwen3: 128) by fighting the natural EP layout.
    moe_cap_batch_sharding: bool = False

    # -- SSM (mamba / mamba2) -------------------------------------------------
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    chunk_size: int = 256
    dt_rank: int = 0              # 0 -> ceil(d_model/16) (mamba1)
    scan_mode: str = "associative"
    ssd_dtype: str = "float32"    # SSD big-matmul dtype (bf16 = perf mode)

    # -- recurrentgemma ---------------------------------------------------------
    lru_width: int = 0
    block_pattern: Tuple[str, ...] = ()   # e.g. ("recurrent","recurrent","attention")

    # -- multimodal stubs -------------------------------------------------------
    frontend: Optional[str] = None        # vision_stub | audio_stub
    num_patches: int = 0                  # llava: image token count
    encoder_layers: int = 0               # whisper
    encoder_seq: int = 1500               # whisper frame count

    # -- execution policies -----------------------------------------------------
    param_dtype: str = "bfloat16"
    remat: str = "none"                   # none | full | dots
    scan_layers: bool = True
    use_flash: bool = False               # Pallas flash attention
    flash_interpret: bool = False
    force_prefill_path: bool = False
    logical_rules: Tuple[Tuple[str, Tuple[str, ...]], ...] = ()

    xamba: XambaConfig = XambaConfig()

    # convenience -----------------------------------------------------------
    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_decode_mode(self, mode: str) -> "ModelConfig":
        """Config with ``XambaConfig.decode`` overridden (CLI plumbing)."""
        return self.replace(xamba=dataclasses.replace(self.xamba,
                                                      decode=mode))

    def with_prefill_mode(self, mode: str) -> "ModelConfig":
        """Config with ``XambaConfig.prefill`` overridden (CLI plumbing):
        how the multi-token SSD prefill pipeline executes."""
        return self.replace(xamba=dataclasses.replace(self.xamba,
                                                      prefill=mode))

    def with_quant(self, mode: str) -> "ModelConfig":
        """Config with ``XambaConfig.quant`` overridden (CLI plumbing);
        pair with ``nn.quant.quantize_params_for_mode`` on the params."""
        return self.replace(xamba=dataclasses.replace(self.xamba,
                                                      quant=mode))


def cross_entropy_loss(logits: Array, labels: Array,
                       mask: Optional[Array] = None,
                       z_loss: float = 1e-4) -> Tuple[Array, dict]:
    """Token-level CE with optional z-loss; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    if mask is not None:
        valid = jnp.logical_and(valid, mask > 0)
    labels_safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
    acc = jnp.sum(jnp.where(
        valid, (jnp.argmax(logits, -1) == labels_safe).astype(jnp.float32),
        0.0)) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
