from repro.models.base import ModelConfig, cross_entropy_loss  # noqa: F401
from repro.models.registry import build_model  # noqa: F401
