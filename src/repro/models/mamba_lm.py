"""Mamba-1 and Mamba-2 language models — the paper's profiled subjects.

Block = RMSNorm -> mixer (selective scan / SSD) -> residual, as in the
reference implementations; Mamba-2's extra post-skip norm is the mixer's
internal gated RMSNorm.  Serving follows the paper's Step-1: prefill runs
the chunked parallel form and emits the recurrent state; decode is the O(1)
recurrence with conv + SSM state caches (static shapes throughout).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import api as dist_api
from repro.models import base
from repro.nn import layers, ssm
from repro.nn.params import stack_specs

Array = jax.Array


class MambaLM(base.DecodeAPI):
    """family == "mamba" (v1, selective scan) or "mamba2" (SSD)."""

    def __init__(self, cfg: base.ModelConfig):
        assert cfg.family in ("mamba", "mamba2"), cfg.family
        self.cfg = cfg
        self.v2 = cfg.family == "mamba2"

    # ---------------- specs ----------------
    def _mixer_specs(self):
        return (ssm.mamba2_specs(self.cfg) if self.v2
                else ssm.mamba1_specs(self.cfg))

    def param_specs(self) -> dict:
        cfg = self.cfg
        block = {
            "ln": layers.norm_specs(cfg.d_model),
            "mixer": self._mixer_specs(),
        }
        specs: Dict[str, Any] = {
            "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),
            "final_norm": layers.norm_specs(cfg.d_model),
        }
        if cfg.scan_layers:
            specs["layers"] = stack_specs(block, cfg.n_layers)
        else:
            specs["layers"] = {str(i): block for i in range(cfg.n_layers)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = layers.linear_specs(
                cfg.d_model, cfg.vocab_size, axes=("embed", "vocab"))
        return specs

    # ---------------- trunk ----------------
    def _mixer_apply(self, p, x, state):
        if self.v2:
            return ssm.mamba2_apply(p, self.cfg, x, state)
        return ssm.mamba1_apply(p, self.cfg, x, state)

    def _block(self, p, x, state):
        h, new_state = self._mixer_apply(p["mixer"], layers.norm(p["ln"], x),
                                         state)
        return x + h, new_state

    def _trunk(self, params, x, states=None):
        cfg = self.cfg
        block = self._block
        if cfg.remat in ("full", "dots"):
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            block = jax.checkpoint(block, policy=policy)

        if cfg.scan_layers and isinstance(params["layers"], tuple):
            # Decode view: layer weights are pre-sliced buffers; only the
            # (small) stacked states are sliced/restacked in-program.
            ns = []
            for i, p_i in enumerate(params["layers"]):
                st_i = jax.tree.map(lambda a: a[i], states)
                x, n_i = block(p_i, x, st_i)
                x = dist_api.shard_tokens3d(x)
                ns.append(n_i)
            new_states = jax.tree.map(lambda *ls: jnp.stack(ls), *ns)
        elif cfg.scan_layers:
            def body(x, xs):
                p, state = xs
                y, new_state = block(p, x, state)
                y = dist_api.shard_tokens3d(y)
                return y, new_state
            # Decode (one token) fully unrolls the layer scan: one trace of
            # the stacked pytree (no per-layer Python dispatch) and no
            # XLA while-loop overhead per generated token.  ``naive``
            # decode mode keeps the rolled scan, matching the program
            # structure decode had before the fused path existed (the
            # benchmark baseline; its step math is the paper's
            # mul+ReduceSum chain, see nn/ssm.py).
            unroll = (True if x.shape[1] == 1 and
                      cfg.xamba.decode != "naive" else 1)
            x, new_states = jax.lax.scan(body, x, (params["layers"], states),
                                         unroll=unroll)
        else:
            new_states = []
            for i in range(cfg.n_layers):
                state = None if states is None else states[i]
                x, ns = block(params["layers"][str(i)], x, state)
                new_states.append(ns)
        return x, new_states

    def _trunk_train(self, params, x):
        cfg = self.cfg

        def block(p, x):
            y, _ = self._block(p, x, None)
            return y

        if cfg.remat in ("full", "dots"):
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            block = jax.checkpoint(block, policy=policy)

        if cfg.scan_layers:
            def body(x, p):
                return dist_api.shard_tokens3d(block(p, x)), None
            x, _ = jax.lax.scan(body, x, params["layers"])
        else:
            for i in range(cfg.n_layers):
                x = block(params["layers"][str(i)], x)
        return x

    def _logits(self, params, x) -> Array:
        x = layers.norm(params["final_norm"], x)
        if self.cfg.tie_embeddings:
            return layers.unembed(params["embed"], x)
        return layers.linear(params["lm_head"], x).astype(jnp.float32)

    # ---------------- training ----------------
    def loss(self, params, batch) -> Tuple[Array, dict]:
        x = dist_api.shard_tokens3d(layers.embed(params["embed"], batch["tokens"]))
        x = self._trunk_train(params, x)
        logits = self._logits(params, x)
        loss, metrics = base.cross_entropy_loss(
            logits[:, :-1], batch["labels"][:, 1:])
        metrics["loss_total"] = loss
        return loss, metrics

    def forward(self, params, tokens) -> Array:
        """Full-sequence logits (used by quality/equivalence benchmarks)."""
        x = layers.embed(params["embed"], tokens)
        x = self._trunk_train(params, x)
        return self._logits(params, x)

    # ---------------- serving ----------------
    def init_cache(self, batch: int, max_seq: int = 0, dtype=jnp.bfloat16):
        cfg = self.cfg
        del max_seq  # SSM state is O(1) in sequence length
        one = (ssm.mamba2_init_state(cfg, batch, dtype) if self.v2
               else ssm.mamba1_init_state(cfg, batch, dtype))
        if cfg.scan_layers:
            return jax.tree.map(
                lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
        # Distinct buffers per layer: an aliased list (same arrays repeated)
        # cannot be donated into the jitted decode program.
        return [jax.tree.map(jnp.copy, one) for _ in range(cfg.n_layers)]

    def cache_batch_axes(self, cache):
        # Scan-stacked states are (n_layers, b, ...); per-layer lists are
        # (b, ...).  Either way the snapshot is O(1) in sequence length —
        # the whole point of prefix-state caching for SSMs.
        return jax.tree.map(lambda a: 1 if self.cfg.scan_layers else 0,
                            cache)

    def prefill(self, params, batch, cache) -> Tuple[Array, Any]:
        x = layers.embed(params["embed"], batch["tokens"])
        x, new_states = self._trunk(params, x, cache)
        return self._logits(params, x[:, -1]), new_states

    def prefill_chunk(self, params, tokens, cache, index) -> Tuple[Array, Any]:
        """One prompt slice with carried state.  ``index`` is accepted for
        API uniformity and ignored: the SSM recurrence and conv tail carry
        position in ``cache`` (the same O(1)-state property that makes SSM
        slots relocatable under continuous batching), so chunked prefill is
        just the whole-sequence path re-entered with the previous chunk's
        state — SSD resumes through its inter-chunk recurrence
        (``initial_state``), selective scan through the carried ``h``."""
        del index
        x = layers.embed(params["embed"], tokens)
        x, new_states = self._trunk(params, x, cache)
        return self._logits(params, x[:, -1]), new_states

    def verify_chunk(self, params, tokens, cache, index) -> Tuple[Array, Any]:
        """``prefill_chunk`` with per-position logits (``(b, s, vocab)``):
        the speculative verifier scores a whole draft window in one call
        (``serve/speculative.py``).  Same trunk, same carried state —
        only the final-logits slice differs."""
        del index
        x = layers.embed(params["embed"], tokens)
        x, new_states = self._trunk(params, x, cache)
        return self._logits(params, x), new_states

    def decode_step(self, params, token, cache, index) -> Tuple[Array, Any]:
        """index: () or (b,) — accepted for engine uniformity and ignored;
        the recurrence carries position implicitly, which is why SSM slots
        are trivially relocatable under continuous batching."""
        del index
        x = layers.embed(params["embed"], token)
        x, new_states = self._trunk(params, x, cache)
        # Final norm + unembed on the squeezed (b, d) token — the batched
        # (b, 1, d) gemm is a pathological layout for single-token decode.
        return self._logits(params, x[:, 0]), new_states
