"""Whisper-style encoder-decoder transformer (audio frontend stubbed).

Per the assignment, the conv frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings (batch, enc_seq, d_model).  The encoder is a
bidirectional transformer; the decoder interleaves causal self-attention and
cross-attention to the encoded audio.  Sinusoidal positions (no RoPE),
LayerNorm, GELU MLPs — matching the Whisper family.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import api as dist_api
from repro.models import base
from repro.nn import attention, layers, mlp as mlp_mod

Array = jax.Array


class Whisper(base.DecodeAPI):
    def __init__(self, cfg: base.ModelConfig):
        self.cfg = cfg
        self.n_enc = cfg.encoder_layers or cfg.n_layers
        self.n_dec = cfg.n_layers

    # ---------------- specs ----------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        enc_block = {
            "ln_attn": layers.norm_specs(cfg.d_model, norm_type="layernorm"),
            "attn": attention.attention_specs(cfg),
            "ln_mlp": layers.norm_specs(cfg.d_model, norm_type="layernorm"),
            "mlp": mlp_mod.mlp_specs(cfg),
        }
        dec_block = {
            "ln_self": layers.norm_specs(cfg.d_model, norm_type="layernorm"),
            "self_attn": attention.attention_specs(cfg),
            "ln_cross": layers.norm_specs(cfg.d_model, norm_type="layernorm"),
            "cross_attn": attention.attention_specs(cfg),
            "ln_mlp": layers.norm_specs(cfg.d_model, norm_type="layernorm"),
            "mlp": mlp_mod.mlp_specs(cfg),
        }
        return {
            "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),
            "enc_ln_post": layers.norm_specs(cfg.d_model,
                                             norm_type="layernorm"),
            "dec_ln_post": layers.norm_specs(cfg.d_model,
                                             norm_type="layernorm"),
            "encoder": {str(i): enc_block for i in range(self.n_enc)},
            "decoder": {str(i): dec_block for i in range(self.n_dec)},
        }

    # ---------------- encoder ----------------
    def encode(self, params, frames: Array) -> Array:
        """frames: (b, enc_seq, d_model) — stub frontend output."""
        cfg = self.cfg
        pos = layers.sinusoidal_positions(frames.shape[1], cfg.d_model)
        x = frames + jnp.asarray(pos, frames.dtype)[None]
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        for i in range(self.n_enc):
            p = params["encoder"][str(i)]
            h, _ = attention.apply(
                p["attn"], cfg,
                layers.norm(p["ln_attn"], x, norm_type="layernorm"),
                positions=positions, causal=False)
            x = x + h
            x = dist_api.shard_tokens3d(x + mlp_mod.apply(
                p["mlp"], cfg,
                layers.norm(p["ln_mlp"], x, norm_type="layernorm")))
        return layers.norm(params["enc_ln_post"], x, norm_type="layernorm")

    # ---------------- decoder ----------------
    def _dec_trunk(self, params, x, positions, enc_out, caches=None,
                   cache_index=None):
        cfg = self.cfg
        new_caches: List[Any] = []
        for i in range(self.n_dec):
            p = params["decoder"][str(i)]
            cache = None if caches is None else caches[i]
            self_c = None if cache is None else cache["self"]
            cross_c = None if cache is None else cache["cross"]
            h, nsc = attention.apply(
                p["self_attn"], cfg,
                layers.norm(p["ln_self"], x, norm_type="layernorm"),
                positions=positions, cache=self_c, cache_index=cache_index,
                causal=True)
            x = x + h
            h, ncc = attention.apply(
                p["cross_attn"], cfg,
                layers.norm(p["ln_cross"], x, norm_type="layernorm"),
                positions=positions, cache=cross_c,
                cache_index=cache_index, kv_source=enc_out, is_cross=True)
            x = x + h
            x = dist_api.shard_tokens3d(x + mlp_mod.apply(
                p["mlp"], cfg,
                layers.norm(p["ln_mlp"], x, norm_type="layernorm")))
            new_caches.append(None if cache is None
                              else {"self": nsc, "cross": ncc})
        return x, new_caches

    def _dec_embed(self, params, tokens):
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens)
        return x  # positional added below with true offsets

    def _logits(self, params, x) -> Array:
        x = layers.norm(params["dec_ln_post"], x, norm_type="layernorm")
        return layers.unembed(params["embed"], x)

    # ---------------- training ----------------
    def loss(self, params, batch) -> Tuple[Array, dict]:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        pos_tab = layers.sinusoidal_positions(tokens.shape[1], cfg.d_model)
        x = self._dec_embed(params, tokens) + \
            jnp.asarray(pos_tab, cfg.dtype)[None]
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
        x, _ = self._dec_trunk(params, x, positions, enc_out)
        logits = self._logits(params, x)
        loss, metrics = base.cross_entropy_loss(
            logits[:, :-1], batch["labels"][:, 1:])
        metrics["loss_total"] = loss
        return loss, metrics

    # ---------------- serving ----------------
    def cache_batch_axes(self, cache):
        # Per-layer list of {"self", "cross"} KVCaches, batch axis 0.
        # (Whisper is not servable by the token-only engines, but the
        # snapshot API keeps the DecodeAPI surface uniform: the cross
        # cache is the audio-conditioned state a future multimodal serve
        # path would snapshot.)
        return jax.tree.map(lambda a: 0, cache)

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        caches = []
        for _ in range(self.n_dec):
            caches.append({
                "self": attention.init_cache(cfg, batch, max_seq, dtype),
                "cross": attention.init_cache(cfg, batch, cfg.encoder_seq,
                                              dtype),
            })
        return caches

    def prefill(self, params, batch, cache) -> Tuple[Array, Any]:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        pos_tab = layers.sinusoidal_positions(tokens.shape[1], cfg.d_model)
        x = self._dec_embed(params, tokens) + \
            jnp.asarray(pos_tab, cfg.dtype)[None]
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
        x, new_caches = self._dec_trunk(params, x, positions, enc_out,
                                        cache, cache_index=None)
        return self._logits(params, x[:, -1]), new_caches

    def prefill_chunk(self, params, batch, cache, index) -> Tuple[Array, Any]:
        """One decoder-prompt slice with carried self-attention KV state.

        ``batch`` is ``{"tokens": (b, s), "frames": ...}`` — the encoder
        (and the idempotent cross-attention cache write) reruns on every
        chunk because the stub frontend is cheap; a production path would
        encode once at admission and reuse the cross cache.  Self-attention
        appends at (per-row) ``index`` like the decoder-only families."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        positions = base.chunk_positions(index, *tokens.shape)
        x = self._dec_embed(params, tokens) + \
            layers.sinusoidal_positions_at(positions,
                                           cfg.d_model).astype(cfg.dtype)
        x, new_caches = self._dec_trunk(params, x, positions, enc_out,
                                        cache,
                                        cache_index=jnp.asarray(index,
                                                                jnp.int32))
        return self._logits(params, x[:, -1]), new_caches

    def decode_step(self, params, token, cache, index) -> Tuple[Array, Any]:
        cfg = self.cfg
        pos_emb = layers.sinusoidal_position_at(index, cfg.d_model)
        x = self._dec_embed(params, token) + \
            pos_emb.astype(cfg.dtype)[None, None, :]
        positions = jnp.full((token.shape[0], 1), index, jnp.int32)
        x, new_caches = self._dec_trunk(params, x, positions, None,
                                        cache, cache_index=index)
        # Squeezed (b, d) final norm + unembed (see models/mamba_lm.py).
        return self._logits(params, x[:, 0]), new_caches
