"""Model factory: family -> model class."""
from __future__ import annotations

from repro.models.base import ModelConfig
from repro.models.mamba_lm import MambaLM
from repro.models.recurrentgemma import RecurrentGemma
from repro.models.transformer import TransformerLM
from repro.models.whisper import Whisper

_FAMILIES = {
    "transformer": TransformerLM,
    "mamba": MambaLM,
    "mamba2": MambaLM,
    "recurrentgemma": RecurrentGemma,
    "whisper": Whisper,
}


def build_model(cfg: ModelConfig):
    try:
        cls = _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}; "
                         f"have {sorted(_FAMILIES)}") from None
    return cls(cfg)
