"""Decoder-only transformer LM: dense GQA/MQA, MoE, and VLM-stub variants.

Covers internlm2-20b, deepseek-7b, qwen1.5-4b, gemma-2b, llava-next
(mistral backbone + patch-embedding stub), qwen3-moe-30b-a3b and grok-1-314b
through config alone.  Homogeneous layers scan (bounded HLO at 512 devices);
remat policy wraps the scanned block.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import api as dist_api
from repro.models import base
from repro.nn import attention, layers, mlp as mlp_mod, moe as moe_mod
from repro.nn.params import ParamSpec, stack_specs

Array = jax.Array


def _block_specs(cfg) -> dict:
    specs = {
        "ln_attn": layers.norm_specs(cfg.d_model, norm_type=cfg.norm_type),
        "attn": attention.attention_specs(cfg),
        "ln_mlp": layers.norm_specs(cfg.d_model, norm_type=cfg.norm_type),
    }
    if cfg.moe:
        specs["moe"] = moe_mod.moe_specs(cfg)
    else:
        specs["mlp"] = mlp_mod.mlp_specs(cfg)
    return specs


def _block_apply(params, cfg, x, positions, cache, cache_index):
    h, new_cache = attention.apply(
        params["attn"], cfg, layers.norm(params["ln_attn"], x,
                                         norm_type=cfg.norm_type),
        positions=positions, cache=cache, cache_index=cache_index,
        causal=True, window=cfg.sliding_window)
    x = x + h
    hin = layers.norm(params["ln_mlp"], x, norm_type=cfg.norm_type)
    if cfg.moe:
        h, aux = moe_mod.apply(params["moe"], cfg, hin)
    else:
        h, aux = mlp_mod.apply(params["mlp"], cfg, hin), jnp.float32(0.0)
    return x + h, new_cache, aux


class TransformerLM(base.DecodeAPI):
    def __init__(self, cfg: base.ModelConfig):
        self.cfg = cfg

    # ---------------- specs ----------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),
            "final_norm": layers.norm_specs(cfg.d_model,
                                            norm_type=cfg.norm_type),
        }
        block = _block_specs(cfg)
        if cfg.scan_layers:
            specs["layers"] = stack_specs(block, cfg.n_layers)
        else:
            specs["layers"] = {str(i): block for i in range(cfg.n_layers)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = layers.linear_specs(
                cfg.d_model, cfg.vocab_size, axes=("embed", "vocab"))
        return specs

    # ---------------- embedding / logits ----------------
    def _embed_inputs(self, params, batch) -> Tuple[Array, Array, Array]:
        """Returns (x, positions, loss_mask-prefix-length)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = layers.embed(params["embed"], tokens)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        prefix = 0
        if cfg.frontend == "vision_stub" and "image_embeds" in batch:
            img = batch["image_embeds"].astype(x.dtype)   # (b, P, d)
            x = jnp.concatenate([img, x], axis=1)
            prefix = img.shape[1]
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x = dist_api.shard_tokens3d(x)
        return x, positions, prefix

    def _trunk(self, params, x, positions, caches=None, cache_index=None):
        cfg = self.cfg
        aux_total = jnp.float32(0.0)

        def block(p, x, cache):
            return _block_apply(p, cfg, x, positions, cache, cache_index)

        if cfg.remat in ("full", "dots"):
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            block = jax.checkpoint(block, policy=policy)

        if cfg.scan_layers and isinstance(params["layers"], tuple):
            # Decode view: pre-sliced layer weights, stacked caches
            # sliced/restacked in-program (see models/mamba_lm.py).
            ns = []
            for i, p_i in enumerate(params["layers"]):
                c_i = (None if caches is None
                       else jax.tree.map(lambda a: a[i], caches))
                x, n_i, a = block(p_i, x, c_i)
                x = dist_api.shard_tokens3d(x)
                aux_total += a
                ns.append(n_i)
            new_caches = (None if caches is None
                          else jax.tree.map(lambda *ls: jnp.stack(ls), *ns))
        elif cfg.scan_layers:
            def body(carry, xs):
                x, aux = carry
                p, cache = xs
                y, new_cache, a = block(p, x, cache)
                y = dist_api.shard_tokens3d(y)
                return (y, aux + a), new_cache
            # Fully unroll the layer scan at decode (see models/mamba_lm.py);
            # naive decode mode keeps the rolled pre-refactor scan.
            unroll = (True if x.shape[1] == 1 and
                      cfg.xamba.decode != "naive" else 1)
            (x, aux_total), new_caches = jax.lax.scan(
                body, (x, aux_total), (params["layers"], caches),
                unroll=unroll)
        else:
            new_caches = []
            for i in range(cfg.n_layers):
                cache = None if caches is None else caches[i]
                x, nc, a = block(params["layers"][str(i)], x, cache)
                aux_total += a
                new_caches.append(nc)
        return x, new_caches, aux_total

    def _logits(self, params, x) -> Array:
        cfg = self.cfg
        x = layers.norm(params["final_norm"], x, norm_type=cfg.norm_type)
        if cfg.tie_embeddings:
            logits = layers.unembed(params["embed"], x)
        else:
            logits = layers.linear(params["lm_head"], x).astype(jnp.float32)
        return logits

    # ---------------- training ----------------
    def loss(self, params, batch) -> Tuple[Array, dict]:
        cfg = self.cfg
        x, positions, prefix = self._embed_inputs(params, batch)
        if cfg.scan_layers:
            x, _, aux = self._trunk_train(params, x, positions)
        else:
            x, _, aux = self._trunk(params, x, positions)
        logits = self._logits(params, x)
        if prefix:
            logits = logits[:, prefix:]
        labels = batch["labels"]
        loss, metrics = base.cross_entropy_loss(logits[:, :-1], labels[:, 1:])
        if cfg.moe:
            loss = loss + cfg.moe_aux_weight * aux / cfg.n_layers
            metrics["moe_aux"] = aux / cfg.n_layers
        metrics["loss_total"] = loss
        return loss, metrics

    def _trunk_train(self, params, x, positions):
        """scan-over-layers without caches (cache pytree = None per layer)."""
        cfg = self.cfg

        def block(p, x):
            y, _, a = _block_apply(p, cfg, x, positions, None, None)
            return y, a

        if cfg.remat in ("full", "dots"):
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            block = jax.checkpoint(block, policy=policy)

        def body(carry, p):
            x, aux = carry
            y, a = block(p, x)
            y = dist_api.shard_tokens3d(y)
            return (y, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   params["layers"])
        return x, None, aux

    # ---------------- serving ----------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.sliding_window is not None:
            max_seq = min(max_seq, cfg.sliding_window)
        shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        if cfg.scan_layers:
            return attention.KVCache(
                jnp.zeros((cfg.n_layers,) + shape, dtype),
                jnp.zeros((cfg.n_layers,) + shape, dtype))
        return [attention.init_cache(cfg, batch, max_seq, dtype)
                for _ in range(cfg.n_layers)]

    def cache_batch_axes(self, cache):
        # KVCache leaves: (n_layers, b, T, nkv, hd) scan-stacked, (b, T,
        # nkv, hd) per-layer — batch axis 1 or 0, seq axis right after it.
        return jax.tree.map(lambda a: 1 if self.cfg.scan_layers else 0,
                            cache)

    def _clip_snapshot(self, snapshot, axes, index):
        """Keep only the valid KV prefix: a transformer's cached state is
        length-proportional, so honest snapshot byte accounting clips the
        seq axis to ``snapshot_keep_len`` (ring caches — sliding-window
        layers with ``T == window`` — are kept whole: their occupancy is
        position-dependent).  The dropped region is all zeros by the
        chunked-prefill write discipline, so ``_unclip_snapshot``'s
        zero-pad restores it exactly."""
        if index is None:
            return snapshot
        w = self.cfg.sliding_window

        def leaf(a, ax):
            seq = ax + 1
            keep = attention.snapshot_keep_len(a.shape[seq], index, w)
            return a[(slice(None),) * seq + (slice(0, keep),)]
        return jax.tree.map(leaf, snapshot, axes)

    def _unclip_snapshot(self, snapshot, axes, index, like):
        del index

        def leaf(s, c, ax):
            seq = ax + 1
            pad = c.shape[seq] - s.shape[seq]
            if not pad:
                return s
            widths = [(0, 0)] * s.ndim
            widths[seq] = (0, pad)
            return np.pad(np.asarray(s), widths)
        return jax.tree.map(leaf, snapshot, like, axes)

    def prefill(self, params, batch, cache) -> Tuple[Array, Any]:
        x, positions, _ = self._embed_inputs(params, batch)
        x, new_caches, _ = self._trunk(params, x, positions, cache,
                                       cache_index=None)
        return self._logits(params, x[:, -1]), new_caches

    def prefill_chunk(self, params, tokens, cache, index) -> Tuple[Array, Any]:
        """One prompt slice with carried KV state: the chunk's k/v append
        into the cache at (per-row) ``index`` and its queries attend the
        cached prefix + the chunk itself with absolute positions (RoPE,
        causal mask and sliding window all realign per row — see
        ``nn/attention.py: chunk_attention``)."""
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        positions = base.chunk_positions(index, *tokens.shape)
        x = dist_api.shard_tokens3d(x)
        x, new_caches, _ = self._trunk(params, x, positions, cache,
                                       cache_index=jnp.asarray(index,
                                                               jnp.int32))
        return self._logits(params, x[:, -1]), new_caches

    def verify_chunk(self, params, tokens, cache, index) -> Tuple[Array, Any]:
        """``prefill_chunk`` with per-position logits (``(b, s, vocab)``)
        for the speculative verifier (``serve/speculative.py``): same
        KV-append + chunk attention — only the final slice differs."""
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        positions = base.chunk_positions(index, *tokens.shape)
        x = dist_api.shard_tokens3d(x)
        x, new_caches, _ = self._trunk(params, x, positions, cache,
                                       cache_index=jnp.asarray(index,
                                                               jnp.int32))
        return self._logits(params, x), new_caches

    def decode_step(self, params, token, cache, index) -> Tuple[Array, Any]:
        """token: (b, 1); index: () or (b,) int32 — position of this token.

        A vector index gives every batch row its own position (continuous
        batching: slots prefilled at different buckets decode at different
        offsets); RoPE and the KV-cache write both realign per row.
        """
        cfg = self.cfg
        x = layers.embed(params["embed"], token)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        idx = jnp.asarray(index, jnp.int32)
        positions = jnp.broadcast_to(
            idx.reshape(-1, 1) if idx.ndim else idx,
            (token.shape[0], 1))
        x, new_caches, _ = self._trunk(params, x, positions, cache,
                                       cache_index=index)
        # Squeezed (b, d) final norm + unembed (see models/mamba_lm.py).
        return self._logits(params, x[:, 0]), new_caches
