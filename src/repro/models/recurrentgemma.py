"""RecurrentGemma (Griffin): RG-LRU recurrent blocks + local attention, 1:2.

Block pattern repeats (recurrent, recurrent, attention); every temporal
block is followed by a GeGLU MLP block.  Heterogeneous layers use a python
loop (26 layers — bounded HLO); caches are per-layer NamedTuples
(RGLRUState for recurrent layers, ring-buffer KVCache of size == window for
the local-attention layers).

XAMBA applicability: the RG-LRU gate chain is sigmoid/softplus-heavy
(ActiBA), and the recurrence's cumulative log-decay products are the same
cumsum structure CumBA remaps (``kernels/rg_lru.py`` for the fused scan).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import api as dist_api
from repro.models import base
from repro.nn import attention, layers, mlp as mlp_mod, ssm
from repro.nn.params import stack_specs

Array = jax.Array


class RecurrentGemma:
    """Layer stack = N full (r, r, a) pattern groups + a tail remainder.

    Training scans over the stacked pattern GROUPS (homogeneous pytree ->
    one scan body holding one group's heterogeneous layers), which keeps the
    512-device HLO bounded; serving uses the per-layer loop (heterogeneous
    caches, tiny modules).  Parameters live in group-stacked form; the
    serving path slices layer i out of group i//P, position i%P.
    """

    def __init__(self, cfg: base.ModelConfig):
        self.cfg = cfg
        pattern = cfg.block_pattern or ("recurrent", "recurrent", "attention")
        self.pattern = tuple(pattern)
        self.layer_kinds = [pattern[i % len(pattern)]
                            for i in range(cfg.n_layers)]
        self.n_groups = cfg.n_layers // len(pattern)
        self.n_tail = cfg.n_layers - self.n_groups * len(pattern)

    def _block_specs(self, kind: str) -> dict:
        cfg = self.cfg
        block = {
            "ln_mix": layers.norm_specs(cfg.d_model, norm_type=cfg.norm_type),
            "ln_mlp": layers.norm_specs(cfg.d_model, norm_type=cfg.norm_type),
            "mlp": mlp_mod.mlp_specs(cfg),
        }
        if kind == "recurrent":
            block["rglru"] = ssm.rglru_specs(cfg)
        else:
            block["attn"] = attention.attention_specs(cfg)
        return block

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),
            "final_norm": layers.norm_specs(cfg.d_model,
                                            norm_type=cfg.norm_type),
        }
        group = {str(j): self._block_specs(kind)
                 for j, kind in enumerate(self.pattern)}
        if self.n_groups:
            specs["groups"] = stack_specs(group, self.n_groups)
        specs["tail"] = {
            str(i): self._block_specs(self.layer_kinds[
                self.n_groups * len(self.pattern) + i])
            for i in range(self.n_tail)
        }
        return specs

    def _layer_params(self, params, i: int):
        """Slice layer i's params out of the grouped layout (serving path)."""
        p_len = len(self.pattern)
        if i < self.n_groups * p_len:
            g, j = divmod(i, p_len)
            return jax.tree.map(lambda a: a[g], params["groups"][str(j)])
        return params["tail"][str(i - self.n_groups * p_len)]

    def _block(self, p, kind, x, positions, cache, cache_index):
        cfg = self.cfg
        hin = layers.norm(p["ln_mix"], x, norm_type=cfg.norm_type)
        if kind == "recurrent":
            h, new_cache = ssm.rglru_apply(p["rglru"], cfg, hin, cache)
        else:
            h, new_cache = attention.apply(
                p["attn"], cfg, hin, positions=positions, cache=cache,
                cache_index=cache_index, causal=True,
                window=cfg.sliding_window)
        x = x + h
        h = mlp_mod.apply(p["mlp"], cfg,
                          layers.norm(p["ln_mlp"], x, norm_type=cfg.norm_type))
        return x + h, new_cache

    def _trunk(self, params, x, positions, caches=None, cache_index=None):
        cfg = self.cfg
        block = self._block
        if cfg.remat in ("full", "dots"):
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            block = jax.checkpoint(block, policy=policy,
                                   static_argnums=(1,))

        if caches is None and cfg.scan_layers and self.n_groups > 1:
            # Training path: scan over the stacked pattern groups.
            def group_body(x, gp):
                for j, kind in enumerate(self.pattern):
                    x, _ = block(gp[str(j)], kind, x, positions, None, None)
                x = dist_api.shard_tokens3d(x)
                return x, None

            x, _ = jax.lax.scan(group_body, x, params["groups"])
            for i in range(self.n_tail):
                x, _ = block(params["tail"][str(i)],
                             self.layer_kinds[-self.n_tail + i], x,
                             positions, None, None)
                x = dist_api.shard_tokens3d(x)
            return x, None

        new_caches: List[Any] = []
        for i, kind in enumerate(self.layer_kinds):
            cache = None if caches is None else caches[i]
            x, nc = block(self._layer_params(params, i), kind, x, positions,
                          cache, cache_index)
            x = dist_api.shard_tokens3d(x)
            new_caches.append(nc)
        return x, new_caches

    def _logits(self, params, x) -> Array:
        cfg = self.cfg
        x = layers.norm(params["final_norm"], x, norm_type=cfg.norm_type)
        logits = layers.unembed(params["embed"], x)
        if cfg.attn_logit_softcap:
            logits = jnp.tanh(logits / cfg.attn_logit_softcap) * \
                cfg.attn_logit_softcap
        return logits

    def _embed(self, params, tokens):
        x = layers.embed(params["embed"], tokens)
        if self.cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(self.cfg.d_model), x.dtype)
        return x

    # ---------------- training ----------------
    def loss(self, params, batch) -> Tuple[Array, dict]:
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, _ = self._trunk(params, x, positions)
        logits = self._logits(params, x)
        loss, metrics = base.cross_entropy_loss(
            logits[:, :-1], batch["labels"][:, 1:])
        metrics["loss_total"] = loss
        return loss, metrics

    # ---------------- serving ----------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        caches = []
        for kind in self.layer_kinds:
            if kind == "recurrent":
                caches.append(ssm.rglru_init_state(cfg, batch, dtype))
            else:
                window = cfg.sliding_window or max_seq
                caches.append(attention.init_cache(
                    cfg, batch, min(max_seq, window), dtype))
        return caches

    def prefill(self, params, batch, cache) -> Tuple[Array, Any]:
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, new_caches = self._trunk(params, x, positions,
                                    cache, cache_index=jnp.int32(0))
        logits = self._logits(params, x[:, -1:])
        return logits[:, 0], new_caches

    def decode_step(self, params, token, cache, index) -> Tuple[Array, Any]:
        """index: () or (b,) int32 — per-row positions realign the local
        attention layers (RG-LRU layers carry position in their state)."""
        x = self._embed(params, token)
        idx = jnp.asarray(index, jnp.int32)
        positions = jnp.broadcast_to(
            idx.reshape(-1, 1) if idx.ndim else idx,
            (token.shape[0], 1))
        x, new_caches = self._trunk(params, x, positions, cache,
                                    cache_index=index)
        logits = self._logits(params, x)
        return logits[:, 0], new_caches
