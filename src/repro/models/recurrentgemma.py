"""RecurrentGemma (Griffin): RG-LRU recurrent blocks + local attention, 1:2.

Block pattern repeats (recurrent, recurrent, attention); every temporal
block is followed by a GeGLU MLP block.  Heterogeneous layers use a python
loop (26 layers — bounded HLO); caches are per-layer NamedTuples
(RGLRUState for recurrent layers, ring-buffer KVCache of size == window for
the local-attention layers).

XAMBA applicability: the RG-LRU gate chain is sigmoid/softplus-heavy
(ActiBA), and the recurrence's cumulative log-decay products are the same
cumsum structure CumBA remaps (``kernels/rg_lru.py`` for the fused scan).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import api as dist_api
from repro.models import base
from repro.nn import attention, layers, mlp as mlp_mod, ssm
from repro.nn.params import stack_specs

Array = jax.Array


class RecurrentGemma(base.DecodeAPI):
    """Layer stack = N full (r, r, a) pattern groups + a tail remainder.

    Training scans over the stacked pattern GROUPS (homogeneous pytree ->
    one scan body holding one group's heterogeneous layers), which keeps the
    512-device HLO bounded.  Serving follows the same shape when
    ``scan_layers`` is on: caches live GROUP-STACKED (``{"groups": {pos:
    (n_groups, b, ...) tree}, "tail": [...]}`` — pattern position is a dict
    key, so each scanned leaf is homogeneous) and prefill/decode scan over
    groups instead of Python-dispatching 26 layers.  With ``scan_layers``
    off, serving keeps the per-layer loop over per-layer cache lists; the
    grouped parameter layout serves both (``_layer_params`` slices layer i
    out of group i//P, position i%P).
    """

    def __init__(self, cfg: base.ModelConfig):
        self.cfg = cfg
        pattern = cfg.block_pattern or ("recurrent", "recurrent", "attention")
        self.pattern = tuple(pattern)
        self.layer_kinds = [pattern[i % len(pattern)]
                            for i in range(cfg.n_layers)]
        self.n_groups = cfg.n_layers // len(pattern)
        self.n_tail = cfg.n_layers - self.n_groups * len(pattern)

    def _block_specs(self, kind: str) -> dict:
        cfg = self.cfg
        block = {
            "ln_mix": layers.norm_specs(cfg.d_model, norm_type=cfg.norm_type),
            "ln_mlp": layers.norm_specs(cfg.d_model, norm_type=cfg.norm_type),
            "mlp": mlp_mod.mlp_specs(cfg),
        }
        if kind == "recurrent":
            block["rglru"] = ssm.rglru_specs(cfg)
        else:
            block["attn"] = attention.attention_specs(cfg)
        return block

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),
            "final_norm": layers.norm_specs(cfg.d_model,
                                            norm_type=cfg.norm_type),
        }
        group = {str(j): self._block_specs(kind)
                 for j, kind in enumerate(self.pattern)}
        if self.n_groups:
            specs["groups"] = stack_specs(group, self.n_groups)
        specs["tail"] = {
            str(i): self._block_specs(self.layer_kinds[
                self.n_groups * len(self.pattern) + i])
            for i in range(self.n_tail)
        }
        return specs

    def _layer_params(self, params, i: int):
        """Slice layer i's params out of the grouped layout (serving path)."""
        p_len = len(self.pattern)
        if i < self.n_groups * p_len:
            g, j = divmod(i, p_len)
            if isinstance(params["groups"], tuple):
                return params["groups"][g][str(j)]
            return jax.tree.map(lambda a: a[g], params["groups"][str(j)])
        return params["tail"][str(i - self.n_groups * p_len)]

    def decode_view(self, params):
        """Pre-slice the group-stacked weights into a per-group tuple (see
        ``base.DecodeAPI.decode_view``)."""
        if not self.cfg.scan_layers or self.n_groups == 0 or \
                isinstance(params.get("groups"), tuple):
            return params
        return dict(params, groups=tuple(
            jax.tree.map(lambda a: a[g], params["groups"])
            for g in range(self.n_groups)))

    def _block(self, p, kind, x, positions, cache, cache_index):
        cfg = self.cfg
        hin = layers.norm(p["ln_mix"], x, norm_type=cfg.norm_type)
        if kind == "recurrent":
            h, new_cache = ssm.rglru_apply(p["rglru"], cfg, hin, cache)
        else:
            h, new_cache = attention.apply(
                p["attn"], cfg, hin, positions=positions, cache=cache,
                cache_index=cache_index, causal=True,
                window=cfg.sliding_window)
        x = x + h
        h = mlp_mod.apply(p["mlp"], cfg,
                          layers.norm(p["ln_mlp"], x, norm_type=cfg.norm_type))
        return x + h, new_cache

    def _trunk(self, params, x, positions, caches=None, cache_index=None):
        cfg = self.cfg
        block = self._block
        if cfg.remat in ("full", "dots"):
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            block = jax.checkpoint(block, policy=policy,
                                   static_argnums=(1,))

        if caches is None and cfg.scan_layers and self.n_groups > 1:
            # Training path: scan over the stacked pattern groups.
            def group_body(x, gp):
                for j, kind in enumerate(self.pattern):
                    x, _ = block(gp[str(j)], kind, x, positions, None, None)
                x = dist_api.shard_tokens3d(x)
                return x, None

            x, _ = jax.lax.scan(group_body, x, params["groups"])
            for i in range(self.n_tail):
                x, _ = block(params["tail"][str(i)],
                             self.layer_kinds[-self.n_tail + i], x,
                             positions, None, None)
                x = dist_api.shard_tokens3d(x)
            return x, None

        if isinstance(caches, dict):
            # Serving path, group-stacked caches: one scan body holds one
            # pattern group; cache turnover stays a single compiled scan.
            if isinstance(params.get("groups"), tuple):
                # Decode view: pre-sliced group weights (see
                # base.DecodeAPI.decode_view); loop groups in-program.
                ngs = []
                for g, gp in enumerate(params["groups"]):
                    ncs = {}
                    for j, kind in enumerate(self.pattern):
                        gc = jax.tree.map(lambda a: a[g],
                                          caches["groups"][str(j)])
                        x, nc = block(gp[str(j)], kind, x, positions, gc,
                                      cache_index)
                        ncs[str(j)] = nc
                    x = dist_api.shard_tokens3d(x)
                    ngs.append(ncs)
                new_groups = {
                    str(j): jax.tree.map(lambda *ls: jnp.stack(ls),
                                         *(ng[str(j)] for ng in ngs))
                    for j in range(len(self.pattern))}
            else:
                def group_body(x, xs):
                    gp, gc = xs
                    ncs = {}
                    for j, kind in enumerate(self.pattern):
                        x, nc = block(gp[str(j)], kind, x, positions,
                                      gc[str(j)], cache_index)
                        ncs[str(j)] = nc
                    return dist_api.shard_tokens3d(x), ncs

                unroll = (True if x.shape[1] == 1 and
                          self.cfg.xamba.decode != "naive" else 1)
                x, new_groups = jax.lax.scan(
                    group_body, x, (params["groups"], caches["groups"]),
                    unroll=unroll)
            new_tail: List[Any] = []
            base_i = self.n_groups * len(self.pattern)
            for i in range(self.n_tail):
                x, nc = block(params["tail"][str(i)],
                              self.layer_kinds[base_i + i], x, positions,
                              caches["tail"][i], cache_index)
                x = dist_api.shard_tokens3d(x)
                new_tail.append(nc)
            return x, {"groups": new_groups, "tail": new_tail}

        new_caches: List[Any] = []
        for i, kind in enumerate(self.layer_kinds):
            cache = None if caches is None else caches[i]
            x, nc = block(self._layer_params(params, i), kind, x, positions,
                          cache, cache_index)
            x = dist_api.shard_tokens3d(x)
            new_caches.append(nc)
        return x, new_caches

    def _logits(self, params, x) -> Array:
        cfg = self.cfg
        x = layers.norm(params["final_norm"], x, norm_type=cfg.norm_type)
        logits = layers.unembed(params["embed"], x)
        if cfg.attn_logit_softcap:
            logits = jnp.tanh(logits / cfg.attn_logit_softcap) * \
                cfg.attn_logit_softcap
        return logits

    def _embed(self, params, tokens):
        x = layers.embed(params["embed"], tokens)
        if self.cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(self.cfg.d_model), x.dtype)
        return x

    # ---------------- training ----------------
    def loss(self, params, batch) -> Tuple[Array, dict]:
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, _ = self._trunk(params, x, positions)
        logits = self._logits(params, x)
        loss, metrics = base.cross_entropy_loss(
            logits[:, :-1], batch["labels"][:, 1:])
        metrics["loss_total"] = loss
        return loss, metrics

    # ---------------- serving ----------------
    def _layer_cache(self, kind: str, batch: int, max_seq: int, dtype):
        cfg = self.cfg
        if kind == "recurrent":
            return ssm.rglru_init_state(cfg, batch, dtype)
        window = cfg.sliding_window or max_seq
        return attention.init_cache(cfg, batch, min(max_seq, window), dtype)

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        if self.cfg.scan_layers and self.n_groups > 0:
            # Group-stacked layout (see class docstring): leading n_groups
            # axis per leaf; pattern position is a dict key so every
            # scanned leaf stays homogeneous.
            groups = {
                str(j): jax.tree.map(
                    lambda a: jnp.zeros((self.n_groups,) + a.shape, a.dtype),
                    self._layer_cache(kind, batch, max_seq, dtype))
                for j, kind in enumerate(self.pattern)
            }
            base_i = self.n_groups * len(self.pattern)
            tail = [self._layer_cache(self.layer_kinds[base_i + i], batch,
                                      max_seq, dtype)
                    for i in range(self.n_tail)]
            return {"groups": groups, "tail": tail}
        return [self._layer_cache(kind, batch, max_seq, dtype)
                for kind in self.layer_kinds]

    def cache_batch_axes(self, cache):
        # Group-stacked serving caches are {"groups": {pos: (n_groups, b,
        # ...)}, "tail": [(b, ...)]}; per-layer lists are (b, ...).  The
        # attention entries are ring caches of size == window, so rgemma
        # snapshots are already window-clipped at init — no seq clipping
        # needed (RG-LRU ``h`` + conv tail are O(1) anyway).
        if isinstance(cache, dict):
            return {"groups": jax.tree.map(lambda a: 1, cache["groups"]),
                    "tail": jax.tree.map(lambda a: 0, cache["tail"])}
        return jax.tree.map(lambda a: 0, cache)

    def prefill(self, params, batch, cache) -> Tuple[Array, Any]:
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, new_caches = self._trunk(params, x, positions,
                                    cache, cache_index=None)
        return self._logits(params, x[:, -1]), new_caches

    def prefill_chunk(self, params, tokens, cache, index) -> Tuple[Array, Any]:
        """One prompt slice with carried state: RG-LRU layers resume from
        the carried ``h`` + conv tail (``index`` is irrelevant to them —
        the recurrence carries position), local-attention layers append
        the chunk's k/v into their ring caches at (per-row) ``index`` and
        attend the in-window prefix (``nn/attention.py: chunk_attention``,
        ring layout)."""
        x = self._embed(params, tokens)
        positions = base.chunk_positions(index, *tokens.shape)
        x, new_caches = self._trunk(params, x, positions, cache,
                                    cache_index=jnp.asarray(index,
                                                            jnp.int32))
        return self._logits(params, x[:, -1]), new_caches

    def verify_chunk(self, params, tokens, cache, index) -> Tuple[Array, Any]:
        """``prefill_chunk`` with per-position logits (``(b, s, vocab)``)
        for the speculative verifier (``serve/speculative.py``): same
        trunk, same ring-cache writes — only the final slice differs."""
        x = self._embed(params, tokens)
        positions = base.chunk_positions(index, *tokens.shape)
        x, new_caches = self._trunk(params, x, positions, cache,
                                    cache_index=jnp.asarray(index,
                                                            jnp.int32))
        return self._logits(params, x), new_caches

    def decode_step(self, params, token, cache, index) -> Tuple[Array, Any]:
        """index: () or (b,) int32 — per-row positions realign the local
        attention layers (RG-LRU layers carry position in their state)."""
        x = self._embed(params, token)
        idx = jnp.asarray(index, jnp.int32)
        positions = jnp.broadcast_to(
            idx.reshape(-1, 1) if idx.ndim else idx,
            (token.shape[0], 1))
        x, new_caches = self._trunk(params, x, positions, cache,
                                    cache_index=index)
        # Squeezed (b, d) final norm + unembed (see models/mamba_lm.py).
        return self._logits(params, x[:, 0]), new_caches
