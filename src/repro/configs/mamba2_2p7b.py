"""mamba2-2.7b [ssm]: 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].

The paper's main regime: SSD's segsum/cumsum runs under CumBA, the
contractions under ReduBA, the SiLU/Softplus under ActiBA.
"""
from repro.core.xamba import XambaConfig
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="mamba2",
    vocab_size=50280, d_model=2560, n_layers=64,
    d_state=128, d_conv=4, expand=2, ssm_head_dim=64, ssm_ngroups=1,
    chunk_size=256, tie_embeddings=True, norm_type="rmsnorm",
    remat="full", scan_layers=True,
    xamba=XambaConfig.optimized(),
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=2, d_state=16, ssm_head_dim=32,
    chunk_size=32, remat="none")
