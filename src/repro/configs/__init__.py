from repro.configs.registry import ASSIGNED, get_config, list_archs  # noqa: F401
from repro.configs import shapes  # noqa: F401
