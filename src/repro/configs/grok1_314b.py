"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1; unverified].

8 experts do not divide the 16-way model axis; the sharding fallback maps
the expert dim onto the pod axis (multi-pod) or replicates it (single-pod),
and shards each expert's 32768-wide FFN over "model" instead — exercised by
the dry-run's divisibility-aware layout resolution.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="transformer",
    vocab_size=131072, d_model=6144, n_layers=64,
    n_heads=48, n_kv_heads=8, head_dim=128,
    mlp_type="geglu", norm_type="rmsnorm",
    attn_logit_softcap=30.0,
    rope_theta=1e4, tie_embeddings=False,
    moe=True, n_experts=8, n_experts_per_token=2, moe_d_ff=32768,
    moe_renormalize=True, capacity_factor=1.25,
    moe_cap_batch_sharding=True,
    remat="full", scan_layers=True,
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=32, moe_d_ff=128, n_experts=4, n_experts_per_token=2,
    remat="none")
