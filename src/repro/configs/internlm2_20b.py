"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA [arXiv:2403.17297; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="transformer",
    vocab_size=92544, d_model=6144, n_layers=48,
    n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, mlp_type="swiglu", norm_type="rmsnorm",
    rope_theta=1e6, tie_embeddings=False,
    remat="full", scan_layers=True,
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, remat="none")
