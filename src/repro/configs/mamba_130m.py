"""mamba-130m — the paper's Mamba-1 evaluation subject (hf:mamba-130m-hf)."""
from repro.core.xamba import XambaConfig
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba-130m", family="mamba",
    vocab_size=50280, d_model=768, n_layers=24,
    d_state=16, d_conv=4, expand=2, dt_rank=48,
    tie_embeddings=True, scan_layers=True, remat="full",
    xamba=XambaConfig.optimized(),
)

REDUCED = CONFIG.replace(vocab_size=512, d_model=128, n_layers=2, dt_rank=8)
