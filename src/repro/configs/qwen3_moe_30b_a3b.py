"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="transformer",
    vocab_size=151936, d_model=2048, n_layers=48,
    n_heads=32, n_kv_heads=4, head_dim=128,
    mlp_type="swiglu", norm_type="rmsnorm",
    rope_theta=1e6, tie_embeddings=False,
    moe=True, n_experts=128, n_experts_per_token=8, moe_d_ff=768,
    moe_renormalize=True, capacity_factor=1.25,
    remat="full", scan_layers=True,
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=32, moe_d_ff=64, n_experts=8, n_experts_per_token=2,
    remat="none")
