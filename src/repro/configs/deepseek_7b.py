"""deepseek-7b [dense]: 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400 — llama-arch [arXiv:2401.02954; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="transformer",
    vocab_size=102400, d_model=4096, n_layers=30,
    n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, mlp_type="swiglu", norm_type="rmsnorm",
    rope_theta=1e4, tie_embeddings=False,
    remat="full", scan_layers=True,
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=2, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=256, remat="none")
