"""Architecture config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.base import ModelConfig

# arch id -> module under repro.configs
_ARCHS: Dict[str, str] = {
    "internlm2-20b": "internlm2_20b",
    "deepseek-7b": "deepseek_7b",
    "qwen1.5-4b": "qwen15_4b",
    "gemma-2b": "gemma_2b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "grok-1-314b": "grok1_314b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-2.7b": "mamba2_2p7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    # the paper's own evaluation subjects
    "mamba-130m": "mamba_130m",
    "mamba2-130m": "mamba2_130m",
}

ASSIGNED = [a for a in _ARCHS if not a.endswith("130m")]


def list_archs() -> List[str]:
    return list(_ARCHS)


def _module(arch: str):
    if arch not in _ARCHS:
        raise ValueError(f"unknown arch {arch!r}; have {sorted(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch]}")


def get_config(arch: str, *, reduced: bool = False, **overrides
               ) -> ModelConfig:
    mod = _module(arch)
    cfg = mod.REDUCED if reduced else mod.CONFIG
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg
