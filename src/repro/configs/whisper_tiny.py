"""whisper-tiny [audio]: 4L d_model=384 6H d_ff=1536 vocab=51865 — enc-dec,
conv frontend stubbed [arXiv:2212.04356; unverified].

``input_specs()`` provides precomputed frame embeddings (batch, 1500, 384);
the conv1d+GELU frontend is a stub per the assignment.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="whisper",
    vocab_size=51865, d_model=384, n_layers=4, encoder_layers=4,
    n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, mlp_type="mlp", norm_type="layernorm",
    encoder_seq=1500, tie_embeddings=True,
    remat="none", scan_layers=False,
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=64, n_layers=2, encoder_layers=2, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, encoder_seq=32)
