"""mamba2-130m — the paper's Mamba-2 evaluation subject (hf:mamba2-130m-hf).

CumSum_b here is the (256, 256) segsum inside each SSD chunk — the op the
paper measures at >99.9% of total CumSum time and remaps with CumBA.
"""
from repro.core.xamba import XambaConfig
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="mamba2",
    vocab_size=50288, d_model=768, n_layers=24,
    d_state=128, d_conv=4, expand=2, ssm_head_dim=64, ssm_ngroups=1,
    chunk_size=256, tie_embeddings=True, scan_layers=True, remat="full",
    xamba=XambaConfig.optimized(),
)

REDUCED = CONFIG.replace(vocab_size=512, d_model=128, n_layers=2,
                         d_state=16, ssm_head_dim=32, chunk_size=32)
