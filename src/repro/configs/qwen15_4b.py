"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="transformer",
    vocab_size=151936, d_model=2560, n_layers=40,
    n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, mlp_type="swiglu", norm_type="rmsnorm",
    qkv_bias=True, rope_theta=5e6, tie_embeddings=False,
    remat="full", scan_layers=True,
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=2, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=256, remat="none")
