"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling (patch-embedding stub)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (anyres tiling -> 576 base patches) that are
concatenated ahead of the text tokens.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="transformer",
    vocab_size=32000, d_model=4096, n_layers=32,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, mlp_type="swiglu", norm_type="rmsnorm",
    rope_theta=1e6, tie_embeddings=False,
    frontend="vision_stub", num_patches=576,
    remat="full", scan_layers=True,
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, num_patches=16, remat="none")
