"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000
— GeGLU, head_dim=256 [arXiv:2403.08295; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="transformer",
    vocab_size=256000, d_model=2048, n_layers=18,
    n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, mlp_type="geglu", norm_type="gemma_rmsnorm",
    embed_scale=True, tie_embeddings=True, rope_theta=1e4,
    remat="full", scan_layers=True,
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=2, n_heads=4, n_kv_heads=1,
    head_dim=32, d_ff=256, remat="none")
