"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf]."""
from repro.core.xamba import XambaConfig
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="recurrentgemma",
    vocab_size=256000, d_model=2560, n_layers=26,
    n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, mlp_type="geglu", norm_type="gemma_rmsnorm",
    embed_scale=True, tie_embeddings=True,
    lru_width=2560, sliding_window=2048,
    block_pattern=("recurrent", "recurrent", "attention"),
    attn_logit_softcap=30.0,
    remat="full", scan_layers=True,
    xamba=XambaConfig.optimized(),
)

REDUCED = CONFIG.replace(
    vocab_size=512, d_model=128, n_layers=3, n_heads=4, n_kv_heads=1,
    head_dim=32, d_ff=256, lru_width=128, sliding_window=64, remat="none")
