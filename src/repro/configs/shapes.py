"""Assigned input shapes and abstract input builders for the dry-run.

Every LM-family arch is paired with four shape cells:

  train_4k     seq=4096    batch=256   -> train_step
  prefill_32k  seq=32768   batch=32    -> prefill_step
  decode_32k   seq=32768   batch=128   -> decode_step (1 token, full cache)
  long_500k    seq=524288  batch=1     -> decode_step; SSM/hybrid archs only

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for the matching step function.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# Archs with a sub-quadratic sequence path (may run long_500k).
SUBQUADRATIC_FAMILIES = ("mamba", "mamba2", "recurrentgemma")


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return ("pure full-attention arch: O(L^2) attention at 524k is "
                "out of scope per assignment (sub-quadratic archs only)")
    return None


def batch_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract batch for loss()/train_step: tokens + labels (+ stubs)."""
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.family == "whisper":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return out
    if cfg.frontend == "vision_stub":
        p = cfg.num_patches
        out["image_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                   cfg.dtype)
        s = max(s - p, 1)  # total context = patches + text = shape seq
    out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def prefill_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.family == "whisper":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.frontend == "vision_stub":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), cfg.dtype)
        s = max(s - cfg.num_patches, 1)
    out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b = shape.global_batch
    return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def abstract_cache(model, cfg: ModelConfig, shape: ShapeSpec,
                   dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree matching model.init_cache."""
    concrete = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, dtype))
    return concrete
