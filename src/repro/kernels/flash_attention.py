"""Flash attention Pallas kernel (causal / sliding-window / GQA).

Not a paper contribution, but the compute hot-spot of 8 of the 10 assigned
architectures, so it gets the same treatment as the SSM kernels: online-
softmax tiling so the (Lq, Lk) score matrix never exists in HBM, fp32
running max/denominator in VMEM, MXU for both score and value matmuls.

Forward-only kernel; the backward pass is supplied via ``jax.custom_vjp``
with the rematerialized XLA reference (standard practice while a bwd kernel
lands — training defaults to the XLA path anyway, see ``nn/attention.py``).

Layouts:
  q: (b, hq, Lq, d);  k, v: (b, hkv, Lk, d);  hq % hkv == 0 (GQA).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

Array = jax.Array
NEG_INF = common.NEG_INF


def _flash_kernel(nkv: int, block_q: int, block_k: int, causal: bool,
                  window: Optional[int], scale: float):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        kv = pl.program_id(3)

        @pl.when(kv == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        qi = pl.program_id(2)
        q_off = qi * block_q
        k_off = kv * block_k

        # Structural skip: blocks fully above the causal diagonal (or fully
        # outside the sliding window) contribute nothing.
        fully_masked = jnp.bool_(False)
        if causal:
            fully_masked = jnp.logical_or(
                fully_masked, (q_off + block_q - 1) < k_off)
        if window is not None:
            # q attends to [q - window + 1, q]
            fully_masked = jnp.logical_or(
                fully_masked, (k_off + block_k - 1) < (q_off - window + 1))

        @pl.when(jnp.logical_not(fully_masked))
        def _block():
            q = q_ref[0, 0, :, :].astype(jnp.float32) * scale   # (bq, d)
            k = k_ref[0, 0, :, :].astype(jnp.float32)           # (bk, d)
            v = v_ref[0, 0, :, :].astype(jnp.float32)           # (bk, d)
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

            q_ids = q_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_ids = k_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = jnp.ones_like(s, dtype=jnp.bool_)
            if causal:
                mask = jnp.logical_and(mask, k_ids <= q_ids)
            if window is not None:
                mask = jnp.logical_and(mask, k_ids > q_ids - window)
            s = jnp.where(mask, s, NEG_INF)

            m_prev = m_ref[...]                                 # (bq, 1)
            m_cur = jnp.max(s, axis=1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
            acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
                p, v, preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        @pl.when(kv == nkv - 1)
        def _drain():
            l = l_ref[...]
            safe = jnp.where(l == 0.0, 1.0, l)
            o_ref[0, 0, :, :] = (acc_ref[...] / safe).astype(o_ref.dtype)

    return kernel


def _flash_forward(q: Array, k: Array, v: Array, *, causal: bool,
                   window: Optional[int], scale: Optional[float],
                   block_q: int, block_k: int, interpret: bool) -> Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    qpg = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)

    bq = min(block_q, common.round_up(lq, 128))
    bk = min(block_k, common.round_up(lk, 128))
    lqp, lkp = common.round_up(lq, bq), common.round_up(lk, bk)
    q2 = common.pad_axis(q, 2, lqp)
    k2 = common.pad_axis(k, 2, lkp)
    v2 = common.pad_axis(v, 2, lkp)
    nkv = lkp // bk

    out = common.pallas_call(
        _flash_kernel(nkv, bq, bk, causal, window, scale),
        grid=(b, hq, lqp // bq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // qpg, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // qpg, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        interpret=interpret,
        name="flash_attention",
    )(q2, k2, v2)
    return out[:, :, :lq, :]


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> Array:
    return _flash_forward(q, k, v, causal=causal, window=window, scale=scale,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)


def _fwd(q, k, v, causal, window, scale, block_q, block_k, interpret):
    out = _flash_forward(q, k, v, causal=causal, window=window, scale=scale,
                         block_q=block_q, block_k=block_k, interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, window, scale, block_q, block_k, interpret, res, g):
    q, k, v = res
    from repro.kernels import ref as kref

    def f(q, k, v):
        return kref.attention_ref(q, k, v, causal=causal, window=window,
                                  scale=scale)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
