"""Public jit'd wrappers for the XAMBA Pallas kernels.

Every op dispatches between the Pallas kernel (TPU target; ``interpret=True``
runs the same kernel body on CPU for validation) and the XLA reference.  The
models call these through ``XambaConfig`` modes; tests sweep shapes/dtypes
against ``kernels/ref.py``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import pwl
from repro.core.pwl import PWLTable
from repro.kernels import (actiba as _actiba, cumba as _cumba,
                           decode_step as _ds, flash_attention as _fa,
                           matmul_pwl as _mpwl, qmatmul as _qm,
                           reduba as _reduba, rg_lru as _rg, ref)

Array = jax.Array


@partial(jax.jit, static_argnames=("interpret",))
def cumba_cumsum(x: Array, *, interpret: bool = False) -> Array:
    """CumBA: cumulative sum along the trailing axis."""
    return _cumba.cumsum_last(x, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def reduba_sum(x: Array, *, interpret: bool = False) -> Array:
    """ReduBA: sum over the trailing axis (input moved so target is last)."""
    # reduce over last axis == reduce_rows of the transpose
    x2 = x.reshape(-1, x.shape[-1]).T          # (m=last, n=rest)
    out = _reduba.reduce_rows(x2, interpret=interpret)
    return out.reshape(x.shape[:-1])


@partial(jax.jit, static_argnames=("table", "interpret"))
def actiba_activate(x: Array, table: PWLTable, *,
                    interpret: bool = False) -> Array:
    """ActiBA: standalone PWL activation."""
    return _actiba.pwl_activate(x, table, interpret=interpret)


@partial(jax.jit, static_argnames=("table", "interpret"))
def matmul_pwl(x: Array, w: Array, table: PWLTable,
               v: Optional[Array] = None, *,
               interpret: bool = False) -> Array:
    """ActiBA vertical fusion: pwl(x @ w) [* (x @ v)]."""
    return _mpwl.matmul_pwl(x, w, table, v, interpret=interpret)


@partial(jax.jit, static_argnames=("table", "interpret"))
def qmatmul(x: Array, q: Array, scale: Array, *,
            table: Optional[PWLTable] = None,
            qv: Optional[Array] = None, vscale: Optional[Array] = None,
            interpret: bool = False) -> Array:
    """W8 fused dequant-matmul: ``epi((x @ q) * scale)`` with int8 weight
    tiles dequantized in-register; optional PWL epilogue + gated form."""
    return _qm.qmatmul(x, q, scale, table=table, qv=qv, vscale=vscale,
                       interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x_c: Array, a_c: Array, A_cum: Array, B_c: Array, C_c: Array,
              *, interpret: bool = False):
    """Fused SSD intra-chunk pass -> (y_diag, chunk_states)."""
    del a_c  # only the prefix sums are needed; kept for API symmetry
    from repro.kernels import ssd_chunk as _ssd
    return _ssd.ssd_chunk(x_c, None, A_cum, B_c, C_c, interpret=interpret)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    interpret: bool = False) -> Array:
    """Flash attention (custom_vjp handles the backward pass)."""
    return _fa.flash_attention(q, k, v, causal, window, scale, 128, 128,
                               interpret)


@partial(jax.jit, static_argnames=("interpret",))
def rg_lru_scan(a: Array, b: Array, *, interpret: bool = False) -> Array:
    """Gated linear recurrence h_t = a_t h_{t-1} + b_t."""
    return _rg.rg_lru_scan(a, b, interpret=interpret)


# ----------------------------------------------------------------------------
# Fused single-token decode steps (``XambaConfig.decode`` pallas modes)
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("interpret",))
def ssd_step(state, x_t, dt_t, A, B_t, C_t, *, interpret: bool = False):
    """Bare SSD recurrent update (core/ssd.py pallas dispatch target)."""
    return _ds.ssd_step(state, x_t, dt_t, A, B_t, C_t, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def sscan_step(state, u_t, delta_t, A, B_t, C_t, D=None, *,
               interpret: bool = False):
    """Bare selective-scan update (core/selective_scan.py pallas target)."""
    return _ds.sscan_step(state, u_t, delta_t, A, B_t, C_t, D,
                          interpret=interpret)


@partial(jax.jit, static_argnames=("ngroups", "head_dim", "xamba",
                                   "interpret"))
def mamba2_decode_step(z, xbc, dt, conv_state, ssm_state, conv_w, conv_b,
                       dt_bias, A, D, norm_scale, *, ngroups: int,
                       head_dim: int, xamba=None, interpret: bool = False):
    """Fused Mamba-2 single-token step (conv + SiLU + softplus + SSD +
    gated norm).  ``xamba`` (hashable config) bakes ActiBA tables in."""
    return _ds.mamba2_step(
        z, xbc, dt, conv_state, ssm_state, conv_w, conv_b, dt_bias, A, D,
        norm_scale, ngroups=ngroups, head_dim=head_dim,
        silu=pwl.activation("silu", xamba),
        softplus=pwl.activation("softplus", xamba), interpret=interpret)


@partial(jax.jit, static_argnames=("ngroups", "head_dim", "chunk",
                                   "xamba", "mode"))
def mamba2_prefill(x, in_w, conv_state, ssm_state, conv_w, conv_b, dt_bias,
                   A, D, norm_scale, *, ngroups: int, head_dim: int,
                   chunk: int, xamba=None, mode: str = "cumba"):
    """Fused Mamba-2 multi-token prefill (``XambaConfig.prefill``):
    in-projection (W8-fused when ``in_w`` is quantized) + conv + SiLU +
    softplus(dt) + chunked SSD scan + gated norm in one pass.

    ``mode``: ``cumba`` = fused-structure XLA pipeline; ``pallas`` /
    ``pallas_interpret`` = the one-kernel Pallas pipeline.  Returns
    ``(y, new_conv, new_ssm)`` with ``y`` the pre-out-projection gated
    mixer output (b, l, d_inner) in the stream dtype of ``x``.
    """
    from repro.kernels import prefill_chunk as _pc
    di = norm_scale.shape[-1]
    g, n = ngroups, ssm_state.shape[-1]
    zxbcdt = _pc.project_in(x, in_w)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    kwargs = dict(ngroups=g, head_dim=head_dim, chunk=chunk,
                  silu=pwl.activation("silu", xamba),
                  softplus=pwl.activation("softplus", xamba))
    if mode in ("pallas", "pallas_interpret"):
        return _pc.mamba2_prefill_pallas(
            z, xbc, dt, conv_state, ssm_state, conv_w, conv_b, dt_bias,
            A, D, norm_scale, interpret=(mode == "pallas_interpret"),
            **kwargs)
    return _pc.mamba2_prefill_xla(
        z, xbc, dt, conv_state, ssm_state, conv_w, conv_b, dt_bias,
        A, D, norm_scale, **kwargs)


@partial(jax.jit, static_argnames=("dt_rank", "xamba", "interpret"))
def mamba1_decode_step(xs_raw, z, conv_state, ssm_state, conv_w, conv_b,
                       xproj_w, dtproj_w, dtproj_b, A, D, *, dt_rank: int,
                       xamba=None, interpret: bool = False):
    """Fused Mamba-1 single-token step (conv + SiLU + dt projections +
    selective scan + gate)."""
    return _ds.mamba1_step(
        xs_raw, z, conv_state, ssm_state, conv_w, conv_b, xproj_w,
        dtproj_w, dtproj_b, A, D, dt_rank=dt_rank,
        silu=pwl.activation("silu", xamba),
        softplus=pwl.activation("softplus", xamba), interpret=interpret)


@partial(jax.jit, static_argnames=("xamba", "interpret"))
def rglru_decode_step(u, gate, conv_state, h_state, conv_w, conv_b, rg_w,
                      rg_b, ig_w, ig_b, lam, *, xamba=None,
                      interpret: bool = False):
    """Fused RG-LRU single-token step (conv + sigmoid gates + recurrence
    + GeLU output gate)."""
    return _ds.rglru_step(
        u, gate, conv_state, h_state, conv_w, conv_b, rg_w, rg_b, ig_w,
        ig_b, lam, sigmoid=pwl.activation("sigmoid", xamba),
        softplus=pwl.activation("softplus", xamba),
        gelu=pwl.activation("gelu", xamba), interpret=interpret)


# Re-export oracles for convenience in tests/benchmarks.
reference = ref
