"""Fused W8 dequant-matmul: int8 weight tiles, per-channel scale drain.

The weight-only quantization path (``nn/quant.py``) stores linear weights
as int8 + an fp32 per-channel scale row.  A naive XLA program would
materialize ``convert(q) * scale`` — a full fp32 copy of the weight in
HBM per call, erasing the bandwidth win.  This kernel keeps the weight
int8 all the way into VMEM: each ``(bk, bn)`` tile is upconverted
IN-REGISTER for the MXU contraction, and the per-channel scale multiplies
the fp32 accumulator once in the drain phase — so HBM only ever sees 1
byte per weight element.

Per-channel symmetric scales commute with the contraction
(``(x @ q) * scale == x @ (q * scale)``), which is what makes the
drain-phase rescale exact.  The drain composes with the ActiBA PWL
epilogue from ``kernels/actiba.py`` (the paper's vertical fusion), and
with the gated two-weight form used by every assigned MLP
(``act(x @ w) * (x @ v)``) — mirroring ``kernels/matmul_pwl.py`` with
both weights int8:

    out = epi(acc_w * scale_w) [* (acc_v * scale_v)]

Oracle: ``kernels/ref.py: qmatmul_ref``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pwl import PWLTable
from repro.kernels import common
from repro.kernels.actiba import make_pwl_epilogue

Array = jax.Array


def _qmatmul_kernel(table: Optional[PWLTable], nk: int, gated: bool):
    epi = make_pwl_epilogue(table) if table is not None else (lambda a: a)

    if not gated:
        def kernel(x_ref, q_ref, s_ref, o_ref, acc_ref):
            k = pl.program_id(2)

            @pl.when(k == 0)
            def _init():
                acc_ref[...] = jnp.zeros_like(acc_ref)

            # In-register dequant: the int8 tile upconverts in VMEM for
            # the MXU; the scale waits for the drain.
            acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                                    q_ref[...].astype(jnp.float32),
                                    preferred_element_type=jnp.float32)

            @pl.when(k == nk - 1)
            def _drain():
                o_ref[...] = epi(acc_ref[...] * s_ref[...]).astype(o_ref.dtype)

        return kernel

    def kernel(x_ref, q_ref, s_ref, v_ref, vs_ref, o_ref, acc_ref, gacc_ref):
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            gacc_ref[...] = jnp.zeros_like(gacc_ref)

        x = x_ref[...].astype(jnp.float32)
        acc_ref[...] += jnp.dot(x, q_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
        gacc_ref[...] += jnp.dot(x, v_ref[...].astype(jnp.float32),
                                 preferred_element_type=jnp.float32)

        @pl.when(k == nk - 1)
        def _drain():
            o_ref[...] = (epi(acc_ref[...] * s_ref[...]) *
                          (gacc_ref[...] * vs_ref[...])).astype(o_ref.dtype)

    return kernel


def qmatmul(x: Array, q: Array, scale: Array, *,
            table: Optional[PWLTable] = None,
            qv: Optional[Array] = None, vscale: Optional[Array] = None,
            block_m: int = 256, block_n: int = 256, block_k: int = 512,
            interpret: bool = False) -> Array:
    """``epi((x @ q) * scale)`` or, gated, ``... * ((x @ qv) * vscale)``.

    x: (m, k) fp; q, qv: (k, n) int8; scale, vscale: (1, n) fp32.
    ``epi`` is the PWL table epilogue when given, identity otherwise.
    """
    m, k = x.shape
    k2, n = q.shape
    assert k == k2, (x.shape, q.shape)
    scale = scale.reshape(1, n)
    gated = qv is not None
    if gated:
        assert vscale is not None, "gated qmatmul needs vscale"
        vscale = vscale.reshape(1, n)

    bm = min(block_m, common.round_up(m, 8))
    bn = min(block_n, common.round_up(n, 128))
    bk = min(block_k, common.round_up(k, 128))
    mp, np_, kp = (common.round_up(m, bm), common.round_up(n, bn),
                   common.round_up(k, bk))
    x2 = common.pad_axis(common.pad_axis(x, 0, mp), 1, kp)
    q2 = common.pad_axis(common.pad_axis(q, 0, kp), 1, np_)
    s2 = common.pad_axis(scale, 1, np_)
    nk = kp // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
    ]
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    operands = [x2, q2, s2]
    if gated:
        v2 = common.pad_axis(common.pad_axis(qv, 0, kp), 1, np_)
        vs2 = common.pad_axis(vscale, 1, np_)
        in_specs.extend([
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ])
        scratch.append(pltpu.VMEM((bm, bn), jnp.float32))
        operands.extend([v2, vs2])

    name = "qmatmul"
    if table is not None:
        name += f"_{table.name}"
    if gated:
        name += "_gated"
    out = common.pallas_call(
        _qmatmul_kernel(table, nk, gated),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=scratch,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
        name=name,
    )(*operands)
    return out[:m, :n]
