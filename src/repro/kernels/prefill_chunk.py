"""Fused SSD prefill pipeline: conv + SiLU + softplus(dt) + chunk scan + gate.

Chunked prefill used to run the unfused XLA chain (projection -> causal
conv -> segsum -> intra-chunk scan -> inter-chunk scan -> gated norm),
each stage a separate op group with its intermediates round-tripping
through HBM.  This module fuses the whole post-projection mixer into a
single pass over the sequence, in two interchangeable backends selected
by ``XambaConfig.prefill``:

* ``mamba2_prefill_xla``  — the fused-structure single-pass XLA pipeline
  (mode ``cumba``): one chunk-sequential sweep that carries the SSM state
  and conv tail, with the CumBA triangular-matmul cumsum and all
  contractions as MXU-shaped ``dot_general``s.  This is the portable
  fast path (and the one the CPU serve bench measures).
* ``mamba2_prefill_pallas`` — the one-kernel Pallas pipeline (modes
  ``pallas`` / ``pallas_interpret``): a ``(batch, chunk)`` grid walked
  sequentially so VMEM scratch carries the conv tail and SSM state
  across chunks — zero intermediate HBM round-trips between the conv,
  the activations, the intra-chunk CumBA scan (absorbing
  ``kernels/ssd_chunk.py``), the inter-chunk recurrence, and the gated
  RMSNorm epilogue.

Both take the *projected* streams (z / xbc / raw dt).  The in-projection
that produces them runs through :func:`project_in`, which keeps the W8
serve path fused: a quantized weight on a pallas backend goes through the
blocked dequant-matmul kernel (``kernels/qmatmul.py``) so the int8 tiles
dequantize in-register — the streams are born from the fused epilogue
instead of a materialized fp copy of the weight.

ActiBA composes the same way as the decode-step kernel: ``silu`` /
``softplus`` arrive as compile-time callables (``pwl.activation``), so
PWL tables bake into either backend unchanged.

Oracle: ``kernels/ref.py: mamba2_prefill_ref`` (sequential
``ssd_reference`` semantics).  Dispatch: ``nn/ssm.py: mamba2_apply``.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

Array = jax.Array


# ----------------------------------------------------------------------------
# In-projection (optionally W8-fused)
# ----------------------------------------------------------------------------

def project_in(x: Array, w) -> Array:
    """``x @ w`` producing the z/xbc/dt streams.

    ``w`` is either an fp weight or a ``QuantTensor``; quantized weights
    on a pallas backend run the blocked dequant-matmul kernel
    (``kernels/qmatmul.py``) so the prefill pipeline's first stage stays
    int8-in-HBM.  Mirrors ``nn/layers.py: linear`` (the in-projection has
    no bias).
    """
    from repro.nn import quant
    if quant.is_quantized(w):
        y = quant.qdot(x, w)
    else:
        y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# Shared chunk math (the CumBA intra-chunk scan + state carry), XLA form
# ----------------------------------------------------------------------------

def _chunk_scan(xdt: Array, a: Array, B: Array, C: Array, state: Array,
                g: int) -> Tuple[Array, Array]:
    """One chunk of the SSD recurrence with an incoming state.

    xdt: (b, L, h, p) dt-scaled values; a: (b, L, h) log decays;
    B, C: (b, L, g, n); state: (b, h, p, n).
    Returns (y (b, L, h, p), new_state (b, h, p, n)), all fp32.
    """
    b, L, h, p = xdt.shape
    n = B.shape[-1]
    hpg = h // g
    tril = jnp.tril(jnp.ones((L, L), jnp.float32))
    # CumBA: inclusive prefix sums as one triangular matmul on the MXU.
    cs = jnp.einsum("ls,bsh->blh", tril, a,
                    preferred_element_type=jnp.float32)      # (b, L, h)
    seg = cs[:, :, None, :] - cs[:, None, :, :]              # (b, L, S, h)
    trilb = (tril > 0)[None, :, :, None]
    decay = jnp.where(trilb, jnp.exp(jnp.where(trilb, seg, 0.0)), 0.0)
    CB = jnp.einsum("blgn,bsgn->blsg", C, B,
                    preferred_element_type=jnp.float32)      # (b, L, S, g)
    x_r = xdt.reshape(b, L, g, hpg, p)
    M = CB[..., None] * decay.reshape(b, L, L, g, hpg)       # (b, L, S, g, q)
    y = jnp.einsum("blsgq,bsgqp->blgqp", M, x_r,
                   preferred_element_type=jnp.float32).reshape(b, L, h, p)
    # State -> output for tokens in this chunk (the inter-chunk term).
    cse = jnp.exp(cs)                                        # (b, L, h)
    st_g = state.reshape(b, g, hpg, p, n)
    y_off = jnp.einsum("blgn,bgqpn->blgqp", C, st_g,
                       preferred_element_type=jnp.float32)
    y = y + y_off.reshape(b, L, h, p) * cse[..., None]
    # Outgoing state: decayed incoming state + this chunk's contribution.
    dstate = jnp.exp(cs[:, -1:, :] - cs)                     # (b, L, h)
    xw = (xdt * dstate[..., None]).reshape(b, L, g, hpg, p)
    st_new = jnp.einsum("blgn,blgqp->bgqpn", B, xw,
                        preferred_element_type=jnp.float32)
    st_new = st_new.reshape(b, h, p, n) + \
        state * jnp.exp(cs[:, -1])[..., None, None]
    return y, st_new


def _conv_window(conv_state: Array, xbc: Array, conv_w: Array,
                 conv_b: Array) -> Tuple[Array, Array]:
    """Causal conv over the sequence with an incoming tail.

    conv_state: (b, w-1, dxbc); xbc: (b, l, dxbc).
    Returns (conv (b, l, dxbc) fp32, new_tail (b, w-1, dxbc) fp32).
    """
    l = xbc.shape[1]
    width = conv_w.shape[0]
    win = jnp.concatenate([conv_state.astype(jnp.float32),
                           xbc.astype(jnp.float32)], axis=1)
    w = conv_w.astype(jnp.float32)
    conv = sum(win[:, i:i + l] * w[i] for i in range(width)) + \
        conv_b.astype(jnp.float32)
    return conv, win[:, l:]


def _gate_epilogue(y: Array, xs: Array, z: Array, D: Array,
                   norm_scale: Array, silu: Callable, eps: float) -> Array:
    """D skip + RMSNorm + SiLU(z) gate; y/xs (b, l, h, p), z (b, l, di).

    Runs in the STREAM dtype with an fp32 norm interior — the exact
    boundary-rounding discipline of the unfused chain (``layers.norm``),
    so fused and unfused prefill agree even under bf16 params.
    """
    b, l, h, p = y.shape
    sd = z.dtype
    y = y.astype(sd) + xs.astype(sd) * D.astype(sd)[None, None, :, None]
    yf = y.reshape(b, l, h * p).astype(jnp.float32)
    ms = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(ms + eps) * norm_scale.astype(jnp.float32)
    return yn.astype(sd) * silu(z)


# ----------------------------------------------------------------------------
# Backend 1: fused-structure XLA pipeline (mode "cumba")
# ----------------------------------------------------------------------------

def mamba2_prefill_xla(z: Array, xbc: Array, dt: Array, conv_state: Array,
                       ssm_state: Array, conv_w: Array, conv_b: Array,
                       dt_bias: Array, A: Array, D: Array,
                       norm_scale: Array, *, ngroups: int, head_dim: int,
                       chunk: int, silu: Callable, softplus: Callable,
                       eps: float = 1e-6) -> Tuple[Array, Array, Array]:
    """Single-pass prefill: streams in, gated mixer output + states out.

    z: (b, l, di); xbc: (b, l, dxbc); dt: (b, l, h) RAW (pre-softplus);
    conv_state: (b, w-1, dxbc); ssm_state: (b, h, p, n).
    Returns (y (b, l, di) in the stream dtype, new_conv (b, w-1, dxbc),
    new_ssm fp32).  ``l`` must be a multiple of ``chunk`` (the dispatcher
    gates on this — no padding, so the conv tail and raw dt stay exact).
    """
    b, l, di = z.shape
    g, p = ngroups, head_dim
    h = dt.shape[-1]
    n = (xbc.shape[-1] - di) // (2 * g)
    sd = z.dtype
    assert l % chunk == 0, (l, chunk)

    conv, new_tail = _conv_window(conv_state, xbc, conv_w, conv_b)
    # Activated streams round to the STREAM dtype (the unfused chain's
    # boundary) before re-entering the fp32 scan core.
    act = silu(conv.astype(sd))
    xs = act[..., :di]
    B = act[..., di:di + g * n].reshape(b, l, g, n).astype(jnp.float32)
    C = act[..., di + g * n:].reshape(b, l, g, n).astype(jnp.float32)
    dt_f = softplus(dt.astype(jnp.float32) +
                    dt_bias.astype(jnp.float32))             # (b, l, h)
    a = dt_f * A.astype(jnp.float32)                         # (b, l, h)
    xs_r = xs.reshape(b, l, h, p)
    xdt = xs_r.astype(jnp.float32) * dt_f[..., None]

    state0 = ssm_state.astype(jnp.float32)
    nchunks = l // chunk
    if nchunks == 1:
        y, new_ssm = _chunk_scan(xdt, a, B, C, state0, g)
    else:
        def split(t):  # (b, l, ...) -> (c, b, L, ...)
            return jnp.moveaxis(
                t.reshape((b, nchunks, chunk) + t.shape[2:]), 1, 0)

        def body(state, blk):
            y_c, state = _chunk_scan(*blk, state, g)
            return state, y_c

        new_ssm, ys = jax.lax.scan(
            body, state0, (split(xdt), split(a), split(B), split(C)))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, l, h, p)

    out = _gate_epilogue(y, xs_r, z, D, norm_scale, silu, eps)
    return out, new_tail.astype(conv_state.dtype), new_ssm


# ----------------------------------------------------------------------------
# Backend 2: one-kernel Pallas pipeline (modes "pallas"/"pallas_interpret")
# ----------------------------------------------------------------------------

def _prefill_kernel(width: int, di: int, g: int, p: int, n: int,
                    silu: Callable, softplus: Callable, eps: float):
    h = (di // p)
    hpg = h // g

    def kernel(z_ref, xbc_ref, dt_ref, c0_ref, s0_ref, cw_ref, cb_ref,
               dtb_ref, a_ref, d_ref, ns_ref, y_ref, co_ref, so_ref,
               tail_scr, st_scr):
        ci = pl.program_id(1)

        @pl.when(ci == 0)
        def _init():
            tail_scr[...] = c0_ref[0].astype(jnp.float32)
            st_scr[...] = s0_ref[0].astype(jnp.float32)

        sd = z_ref.dtype
        xbc = xbc_ref[0].astype(jnp.float32)                 # (L, dxbc)
        L = xbc.shape[0]
        win = jnp.concatenate([tail_scr[...], xbc], axis=0)  # (L+w-1, dxbc)
        w = cw_ref[...].astype(jnp.float32)                  # (w, dxbc)
        conv = sum(win[i:i + L] * w[i] for i in range(width)) + \
            cb_ref[...].astype(jnp.float32)
        # Stream-dtype rounding at the activation boundary (matches the
        # unfused chain, so fused/unfused agree under bf16 params).
        act = silu(conv.astype(sd))
        xs = act[:, :di]                                     # (L, di), sd
        Bq = act[:, di:di + g * n].reshape(L, g, n).astype(jnp.float32)
        Cq = act[:, di + g * n:].reshape(L, g, n).astype(jnp.float32)
        dt_f = softplus(dt_ref[0].astype(jnp.float32) +
                        dtb_ref[...].astype(jnp.float32))    # (L, h)
        a = dt_f * a_ref[...].astype(jnp.float32)            # (L, h)
        tril = jnp.tril(jnp.ones((L, L), jnp.float32))
        # CumBA: prefix sums for all heads as one (L, L) x (L, h) matmul.
        cs = jnp.dot(tril, a, preferred_element_type=jnp.float32)
        trilb = tril > 0
        xdt = xs.astype(jnp.float32).reshape(L, h, p) * \
            dt_f[..., None]                                  # (L, h, p)
        state = st_scr[...]                                  # (h, p, n)

        ys = []
        sts = []
        for gi in range(g):
            Bg, Cg = Bq[:, gi], Cq[:, gi]                    # (L, n)
            CB = jnp.dot(Cg, Bg.T, preferred_element_type=jnp.float32)
            for qi in range(hpg):
                hi = gi * hpg + qi
                cs_h = cs[:, hi]                             # (L,)
                seg = cs_h[:, None] - cs_h[None, :]
                dec = jnp.where(trilb,
                                jnp.exp(jnp.where(trilb, seg, 0.0)), 0.0)
                x_h = xdt[:, hi]                             # (L, p)
                y_h = jnp.dot(CB * dec, x_h,
                              preferred_element_type=jnp.float32)
                y_h += jnp.dot(Cg, state[hi].T,
                               preferred_element_type=jnp.float32) * \
                    jnp.exp(cs_h)[:, None]
                dst = jnp.exp(cs_h[-1] - cs_h)
                st_h = jnp.exp(cs_h[-1]) * state[hi] + \
                    jnp.dot((x_h * dst[:, None]).T, Bg,
                            preferred_element_type=jnp.float32)
                ys.append(y_h)
                sts.append(st_h)
        y = jnp.stack(ys, axis=1)                            # (L, h, p)
        new_state = jnp.stack(sts, axis=0)                   # (h, p, n)

        y = y.astype(sd) + xs.reshape(L, h, p) * \
            d_ref[...].astype(sd).reshape(h)[None, :, None]
        yf = y.reshape(L, di).astype(jnp.float32)
        ms = jnp.mean(yf * yf, axis=-1, keepdims=True)
        yn = yf * jax.lax.rsqrt(ms + eps) * ns_ref[...].astype(jnp.float32)
        out = yn.astype(sd) * silu(z_ref[0])

        y_ref[0] = out.astype(y_ref.dtype)
        tail_scr[...] = win[L:]
        st_scr[...] = new_state
        co_ref[0] = win[L:].astype(co_ref.dtype)
        so_ref[0] = new_state.astype(so_ref.dtype)

    return kernel


def mamba2_prefill_pallas(z: Array, xbc: Array, dt: Array, conv_state: Array,
                          ssm_state: Array, conv_w: Array, conv_b: Array,
                          dt_bias: Array, A: Array, D: Array,
                          norm_scale: Array, *, ngroups: int, head_dim: int,
                          chunk: int, silu: Callable, softplus: Callable,
                          eps: float = 1e-6, interpret: bool = False
                          ) -> Tuple[Array, Array, Array]:
    """One-kernel prefill (shapes/contract as :func:`mamba2_prefill_xla`).

    Grid ``(batch, nchunks)`` with both axes "arbitrary": the sequential
    row-major walk lets VMEM scratch carry each row's conv tail and SSM
    state chunk-to-chunk; the state outputs revisit one block per batch
    row, so only the final chunk's write leaves VMEM.
    """
    b, l, di = z.shape
    g, p = ngroups, head_dim
    h = dt.shape[-1]
    dxbc = xbc.shape[-1]
    n = (dxbc - di) // (2 * g)
    width = conv_w.shape[0]
    assert l % chunk == 0, (l, chunk)
    nchunks = l // chunk

    kernel = _prefill_kernel(width, di, g, p, n, silu, softplus, eps)
    row = lambda bi, ci: (bi, ci, 0)
    head = lambda bi, ci: (bi, 0, 0)
    rep2 = lambda bi, ci: (0, 0)
    y, new_conv, new_ssm = common.pallas_call(
        kernel,
        grid=(b, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, di), row),
            pl.BlockSpec((1, chunk, dxbc), row),
            pl.BlockSpec((1, chunk, h), row),
            pl.BlockSpec((1, width - 1, dxbc), head),
            pl.BlockSpec((1, h, p, n), lambda bi, ci: (bi, 0, 0, 0)),
            pl.BlockSpec((width, dxbc), rep2),
            pl.BlockSpec((1, dxbc), rep2),
            pl.BlockSpec((1, h), rep2),
            pl.BlockSpec((1, h), rep2),
            pl.BlockSpec((1, h), rep2),
            pl.BlockSpec((1, di), rep2),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di), row),
            pl.BlockSpec((1, width - 1, dxbc), head),
            pl.BlockSpec((1, h, p, n), lambda bi, ci: (bi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, di), z.dtype),
            jax.ShapeDtypeStruct((b, width - 1, dxbc), conv_state.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((width - 1, dxbc), jnp.float32),
            pltpu.VMEM((h, p, n), jnp.float32),
        ],
        dimension_semantics=("arbitrary", "arbitrary"),
        interpret=interpret,
        name="mamba2_prefill",
    )(z, xbc, dt, conv_state, ssm_state, conv_w,
      conv_b.reshape(1, dxbc), dt_bias.reshape(1, h), A.reshape(1, h),
      D.reshape(1, h), norm_scale.reshape(1, di))
    return y, new_conv, new_ssm
