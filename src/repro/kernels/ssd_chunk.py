"""Fused SSD intra-chunk Pallas kernel (CumBA inside the hot loop).

One grid step processes one (batch, chunk, head) cell entirely in VMEM:

    cs      = A_cum row (precomputed prefix decay, fp32)
    L       = exp(segsum)   -- via the CumBA broadcast-difference of ``cs``
    scores  = (C @ B^T) * L          (MXU, (L, L))
    y_diag  = scores @ x             (MXU, (L, p))
    state   = (x * decay).T @ B      (MXU, (p, n))  -- the chunk's outgoing state

i.e. the paper's CumSum_b bottleneck *and* the three einsum contractions
(ReduBA) fuse into a single kernel with zero intermediate HBM traffic — the
(L, L) decay matrix never leaves VMEM.  The inter-chunk recurrence stays
outside (associative scan over ~L/chunk terms, negligible).

Shapes (fp32 in, native SSD convention, heads-per-group broadcast handled by
the BlockSpec index map, so grouped B/C are read once per group):
  x_c:   (b, c, L, h, p)   dt-scaled values
  a_c:   (b, h, c, L)      per-step log decay
  A_cum: (b, h, c, L)      inclusive cumsum of a_c
  B_c:   (b, c, L, g, n)
  C_c:   (b, c, L, g, n)
Outputs:
  y_diag: (b, c, L, h, p)
  states: (b, c, h, p, n)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

Array = jax.Array


def _ssd_chunk_kernel(x_ref, acum_ref, b_ref, c_ref, y_ref, st_ref):
    cs = acum_ref[0, 0, ...].astype(jnp.float32)            # (1, L) row
    cs = cs.reshape(-1)                                     # (L,)
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)            # (L, p)
    B = b_ref[0, 0, :, 0, :].astype(jnp.float32)            # (L, n)
    C = c_ref[0, 0, :, 0, :].astype(jnp.float32)            # (L, n)
    L = x.shape[0]

    # CumBA segsum: S_ij = cs_i - cs_j, masked above the diagonal.
    seg = cs[:, None] - cs[None, :]
    tril = jnp.tril(jnp.ones((L, L), jnp.bool_))
    decay = jnp.where(tril, jnp.exp(jnp.where(tril, seg, 0.0)), 0.0)

    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # MXU
    y = jnp.dot(scores * decay, x, preferred_element_type=jnp.float32)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    # Outgoing chunk state: sum_l B_l (exp(cs_last - cs_l)) x_l
    dstate = jnp.exp(cs[-1] - cs)                            # (L,)
    st = jnp.dot((x * dstate[:, None]).T, B,
                 preferred_element_type=jnp.float32)         # (p, n)
    st_ref[0, 0, 0, :, :] = st.astype(st_ref.dtype)


def ssd_chunk(x_c: Array, a_c: Array, A_cum: Array, B_c: Array, C_c: Array,
              *, interpret: bool = False):
    """Run the fused intra-chunk pass. See module docstring for shapes."""
    b, c, L, h, p = x_c.shape
    g, n = B_c.shape[3], B_c.shape[4]
    hpg = h // g

    grid = (b, c, h)
    y, st = common.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, L), lambda bi, ci, hi: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, L, 1, n), lambda bi, ci, hi: (bi, ci, 0, hi // hpg, 0)),
            pl.BlockSpec((1, 1, L, 1, n), lambda bi, ci, hi: (bi, ci, 0, hi // hpg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, L, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, c, h, p, n), jnp.float32),
        ],
        dimension_semantics=("parallel", "parallel", "parallel"),
        interpret=interpret,
        name="ssd_chunk",
    )(x_c.astype(jnp.float32), A_cum.astype(jnp.float32),
      B_c.astype(jnp.float32), C_c.astype(jnp.float32))
    return y, st
