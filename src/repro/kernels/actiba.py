"""ActiBA Pallas kernel: piecewise-linear activation evaluation.

The NPU evaluates PWL activations with a slope/intercept C-LUT in the drain
path.  The TPU kernel bakes the fitted table (``core/pwl.py``) into the
kernel as compile-time scalars and evaluates the gather-free basis form

    f(x) = m0*x + c0 + sum_k dm_k * relu(x - b_k)

entirely in VMEM — K fused multiply-add/max passes on the VPU, no LUT
gather, no extra HBM traffic.  (For producer-fused evaluation — the paper's
"vertical fusion" — see ``kernels/matmul_pwl.py`` which applies the same
epilogue during the matmul drain.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.pwl import PWLTable
from repro.kernels import common

Array = jax.Array


def make_pwl_epilogue(table: PWLTable):
    """Return a traced-constant PWL evaluator usable inside any kernel."""
    dm, m0, c0 = table.basis()
    bps = np.asarray(table.breakpoints, np.float32)
    dm = dm.astype(np.float32)

    def epilogue(x):
        xf = x.astype(jnp.float32)
        y = np.float32(m0) * xf + np.float32(c0)
        for k in range(dm.shape[0]):
            y = y + dm[k] * jnp.maximum(xf - bps[k], 0.0)
        return y

    return epilogue


def _actiba_kernel(table: PWLTable):
    epi = make_pwl_epilogue(table)

    def kernel(x_ref, o_ref):
        o_ref[...] = epi(x_ref[...]).astype(o_ref.dtype)

    return kernel


def pwl_activate(x: Array, table: PWLTable, *, block_rows: int = 512,
                 block_cols: int = 512, interpret: bool = False) -> Array:
    """Elementwise PWL activation over an arbitrary-shaped array."""
    orig_shape = x.shape
    n = orig_shape[-1] if x.ndim else 1
    rows = x.size // n
    x2 = x.reshape(rows, n)
    br = min(block_rows, common.round_up(rows, 8))
    bc = min(block_cols, common.round_up(n, 128))
    rp, cp = common.round_up(rows, br), common.round_up(n, bc)
    x2 = common.pad_axis(common.pad_axis(x2, 0, rp), 1, cp)

    out = common.pallas_call(
        _actiba_kernel(table),
        grid=(rp // br, cp // bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), x.dtype),
        dimension_semantics=("parallel", "parallel"),
        interpret=interpret,
        name=f"actiba_{table.name}",
    )(x2)
    return out[:rows, :n].reshape(orig_shape)
