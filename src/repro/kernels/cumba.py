"""CumBA Pallas kernel: cumulative sum as a blocked triangular matmul.

The paper's CumBA computes ``C = M_CumBA @ X`` with a compile-time lower-
triangular ones mask so the cumsum runs on the NPU MAC array instead of the
sequential DSP.  The TPU-native version tiles the computation so that

* the only mask ever materialized is one (bT, bT) block held in VMEM as a
  compile-time constant (the HBM mask traffic the paper compresses with ZVC
  is *zero* here — structural skip is strictly stronger than compression);
* blocks strictly above the diagonal of the implicit (T, T) mask are never
  scheduled at all: the cross-block prefix is carried in a VMEM scratch
  accumulator across the sequential grid dimension (one running vector add
  per block instead of a (bT, bT) matmul);
* the in-block triangular multiply lands on the MXU
  (``jnp.dot`` with fp32 accumulation).

Layout: the scanned axis is the trailing (lane) axis; leading axes are
flattened into rows (sublanes).  out[r, i] = sum_{j<=i} x[r, j] is computed
per (bR, bT) block as ``x_block @ triu_ones + carry``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

Array = jax.Array


def _cumba_kernel(x_ref, o_ref, carry_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(jnp.float32)              # (bR, bT)
    bt = x.shape[1]
    # Compile-time constant block of M_CumBA^T (upper-tri): out = x @ mask.
    mask = jnp.triu(jnp.ones((bt, bt), jnp.float32))
    local = jnp.dot(x, mask, preferred_element_type=jnp.float32)  # MXU
    o_ref[...] = (local + carry_ref[...]).astype(o_ref.dtype)
    # Running prefix for all later blocks of this row-stripe (the skipped
    # lower-left mask blocks reduce to this single vector add).
    carry_ref[...] = carry_ref[...] + jnp.sum(x, axis=1, keepdims=True)


def cumsum_last(x: Array, *, block_rows: int = 256, block_t: int = 256,
                interpret: bool = False) -> Array:
    """Cumulative sum along the trailing axis of ``x`` (any leading shape)."""
    orig_shape = x.shape
    t = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, t)

    bt = min(block_t, common.round_up(t, 128))
    br = min(block_rows, common.round_up(rows, 8))
    tp = common.round_up(t, bt)
    rp = common.round_up(rows, br)
    x2 = common.pad_axis(common.pad_axis(x2, 1, tp), 0, rp)

    out = common.pallas_call(
        _cumba_kernel,
        grid=(rp // br, tp // bt),
        in_specs=[pl.BlockSpec((br, bt), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, tp), x.dtype),
        scratch_shapes=[pltpu.VMEM((br, 1), jnp.float32)],
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
        name="cumba_cumsum",
    )(x2)
    return out[:rows, :t].reshape(orig_shape)
