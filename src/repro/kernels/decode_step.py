"""Fused single-token decode-step Pallas kernels (one per mixer family).

The continuous-batching engine calls ``decode_step`` once per generated
token, so the per-step op chain — conv-tail shift, SiLU, Softplus(dt),
the SSM state update and the output gate — is the hottest code in the
repo.  XLA executes it as dozens of tiny HBM-roundtripping ops; these
kernels run the whole post-``in_proj`` / pre-``out_proj`` chain for one
batch row in VMEM, writing each state exactly once:

* ``mamba2_step``  — conv shift + SiLU + softplus(dt) + SSD recurrence +
                     D-skip + gated RMSNorm + SiLU(z) gate;
* ``mamba1_step``  — conv shift + SiLU + x_proj/dt_proj matmuls +
                     softplus + selective-scan recurrence + SiLU(z) gate;
* ``rglru_step``   — conv shift + r/i sigmoid gates + RG-LRU update +
                     GeLU(gate) output gate;
* ``ssd_step`` / ``sscan_step`` — the bare recurrent updates, used when
  ``core/{ssd,selective_scan}.py`` are called directly in ``pallas`` mode.

Activations honor ActiBA: callers pass the (compile-time) activation
callables from ``core.pwl.activation`` so the PWL tables are baked into
the kernel body, exactly like the NPU's C-LUT programming.

Grids are one program per batch row (decode batches are slot counts —
small); every ref keeps >= 2 dims for TPU layout friendliness.  On CPU
use ``interpret=True``; numerics are fp32 throughout, tied to the
``kernels/ref.py`` oracles at <= 1e-5.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

Array = jax.Array


def _row(shape):
    """BlockSpec for a per-batch-row block: (1, ...) indexed by program 0."""
    ndim = len(shape)
    return pl.BlockSpec((1,) + tuple(shape),
                        lambda i, _nd=ndim: (i,) + (0,) * _nd)


def _rep(shape):
    """BlockSpec for a broadcast (weight) block shared by every program."""
    ndim = len(shape)
    return pl.BlockSpec(tuple(shape), lambda i, _nd=ndim: (0,) * (_nd))


def _f32(ref):
    return ref[...].astype(jnp.float32)


# ============================================================================
# Bare recurrent updates (core-level dispatch targets)
# ============================================================================

def _ssd_update(st, x, dt, A, B, C):
    """st (h,p,n), x (h,p), dt/A (1,h), B/C (g,n) -> (new_st, y (h,p))."""
    h, p, n = st.shape
    g = B.shape[0]
    hpg = h // g
    decay = jnp.exp(dt[0] * A[0])                            # (h,)
    Bh = jnp.broadcast_to(B[:, None, :], (g, hpg, n)).reshape(h, n)
    Ch = jnp.broadcast_to(C[:, None, :], (g, hpg, n)).reshape(h, n)
    new = st * decay[:, None, None] + \
        (dt[0][:, None] * x)[..., None] * Bh[:, None, :]
    y = jnp.sum(new * Ch[:, None, :], axis=-1)               # (h, p)
    return new, y


def ssd_step(state: Array, x_t: Array, dt_t: Array, A: Array,
             B_t: Array, C_t: Array, *,
             interpret: bool = False) -> Tuple[Array, Array]:
    """state (b,h,p,n), x_t (b,h,p), dt_t (b,h), A (h,), B_t/C_t (b,g,n)."""
    b, h, p, n = state.shape
    g = B_t.shape[1]
    A2 = A.reshape(1, h).astype(jnp.float32)

    def kernel(st_ref, x_ref, dt_ref, a_ref, b_ref, c_ref, ns_ref, y_ref):
        new, y = _ssd_update(_f32(st_ref)[0], _f32(x_ref)[0], _f32(dt_ref),
                             _f32(a_ref), _f32(b_ref)[0], _f32(c_ref)[0])
        ns_ref[0] = new.astype(ns_ref.dtype)
        y_ref[0] = y.astype(y_ref.dtype)

    new_state, y = common.pallas_call(
        kernel, grid=(b,),
        in_specs=[_row((h, p, n)), _row((h, p)), _row((h,)), _rep((1, h)),
                  _row((g, n)), _row((g, n))],
        out_specs=(_row((h, p, n)), _row((h, p))),
        out_shape=(jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
                   jax.ShapeDtypeStruct((b, h, p), x_t.dtype)),
        dimension_semantics=("parallel",),
        interpret=interpret, name="ssd_decode_step",
    )(state, x_t, dt_t, A2, B_t, C_t)
    return new_state, y


def _sscan_update(st, u, dt, A, B, C, D):
    """st (d,n), u/dt (1,d), A (d,n), B/C (1,n), D (1,d) or None."""
    decay = jnp.exp(dt[0][:, None] * A)                      # (d, n)
    new = st * decay + (dt[0] * u[0])[:, None] * B[0][None, :]
    y = jnp.sum(new * C[0][None, :], axis=-1)                # (d,)
    if D is not None:
        y = y + D[0] * u[0]
    return new, y


def sscan_step(state: Array, u_t: Array, delta_t: Array, A: Array,
               B_t: Array, C_t: Array, D: Optional[Array] = None, *,
               interpret: bool = False) -> Tuple[Array, Array]:
    """state (b,d,n), u_t/delta_t (b,d), A (d,n), B_t/C_t (b,n), D (d,)."""
    b, d, n = state.shape
    has_d = D is not None
    D2 = (D.reshape(1, d).astype(jnp.float32) if has_d
          else jnp.zeros((1, d), jnp.float32))

    def kernel(st_ref, u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
               ns_ref, y_ref):
        new, y = _sscan_update(_f32(st_ref)[0], _f32(u_ref), _f32(dt_ref),
                               _f32(a_ref), _f32(b_ref), _f32(c_ref),
                               _f32(d_ref) if has_d else None)
        ns_ref[0] = new.astype(ns_ref.dtype)
        y_ref[0] = y.astype(y_ref.dtype)

    new_state, y = common.pallas_call(
        kernel, grid=(b,),
        in_specs=[_row((d, n)), _row((d,)), _row((d,)), _rep((d, n)),
                  _row((n,)), _row((n,)), _rep((1, d))],
        out_specs=(_row((d, n)), _row((d,))),
        out_shape=(jax.ShapeDtypeStruct((b, d, n), jnp.float32),
                   jax.ShapeDtypeStruct((b, d), u_t.dtype)),
        dimension_semantics=("parallel",),
        interpret=interpret, name="sscan_decode_step",
    )(state, u_t, delta_t, A.astype(jnp.float32), B_t, C_t, D2)
    return new_state, y


# ============================================================================
# Fused mixer steps (conv tail + activations + recurrence + output gate)
# ============================================================================

def _conv_shift(conv_state, x_row, w, bias):
    """conv_state (w-1,d), x_row (1,d), w (width,d), bias (1,d) ->
    (conv_out (1,d), new_state (w-1,d)) — one causal-conv step."""
    win = jnp.concatenate([conv_state, x_row], axis=0)       # (width, d)
    out = jnp.sum(win * w, axis=0, keepdims=True) + bias     # (1, d)
    return out, win[1:]


def mamba2_step(z: Array, xbc: Array, dt: Array, conv_state: Array,
                ssm_state: Array, conv_w: Array, conv_b: Array,
                dt_bias: Array, A: Array, D: Array, norm_scale: Array, *,
                ngroups: int, head_dim: int,
                silu: Callable = jax.nn.silu,
                softplus: Callable = jax.nn.softplus,
                eps: float = 1e-6,
                interpret: bool = False) -> Tuple[Array, Array, Array]:
    """Fused Mamba-2 decode step for one token.

    z (b,di), xbc (b,dxbc), dt (b,h) — the ``in_proj`` splits;
    conv_state (b,w-1,dxbc), ssm_state (b,h,p,n); conv_w (w,dxbc);
    conv_b (dxbc,), dt_bias/A/D (h,), norm_scale (di,).
    A is the negative decay rate (``-exp(A_log)``).
    Returns (y (b,di) — gated, pre-``out_proj``; new_conv; new_ssm).
    """
    b, di = z.shape
    h = dt.shape[1]
    p = head_dim
    g = ngroups
    n = ssm_state.shape[-1]
    w = conv_w.shape[0]
    dxbc = xbc.shape[1]

    conv_b2 = conv_b.reshape(1, dxbc).astype(jnp.float32)
    dtb2 = dt_bias.reshape(1, h).astype(jnp.float32)
    A2 = A.reshape(1, h).astype(jnp.float32)
    D2 = D.reshape(1, h).astype(jnp.float32)
    ns2 = norm_scale.reshape(1, di).astype(jnp.float32)

    def kernel(z_ref, xbc_ref, dt_ref, cs_ref, st_ref, cw_ref, cb_ref,
               dtb_ref, a_ref, d_ref, nsc_ref, y_ref, nc_ref, nst_ref):
        conv_out, new_conv = _conv_shift(_f32(cs_ref)[0], _f32(xbc_ref),
                                         _f32(cw_ref), _f32(cb_ref))
        act = silu(conv_out)                                 # (1, dxbc)
        xs = act[0, :di].reshape(h, p)
        B = act[0, di:di + g * n].reshape(g, n)
        C = act[0, di + g * n:].reshape(g, n)
        dt_f = softplus(_f32(dt_ref) + _f32(dtb_ref))        # (1, h)
        new, y = _ssd_update(_f32(st_ref)[0], xs, dt_f, _f32(a_ref), B, C)
        y = y + _f32(d_ref)[0][:, None] * xs                 # D skip
        yf = y.reshape(1, di)
        ms = jnp.mean(yf * yf, axis=-1, keepdims=True)
        yn = yf * jax.lax.rsqrt(ms + eps) * _f32(nsc_ref)    # gated RMSNorm
        out = yn * silu(_f32(z_ref))
        y_ref[...] = out.astype(y_ref.dtype)
        nc_ref[0] = new_conv.astype(nc_ref.dtype)
        nst_ref[0] = new.astype(nst_ref.dtype)

    y, new_conv, new_ssm = common.pallas_call(
        kernel, grid=(b,),
        in_specs=[_row((di,)), _row((dxbc,)), _row((h,)),
                  _row((w - 1, dxbc)), _row((h, p, n)),
                  _rep((w, dxbc)), _rep((1, dxbc)), _rep((1, h)),
                  _rep((1, h)), _rep((1, h)), _rep((1, di))],
        out_specs=(_row((di,)), _row((w - 1, dxbc)), _row((h, p, n))),
        out_shape=(jax.ShapeDtypeStruct((b, di), z.dtype),
                   jax.ShapeDtypeStruct((b, w - 1, dxbc), conv_state.dtype),
                   jax.ShapeDtypeStruct((b, h, p, n), jnp.float32)),
        dimension_semantics=("parallel",),
        interpret=interpret, name="mamba2_decode_step",
    )(z, xbc, dt, conv_state, ssm_state, conv_w.astype(jnp.float32),
      conv_b2, dtb2, A2, D2, ns2)
    return y, new_conv, new_ssm


def mamba1_step(xs_raw: Array, z: Array, conv_state: Array, ssm_state: Array,
                conv_w: Array, conv_b: Array, xproj_w: Array, dtproj_w: Array,
                dtproj_b: Array, A: Array, D: Array, *,
                dt_rank: int,
                silu: Callable = jax.nn.silu,
                softplus: Callable = jax.nn.softplus,
                interpret: bool = False) -> Tuple[Array, Array, Array]:
    """Fused Mamba-1 decode step.

    xs_raw/z (b,di) — the ``in_proj`` halves; conv_state (b,w-1,di);
    ssm_state (b,di,n); xproj_w (di, dt_rank+2n); dtproj_w (dt_rank,di);
    dtproj_b (di,); A (di,n) negative; D (di,).
    Returns (y (b,di) — gated, pre-``out_proj``; new_conv; new_ssm).
    """
    b, di = z.shape
    n = ssm_state.shape[-1]
    w = conv_w.shape[0]
    r = dt_rank

    conv_b2 = conv_b.reshape(1, di).astype(jnp.float32)
    dtb2 = dtproj_b.reshape(1, di).astype(jnp.float32)
    D2 = D.reshape(1, di).astype(jnp.float32)

    def kernel(x_ref, z_ref, cs_ref, st_ref, cw_ref, cb_ref, xp_ref,
               dtw_ref, dtb_ref, a_ref, d_ref, y_ref, nc_ref, nst_ref):
        conv_out, new_conv = _conv_shift(_f32(cs_ref)[0], _f32(x_ref),
                                         _f32(cw_ref), _f32(cb_ref))
        xs = silu(conv_out)                                  # (1, di)
        dbc = jnp.dot(xs, _f32(xp_ref),
                      preferred_element_type=jnp.float32)    # (1, r+2n)
        dt_low, B, C = dbc[:, :r], dbc[:, r:r + n], dbc[:, r + n:]
        dt_f = softplus(jnp.dot(dt_low, _f32(dtw_ref),
                                preferred_element_type=jnp.float32) +
                        _f32(dtb_ref))                       # (1, di)
        new, y = _sscan_update(_f32(st_ref)[0], xs, dt_f, _f32(a_ref),
                               B, C, _f32(d_ref))
        out = y[None] * silu(_f32(z_ref))
        y_ref[...] = out.astype(y_ref.dtype)
        nc_ref[0] = new_conv.astype(nc_ref.dtype)
        nst_ref[0] = new.astype(nst_ref.dtype)

    y, new_conv, new_ssm = common.pallas_call(
        kernel, grid=(b,),
        in_specs=[_row((di,)), _row((di,)), _row((w - 1, di)),
                  _row((di, n)), _rep((w, di)), _rep((1, di)),
                  _rep((di, r + 2 * n)), _rep((r, di)), _rep((1, di)),
                  _rep((di, n)), _rep((1, di))],
        out_specs=(_row((di,)), _row((w - 1, di)), _row((di, n))),
        out_shape=(jax.ShapeDtypeStruct((b, di), z.dtype),
                   jax.ShapeDtypeStruct((b, w - 1, di), conv_state.dtype),
                   jax.ShapeDtypeStruct((b, di, n), jnp.float32)),
        dimension_semantics=("parallel",),
        interpret=interpret, name="mamba1_decode_step",
    )(xs_raw, z, conv_state, ssm_state, conv_w.astype(jnp.float32),
      conv_b2, xproj_w, dtproj_w, dtb2, A.astype(jnp.float32), D2)
    return y, new_conv, new_ssm


_RG_C = common.RG_LRU_C  # Griffin's fixed gate exponent


def rglru_step(u: Array, gate: Array, conv_state: Array, h_state: Array,
               conv_w: Array, conv_b: Array, rg_w: Array, rg_b: Array,
               ig_w: Array, ig_b: Array, lam: Array, *,
               sigmoid: Callable = jax.nn.sigmoid,
               softplus: Callable = jax.nn.softplus,
               gelu: Callable = jax.nn.gelu,
               interpret: bool = False) -> Tuple[Array, Array, Array]:
    """Fused RG-LRU decode step.

    u/gate (b,w) — the ``in_x``/``in_gate`` projections; conv_state
    (b,wc-1,w); h_state (b,w); rg_w/ig_w (w,w) with (w,) biases; lam (w,).
    Returns (y (b,w) — gated, pre-``out``; new_conv; new_h).
    """
    b, wd = u.shape
    wc = conv_w.shape[0]

    conv_b2 = conv_b.reshape(1, wd).astype(jnp.float32)
    rgb2 = rg_b.reshape(1, wd).astype(jnp.float32)
    igb2 = ig_b.reshape(1, wd).astype(jnp.float32)
    lam2 = lam.reshape(1, wd).astype(jnp.float32)

    def kernel(u_ref, g_ref, cs_ref, h_ref, cw_ref, cb_ref, rw_ref, rb_ref,
               iw_ref, ib_ref, lam_ref, y_ref, nc_ref, nh_ref):
        u_c, new_conv = _conv_shift(_f32(cs_ref)[0], _f32(u_ref),
                                    _f32(cw_ref), _f32(cb_ref))
        r = sigmoid(jnp.dot(u_c, _f32(rw_ref),
                            preferred_element_type=jnp.float32) + _f32(rb_ref))
        i = sigmoid(jnp.dot(u_c, _f32(iw_ref),
                            preferred_element_type=jnp.float32) + _f32(ib_ref))
        log_a = -_RG_C * softplus(_f32(lam_ref)) * r
        a = jnp.exp(log_a)
        gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
            * (i * u_c)
        h_new = a * _f32(h_ref) + gated_in                   # (1, w)
        out = h_new * gelu(_f32(g_ref))
        y_ref[...] = out.astype(y_ref.dtype)
        nc_ref[0] = new_conv.astype(nc_ref.dtype)
        nh_ref[...] = h_new.astype(nh_ref.dtype)

    y, new_conv, new_h = common.pallas_call(
        kernel, grid=(b,),
        in_specs=[_row((wd,)), _row((wd,)), _row((wc - 1, wd)), _row((wd,)),
                  _rep((wc, wd)), _rep((1, wd)), _rep((wd, wd)),
                  _rep((1, wd)), _rep((wd, wd)), _rep((1, wd)),
                  _rep((1, wd))],
        out_specs=(_row((wd,)), _row((wc - 1, wd)), _row((wd,))),
        out_shape=(jax.ShapeDtypeStruct((b, wd), u.dtype),
                   jax.ShapeDtypeStruct((b, wc - 1, wd), conv_state.dtype),
                   jax.ShapeDtypeStruct((b, wd), jnp.float32)),
        dimension_semantics=("parallel",),
        interpret=interpret, name="rglru_decode_step",
    )(u, gate, conv_state, h_state, conv_w.astype(jnp.float32), conv_b2,
      rg_w, rgb2, ig_w, igb2, lam2)
    return y, new_conv, new_h
