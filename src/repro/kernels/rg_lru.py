"""RG-LRU chunked linear-recurrence Pallas kernel (recurrentgemma).

    h_t = a_t * h_{t-1} + b_t

with per-channel gates ``a_t in (0, 1)``.  XLA's associative scan
materializes O(log L) intermediate (L, d) tensors in HBM; the kernel instead
streams (block_t, block_d) tiles through VMEM, carrying the running state in
a scratch register across the sequential time-block dimension.  Within a
block the recurrence is unrolled as a serial VPU loop over ``block_t`` steps
— entirely VMEM-resident, so the kernel is bandwidth-optimal (reads a, b
once, writes h once).

Layouts:  a, b: (B, L, D)  ->  h: (B, L, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

Array = jax.Array


def _rg_lru_kernel(block_t: int):
    del block_t

    def kernel(a_ref, b_ref, o_ref, h_ref):
        t = pl.program_id(2)

        @pl.when(t == 0)
        def _init():
            h_ref[...] = jnp.zeros_like(h_ref)

        a = a_ref[0, :, :].astype(jnp.float32)   # (bt, bd)
        b = b_ref[0, :, :].astype(jnp.float32)

        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        # In-block log-depth scan (VPU), then fold in the carried state.
        a_sc, b_sc = jax.lax.associative_scan(comb, (a, b), axis=0)
        h_in = h_ref[0, :]                        # (bd,)
        h_all = b_sc + a_sc * h_in[None, :]
        o_ref[0, :, :] = h_all.astype(o_ref.dtype)
        h_ref[0, :] = h_all[-1, :]

    return kernel


def rg_lru_scan(a: Array, b: Array, *, block_t: int = 256, block_d: int = 512,
                interpret: bool = False) -> Array:
    """Run the gated linear recurrence with zero initial state."""
    assert a.shape == b.shape and a.ndim == 3, (a.shape, b.shape)
    B, L, D = a.shape
    bt = min(block_t, common.round_up(L, 8))
    bd = min(block_d, common.round_up(D, 128))
    lp, dp = common.round_up(L, bt), common.round_up(D, bd)
    # 'a' pads with 1s would propagate state; 0-pad is fine since padded
    # region is sliced away and never feeds back.
    a2 = common.pad_axis(common.pad_axis(a, 1, lp), 2, dp)
    b2 = common.pad_axis(common.pad_axis(b, 1, lp), 2, dp)

    out = common.pallas_call(
        _rg_lru_kernel(bt),
        grid=(B, dp // bd, lp // bt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, bt, bd), lambda bi, di, ti: (bi, ti, di)),
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda bi, di, ti: (bi, ti, di)),
        out_shape=jax.ShapeDtypeStruct((B, lp, dp), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
        name="rg_lru_scan",
    )(a2, b2)
    return out[:, :L, :D]
