"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pwl import PWLTable, eval_pwl

Array = jax.Array


def cumsum_last_ref(x: Array) -> Array:
    return jnp.cumsum(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def reduce_rows_ref(x: Array) -> Array:
    return jnp.sum(x.astype(jnp.float32), axis=0).astype(x.dtype)


def pwl_activate_ref(x: Array, table: PWLTable) -> Array:
    return eval_pwl(table, x)


def matmul_pwl_ref(x: Array, w: Array, table: PWLTable,
                   v: Optional[Array] = None) -> Array:
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    out = eval_pwl(table, acc)
    if v is not None:
        out = out * jnp.dot(x, v, preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def qmatmul_ref(x: Array, q: Array, scale: Array,
                table: Optional[PWLTable] = None,
                qv: Optional[Array] = None,
                vscale: Optional[Array] = None) -> Array:
    """W8 dequant-matmul oracle: dequantize-then-dot in fp32 (shapes as
    kernels/qmatmul.py; per-channel scales commute with the contraction,
    so this equals the kernel's drain-phase rescale)."""
    deq = q.astype(jnp.float32) * scale.reshape(1, -1)
    acc = jnp.dot(x.astype(jnp.float32), deq,
                  preferred_element_type=jnp.float32)
    out = eval_pwl(table, acc) if table is not None else acc
    if qv is not None:
        deqv = qv.astype(jnp.float32) * vscale.reshape(1, -1)
        out = out * jnp.dot(x.astype(jnp.float32), deqv,
                            preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def ssd_chunk_ref(x_c: Array, a_c: Array, A_cum: Array, B_c: Array,
                  C_c: Array):
    """Intra-chunk SSD oracle.  Shapes as in kernels/ssd_chunk.py."""
    b, c, L, h, p = x_c.shape
    g = B_c.shape[3]
    hpg = h // g
    xf = x_c.astype(jnp.float32)
    cs = A_cum.astype(jnp.float32)                         # (b, h, c, L)
    Bh = jnp.repeat(B_c.astype(jnp.float32), hpg, axis=3)  # (b, c, L, h, n)
    Ch = jnp.repeat(C_c.astype(jnp.float32), hpg, axis=3)

    seg = cs[..., :, None] - cs[..., None, :]              # (b, h, c, L, L)
    tril = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tril, jnp.exp(jnp.where(tril, seg, 0.0)), 0.0)

    scores = jnp.einsum("bclhn,bcshn->bhcls", Ch, Bh)
    y = jnp.einsum("bhcls,bcshp->bclhp", scores * decay, xf)

    dstate = jnp.exp(cs[..., -1:] - cs)                    # (b, h, c, L)
    dstate = jnp.transpose(dstate, (0, 2, 3, 1))           # (b, c, L, h)
    states = jnp.einsum("bclhp,bclh,bclhn->bchpn", xf, dstate, Bh)
    return y, states


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> Array:
    """Standard softmax attention with GQA/causal/sliding-window semantics.

    q: (b, hq, Lq, d); k, v: (b, hkv, Lk, d).
    """
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    qpg = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    kq = jnp.repeat(k, qpg, axis=1)
    vq = jnp.repeat(v, qpg, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kq.astype(jnp.float32))
    q_ids = jnp.arange(lq)[:, None]
    k_ids = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= k_ids <= q_ids
    if window is not None:
        mask &= k_ids > q_ids - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def _conv_shift_ref(conv_state, x, w, b):
    """One causal-conv decode step: conv_state (bt,w-1,d), x (bt,d)."""
    win = jnp.concatenate([conv_state.astype(jnp.float32),
                           x.astype(jnp.float32)[:, None]], axis=1)
    out = jnp.sum(win * w.astype(jnp.float32)[None], axis=1) + \
        b.astype(jnp.float32)[None]
    return out, win[:, 1:]


def ssd_step_ref(state, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD recurrence oracle (shapes as core.ssd)."""
    b, h, p, n = state.shape
    hpg = h // B_t.shape[1]
    Bh = jnp.repeat(B_t.astype(jnp.float32), hpg, axis=1)
    Ch = jnp.repeat(C_t.astype(jnp.float32), hpg, axis=1)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None, :])
    dBx = dtf[..., None, None] * Bh[:, :, None, :] * \
        x_t.astype(jnp.float32)[..., None]
    new = state.astype(jnp.float32) * decay[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new, Ch)
    return new, y.astype(x_t.dtype)


def sscan_step_ref(state, u_t, delta_t, A, B_t, C_t, D=None):
    """Single-token selective-scan oracle (shapes as core.selective_scan)."""
    dtf = delta_t.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * A.astype(jnp.float32)[None])
    dBu = (dtf * u_t.astype(jnp.float32))[..., None] * \
        B_t.astype(jnp.float32)[:, None, :]
    new = state.astype(jnp.float32) * decay + dBu
    y = jnp.einsum("bdn,bn->bd", new, C_t.astype(jnp.float32))
    if D is not None:
        y = y + u_t.astype(jnp.float32) * D.astype(jnp.float32)[None]
    return new, y.astype(u_t.dtype)


def mamba2_step_ref(z, xbc, dt, conv_state, ssm_state, conv_w, conv_b,
                    dt_bias, A, D, norm_scale, *, ngroups, head_dim,
                    silu=jax.nn.silu, softplus=jax.nn.softplus, eps=1e-6):
    """Fused Mamba-2 decode-step oracle (shapes as kernels.decode_step)."""
    b, di = z.shape
    g, p = ngroups, head_dim
    n = ssm_state.shape[-1]
    h = dt.shape[1]
    conv_out, new_conv = _conv_shift_ref(conv_state, xbc, conv_w, conv_b)
    act = silu(conv_out)
    xs = act[:, :di].reshape(b, h, p)
    B = act[:, di:di + g * n].reshape(b, g, n)
    C = act[:, di + g * n:].reshape(b, g, n)
    dt_f = softplus(dt.astype(jnp.float32) +
                    dt_bias.astype(jnp.float32)[None])
    new, y = ssd_step_ref(ssm_state, xs, dt_f, A, B, C)
    y = y + D.astype(jnp.float32)[None, :, None] * xs
    yf = y.reshape(b, di)
    ms = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(ms + eps) * norm_scale.astype(jnp.float32)[None]
    out = yn * silu(z.astype(jnp.float32))
    return (out.astype(z.dtype), new_conv.astype(conv_state.dtype),
            new.astype(jnp.float32))


def mamba2_prefill_ref(z, xbc, dt, conv_state, ssm_state, conv_w, conv_b,
                       dt_bias, A, D, norm_scale, *, ngroups, head_dim,
                       silu=jax.nn.silu, softplus=jax.nn.softplus,
                       eps=1e-6):
    """Fused prefill-pipeline oracle (shapes as kernels.prefill_chunk):
    plain-jnp conv + activations feeding the exact sequential SSD
    recurrence (``core.ssd.ssd_reference``), then the gated-norm
    epilogue.  z: (b, l, di); xbc: (b, l, dxbc); dt raw (b, l, h)."""
    from repro.core.ssd import ssd_reference
    b, l, di = z.shape
    g, p = ngroups, head_dim
    h = dt.shape[-1]
    n = (xbc.shape[-1] - di) // (2 * g)
    width = conv_w.shape[0]
    win = jnp.concatenate([conv_state.astype(jnp.float32),
                           xbc.astype(jnp.float32)], axis=1)
    conv = sum(win[:, i:i + l] * conv_w.astype(jnp.float32)[i]
               for i in range(width)) + conv_b.astype(jnp.float32)
    act = silu(conv)
    xs = act[..., :di].reshape(b, l, h, p)
    B = act[..., di:di + g * n].reshape(b, l, g, n)
    C = act[..., di + g * n:].reshape(b, l, g, n)
    dt_f = softplus(dt.astype(jnp.float32) + dt_bias.astype(jnp.float32))
    y, new_ssm = ssd_reference(xs, dt_f, A, B, C, initial_state=ssm_state)
    y = y + xs * D.astype(jnp.float32)[None, None, :, None]
    yf = y.reshape(b, l, di)
    ms = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(ms + eps) * norm_scale.astype(jnp.float32)
    out = yn * silu(z.astype(jnp.float32))
    return (out, win[:, l:].astype(conv_state.dtype),
            new_ssm.astype(jnp.float32))


def mamba1_step_ref(xs_raw, z, conv_state, ssm_state, conv_w, conv_b,
                    xproj_w, dtproj_w, dtproj_b, A, D, *, dt_rank,
                    silu=jax.nn.silu, softplus=jax.nn.softplus):
    """Fused Mamba-1 decode-step oracle (shapes as kernels.decode_step)."""
    n = ssm_state.shape[-1]
    r = dt_rank
    conv_out, new_conv = _conv_shift_ref(conv_state, xs_raw, conv_w, conv_b)
    xs = silu(conv_out)
    dbc = jnp.dot(xs, xproj_w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    dt_low, B, C = dbc[:, :r], dbc[:, r:r + n], dbc[:, r + n:]
    dt_f = softplus(jnp.dot(dt_low, dtproj_w.astype(jnp.float32),
                            preferred_element_type=jnp.float32) +
                    dtproj_b.astype(jnp.float32)[None])
    new, y = sscan_step_ref(ssm_state, xs, dt_f, A, B, C, D)
    out = y * silu(z.astype(jnp.float32))
    return (out.astype(z.dtype), new_conv.astype(conv_state.dtype),
            new.astype(jnp.float32))


def rglru_step_ref(u, gate, conv_state, h_state, conv_w, conv_b, rg_w,
                   rg_b, ig_w, ig_b, lam, *, sigmoid=jax.nn.sigmoid,
                   softplus=jax.nn.softplus, gelu=None):
    """Fused RG-LRU decode-step oracle (shapes as kernels.decode_step)."""
    from functools import partial
    gelu = gelu or partial(jax.nn.gelu, approximate=True)
    from repro.kernels.common import RG_LRU_C
    u_c, new_conv = _conv_shift_ref(conv_state, u, conv_w, conv_b)
    r = sigmoid(jnp.dot(u_c, rg_w.astype(jnp.float32)) +
                rg_b.astype(jnp.float32)[None])
    i = sigmoid(jnp.dot(u_c, ig_w.astype(jnp.float32)) +
                ig_b.astype(jnp.float32)[None])
    log_a = -RG_LRU_C * softplus(lam.astype(jnp.float32))[None] * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * u_c)
    h_new = a * h_state.astype(jnp.float32) + gated_in
    out = h_new * gelu(gate.astype(jnp.float32))
    return (out.astype(u.dtype), new_conv.astype(conv_state.dtype),
            h_new.astype(jnp.float32))


def rg_lru_scan_ref(a: Array, b: Array) -> Array:
    """h_t = a_t h_{t-1} + b_t via lax.scan (exact sequential semantics)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(h, t_in):
        at, bt = t_in
        h = at * h + bt
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(af, 1, 0),
                                    jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
