"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pwl import PWLTable, eval_pwl

Array = jax.Array


def cumsum_last_ref(x: Array) -> Array:
    return jnp.cumsum(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def reduce_rows_ref(x: Array) -> Array:
    return jnp.sum(x.astype(jnp.float32), axis=0).astype(x.dtype)


def pwl_activate_ref(x: Array, table: PWLTable) -> Array:
    return eval_pwl(table, x)


def matmul_pwl_ref(x: Array, w: Array, table: PWLTable,
                   v: Optional[Array] = None) -> Array:
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    out = eval_pwl(table, acc)
    if v is not None:
        out = out * jnp.dot(x, v, preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def ssd_chunk_ref(x_c: Array, a_c: Array, A_cum: Array, B_c: Array,
                  C_c: Array):
    """Intra-chunk SSD oracle.  Shapes as in kernels/ssd_chunk.py."""
    b, c, L, h, p = x_c.shape
    g = B_c.shape[3]
    hpg = h // g
    xf = x_c.astype(jnp.float32)
    cs = A_cum.astype(jnp.float32)                         # (b, h, c, L)
    Bh = jnp.repeat(B_c.astype(jnp.float32), hpg, axis=3)  # (b, c, L, h, n)
    Ch = jnp.repeat(C_c.astype(jnp.float32), hpg, axis=3)

    seg = cs[..., :, None] - cs[..., None, :]              # (b, h, c, L, L)
    tril = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tril, jnp.exp(jnp.where(tril, seg, 0.0)), 0.0)

    scores = jnp.einsum("bclhn,bcshn->bhcls", Ch, Bh)
    y = jnp.einsum("bhcls,bcshp->bclhp", scores * decay, xf)

    dstate = jnp.exp(cs[..., -1:] - cs)                    # (b, h, c, L)
    dstate = jnp.transpose(dstate, (0, 2, 3, 1))           # (b, c, L, h)
    states = jnp.einsum("bclhp,bclh,bclhn->bchpn", xf, dstate, Bh)
    return y, states


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> Array:
    """Standard softmax attention with GQA/causal/sliding-window semantics.

    q: (b, hq, Lq, d); k, v: (b, hkv, Lk, d).
    """
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    qpg = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    kq = jnp.repeat(k, qpg, axis=1)
    vq = jnp.repeat(v, qpg, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kq.astype(jnp.float32))
    q_ids = jnp.arange(lq)[:, None]
    k_ids = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= k_ids <= q_ids
    if window is not None:
        mask &= k_ids > q_ids - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def rg_lru_scan_ref(a: Array, b: Array) -> Array:
    """h_t = a_t h_{t-1} + b_t via lax.scan (exact sequential semantics)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(h, t_in):
        at, bt = t_in
        h = at * h + bt
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(af, 1, 0),
                                    jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
