"""ReduBA Pallas kernel: ReduceSum as a ones-vector matmul on the MXU.

``R = M_ReduBA @ X`` with ``M_ReduBA = ones(1, m)``: the reduction over the
row axis of an (m, n) operand becomes a (1, m) x (m, n) matmul.  The kernel
tiles over both axes; the ones "mask" is a single compile-time (1, bm) VMEM
constant reused by every tile (the paper's observation that ReduBA's mask is
reused across all operations, minimizing memory traffic — here it never even
leaves VMEM).  Partial sums accumulate directly into the output block, which
stays resident in VMEM across the sequential reduction dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

Array = jax.Array


def _reduba_kernel(x_ref, o_ref):
    i = pl.program_id(1)  # reduction-block index (innermost, sequential)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                    # (bm, bn)
    ones = jnp.ones((1, x.shape[0]), jnp.float32)         # M_ReduBA tile
    part = jnp.dot(ones, x, preferred_element_type=jnp.float32)  # MXU (1, bn)
    o_ref[...] = o_ref[...] + part.astype(o_ref.dtype)


def reduce_rows(x: Array, *, block_m: int = 512, block_n: int = 512,
                interpret: bool = False) -> Array:
    """Sum over axis 0 of a 2-D array: (m, n) -> (n,)."""
    assert x.ndim == 2, x.shape
    m, n = x.shape
    bm = min(block_m, common.round_up(m, 8))
    bn = min(block_n, common.round_up(n, 128))
    mp = common.round_up(m, bm)
    np_ = common.round_up(n, bn)
    x2 = common.pad_axis(common.pad_axis(x, 0, mp), 1, np_)

    out = common.pallas_call(
        _reduba_kernel,
        grid=(np_ // bn, mp // bm),
        in_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((1, bn), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, np_), common.acc_dtype(x.dtype)),
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
        name="reduba_reduce",
    )(x2)
    return out[0, :n].astype(x.dtype)
