"""Shared Pallas utilities for the XAMBA TPU kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# Griffin's fixed RG-LRU gate exponent — single source for the mixer
# (nn/ssm.py), the fused decode kernel and its oracle.
RG_LRU_C = 8.0


def compiler_params(dimension_semantics):
    """Best-effort TPU compiler params across pallas API versions."""
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            try:
                return cls(dimension_semantics=tuple(dimension_semantics))
            except TypeError:
                pass
    return None


def pallas_call(kernel, *, grid, in_specs, out_specs, out_shape,
                scratch_shapes=(), interpret=False, dimension_semantics=None,
                name=None):
    kwargs = {}
    if dimension_semantics is not None and not interpret:
        cp = compiler_params(dimension_semantics)
        if cp is not None:
            kwargs["compiler_params"] = cp
    return pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, scratch_shapes=list(scratch_shapes),
        interpret=interpret, name=name, **kwargs)


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pad_axis(x, axis: int, target: int, value=0):
    """Pad ``axis`` of ``x`` up to ``target`` with ``value``."""
    cur = x.shape[axis]
    if cur == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - cur)
    return jnp.pad(x, pads, constant_values=value)


def acc_dtype(dtype) -> jnp.dtype:
    return jnp.float32 if dtype in (jnp.bfloat16, jnp.float16) else jnp.dtype(dtype)
