"""Pallas TPU kernels for the XAMBA compute hot-spots.

cumba            CumSum -> blocked triangular matmul (MXU) w/ prefix carry
reduba           ReduceSum -> ones-matvec (MXU), tiled accumulation
actiba           PWL activation (gather-free C-LUT analogue)
matmul_pwl       matmul with drain-phase-fused PWL epilogue (vertical fusion)
qmatmul          W8 dequant-matmul: int8 tiles upconverted in-register,
                 per-channel scale (+ optional PWL epilogue) in the drain
ssd_chunk        fused Mamba-2 SSD intra-chunk pass (CumBA+ReduBA inside)
flash_attention  online-softmax attention (causal / window / GQA)
rg_lru           chunked gated linear recurrence (recurrentgemma)

``ops.py`` holds the public jit'd wrappers; ``ref.py`` the pure-jnp oracles.
All kernels are TPU-targeted (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with ``interpret=True``.
"""
