"""Drain-phase fused matmul + PWL activation (ActiBA "vertical fusion").

The paper's ActiBA evaluates the activation *during the drain phase of the
previous layer* so the pre-activation tensor is never stored and reloaded.
The TPU equivalent is epilogue fusion: a blocked matmul whose fp32
accumulator is transformed by the PWL function in VMEM right before the
(bM, bN) output tile is written to HBM.  Optionally computes a gated unit
``act(x @ w) * (x @ v)`` (SwiGLU/GeGLU-style) in one pass — the layout used
by every assigned architecture's MLP and by Mamba's gate.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pwl import PWLTable
from repro.kernels import common
from repro.kernels.actiba import make_pwl_epilogue

Array = jax.Array


def _matmul_pwl_kernel(table: PWLTable, nk: int, gated: bool):
    epi = make_pwl_epilogue(table)

    if not gated:
        def kernel(x_ref, w_ref, o_ref, acc_ref):
            k = pl.program_id(2)

            @pl.when(k == 0)
            def _init():
                acc_ref[...] = jnp.zeros_like(acc_ref)

            acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                    preferred_element_type=jnp.float32)

            @pl.when(k == nk - 1)
            def _drain():
                # ActiBA: activation applied in the drain, no HBM round-trip.
                o_ref[...] = epi(acc_ref[...]).astype(o_ref.dtype)

        return kernel

    def kernel(x_ref, w_ref, v_ref, o_ref, acc_ref, gacc_ref):
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            gacc_ref[...] = jnp.zeros_like(gacc_ref)

        x = x_ref[...]
        acc_ref[...] += jnp.dot(x, w_ref[...],
                                preferred_element_type=jnp.float32)
        gacc_ref[...] += jnp.dot(x, v_ref[...],
                                 preferred_element_type=jnp.float32)

        @pl.when(k == nk - 1)
        def _drain():
            o_ref[...] = (epi(acc_ref[...]) * gacc_ref[...]).astype(o_ref.dtype)

    return kernel


def matmul_pwl(x: Array, w: Array, table: PWLTable,
               v: Optional[Array] = None, *,
               block_m: int = 256, block_n: int = 256, block_k: int = 512,
               interpret: bool = False) -> Array:
    """``pwl(x @ w)`` or, with ``v``, ``pwl(x @ w) * (x @ v)``.

    x: (m, k); w, v: (k, n) -> (m, n).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    gated = v is not None

    bm = min(block_m, common.round_up(m, 8))
    bn = min(block_n, common.round_up(n, 128))
    bk = min(block_k, common.round_up(k, 128))
    mp, np_, kp = (common.round_up(m, bm), common.round_up(n, bn),
                   common.round_up(k, bk))
    x2 = common.pad_axis(common.pad_axis(x, 0, mp), 1, kp)
    w2 = common.pad_axis(common.pad_axis(w, 0, kp), 1, np_)
    nk = kp // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    operands = [x2, w2]
    if gated:
        v2 = common.pad_axis(common.pad_axis(v, 0, kp), 1, np_)
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
        scratch.append(pltpu.VMEM((bm, bn), jnp.float32))
        operands.append(v2)

    out = common.pallas_call(
        _matmul_pwl_kernel(table, nk, gated),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=scratch,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
        name=f"matmul_pwl_{table.name}{'_gated' if gated else ''}",
    )(*operands)
    return out[:m, :n]
