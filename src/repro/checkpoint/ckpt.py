"""Atomic, reshardable checkpointing.

Layout: ``<dir>/step_<N>/`` holding one ``arrays.npz`` (flattened pytree)
plus ``manifest.json`` (tree structure, shapes, dtypes, data-iterator state).
Writes go to ``step_<N>.tmp`` then ``os.rename`` — a crash mid-write never
corrupts the latest checkpoint (restore only ever sees complete dirs).

``restore(..., shardings=...)`` re-device_puts onto the *current* mesh, so a
job restarted on a different device count / mesh shape reloads the same
logical arrays — this is the elastic-scaling path (see runtime/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":
            # ml_dtypes (bfloat16 etc) round-trip poorly through npz;
            # upcast to f32 (lossless for bf16), restore downcasts.
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def save(ckpt_dir: str | Path, step: int, state: PyTree,
         extra: Optional[dict] = None, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    arrays, _ = _flatten(state)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "keys": list(arrays.keys()),
        "extra": extra or {},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)


def all_steps(ckpt_dir: str | Path):
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        if p.is_dir() and p.name.startswith("step_") and \
                not p.name.endswith(".tmp") and \
                (p / "manifest.json").exists():
            out.append(int(p.name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, target: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None):
    """Restore into the structure of ``target`` (a concrete or abstract
    pytree).  With ``shardings``, arrays land sharded on the current mesh
    (reshard-on-load)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = ckpt_dir / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    with open(path / "manifest.json") as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
    out = []
    for i, (p, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        jarr = jax.numpy.asarray(arr).astype(want_dtype)
        if sh_leaves is not None:
            jarr = jax.device_put(jarr, sh_leaves[i])
        out.append(jarr)
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, step, manifest.get("extra", {})
