from repro.checkpoint.async_writer import AsyncCheckpointer  # noqa: F401
from repro.checkpoint.ckpt import (all_steps, latest_step, restore,  # noqa: F401
                                   save)
