"""Async checkpointing: the step loop never blocks on serialization.

``AsyncCheckpointer.save`` snapshots the (device) state to host memory
synchronously — cheap relative to a step — then a single worker thread
serializes and atomically publishes it.  A bounded queue of 1 applies
backpressure instead of accumulating snapshots; ``wait()`` drains before
exit/restore.
"""
from __future__ import annotations

import queue
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt


class AsyncCheckpointer:
    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._done = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._done.set()
                return
            step, host_state, extra = item
            try:
                ckpt.save(self.ckpt_dir, step, host_state, extra, self.keep)
            except BaseException as e:  # noqa: BLE001
                self._err = e

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        if self._err is not None:
            raise self._err
        # Snapshot to host; device buffers are then free to be donated.
        host_state = jax.tree.map(np.asarray, state)
        self._q.put((step, host_state, extra))

    def wait(self):
        """Drain pending writes and stop the worker."""
        self._q.put(None)
        self._done.wait()
        self._thread.join(timeout=60)
        if self._err is not None:
            raise self._err
