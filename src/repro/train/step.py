"""Train step factory: loss -> grads -> AdamW, with microbatch accumulation
and optional compressed cross-pod gradient sync.

The returned ``train_step(state, batch)`` is pure and jit-friendly; the
launcher decides shardings (params via the logical rules, batch via the
activation layout) and jits it once per mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import collectives
from repro.optim import AdamWConfig, ScheduleConfig, adamw, schedule as sched

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    schedule: ScheduleConfig = ScheduleConfig()
    microbatches: int = 1
    # Cross-pod int8 gradient compression (multi-pod meshes only).  When on,
    # params replicate across the pod axis (FSDP restricted to "data") and
    # the pod-axis gradient reduction runs through
    # distributed/collectives.compressed_pod_psum.
    compressed_cross_pod: bool = False


def init_state(model, rng: jax.Array, train_cfg: TrainConfig,
               mesh=None) -> dict:
    from repro.nn.params import init_params
    params = init_params(model.param_specs(), rng, model.cfg.dtype)
    state = {"params": params,
             "opt": adamw.init(params, train_cfg.optimizer)}
    if train_cfg.compressed_cross_pod:
        state["err"] = collectives.init_errors(params)
    return state


def abstract_state(model, train_cfg: TrainConfig) -> dict:
    """ShapeDtypeStruct version of init_state (dry-run, no allocation)."""
    from repro.nn.params import abstract_params
    params = abstract_params(model.param_specs(), model.cfg.dtype)
    state = {"params": params,
             "opt": {
                 "step": jax.ShapeDtypeStruct((), jnp.int32),
                 "m": jax.tree.map(
                     lambda p: jax.ShapeDtypeStruct(
                         p.shape, jnp.dtype(train_cfg.optimizer.m_dtype)),
                     params),
                 "v": jax.tree.map(
                     lambda p: jax.ShapeDtypeStruct(
                         p.shape, jnp.dtype(train_cfg.optimizer.v_dtype)),
                     params),
             }}
    if train_cfg.compressed_cross_pod:
        state["err"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return state


def _split_microbatches(batch: dict, k: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape((k, b // k) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(model, train_cfg: TrainConfig, mesh=None):
    ocfg, scfg = train_cfg.optimizer, train_cfg.schedule
    k = train_cfg.microbatches

    def loss_fn(params, mb):
        return model.loss(params, mb)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if k == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        mbs = _split_microbatches(batch, k)

        def body(acc, mb):
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return acc, metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        from repro.core import accounting
        acc, metrics_stack = jax.lax.scan(
            body, zeros, mbs, unroll=accounting.inner_unroll(k))
        grads = jax.tree.map(lambda g: g / k, acc)
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics_stack)
        return grads, metrics

    def train_step(state, batch):
        if train_cfg.compressed_cross_pod and mesh is not None and \
                "pod" in mesh.shape:
            def podwise(batch, params, err):
                grads, metrics = compute_grads(params, batch)
                red, new_err = collectives.compressed_pod_psum(
                    grads, err, axis="pod")
                npods = jax.lax.psum(1, "pod")
                grads = jax.tree.map(lambda g: g / npods, red)
                metrics = jax.tree.map(
                    lambda m: jax.lax.pmean(m, "pod"), metrics)
                return grads, new_err, metrics

            grads, new_err, metrics = collectives.shard_map(
                podwise, mesh=mesh, axis_names={"pod"},
                in_specs=(P("pod"), P(), P()),
                out_specs=(P(), P(), P()))(batch, state["params"],
                                           state["err"])
        else:
            grads, metrics = compute_grads(state["params"], batch)
            new_err = state.get("err")

        lr = sched.lr_at(state["opt"]["step"], scfg)
        new_params, new_opt, stats = adamw.update(
            grads, state["opt"], state["params"], lr, ocfg)
        new_state = {"params": new_params, "opt": new_opt}
        if new_err is not None:
            new_state["err"] = new_err
        metrics = dict(metrics)
        metrics.update(stats)
        return new_state, metrics

    return train_step
