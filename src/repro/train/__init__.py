from repro.train.step import (TrainConfig, abstract_state, init_state,  # noqa: F401
                              make_train_step)
