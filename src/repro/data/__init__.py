from repro.data.pipeline import PrefetchIterator, device_put_batch  # noqa: F401
from repro.data.synthetic import DataConfig, SyntheticLM  # noqa: F401
from repro.data.packing import batch_packed, pack_documents  # noqa: F401
