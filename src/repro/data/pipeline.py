"""Sharded, prefetching data pipeline.

Each host materializes only its slice of the global batch
(``host_local_slice``); a background thread keeps ``prefetch`` batches ready
so input never blocks the step (the straggler story starts here — see
runtime/health.py).  On this single-process box the host slice is the whole
batch; the code path is identical.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


def host_local_slice(global_batch: int) -> slice:
    n_hosts = jax.process_count()
    idx = jax.process_index()
    per = global_batch // n_hosts
    return slice(idx * per, (idx + 1) * per)


class PrefetchIterator:
    """Wrap an iterator with a daemon prefetch thread."""

    def __init__(self, it: Iterator, prefetch: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        except BaseException as e:  # noqa: BLE001 — surfaced on next()
            self._err = e
        self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def device_put_batch(batch: Dict[str, np.ndarray], shardings=None):
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(jax.device_put, batch, shardings)
