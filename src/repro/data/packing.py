"""Document packing into static shapes (the paper's Step-1 discipline).

NPUs (and jit) want fixed input shapes; variable-length documents are packed
greedily into fixed ``seq_len`` rows.  Loss masking uses label ``-1`` on
padding and on positions that cross a document boundary, so no gradient
flows across packed documents.
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, List

import numpy as np


def pack_documents(docs: Iterable[List[int]], seq_len: int,
                   pad_id: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Greedy first-fit packing; yields {"tokens", "labels", "segments"}."""
    buf_tokens: List[int] = []
    buf_labels: List[int] = []
    buf_segments: List[int] = []
    seg = 1

    def flush():
        nonlocal buf_tokens, buf_labels, buf_segments, seg
        pad = seq_len - len(buf_tokens)
        tokens = np.asarray(buf_tokens + [pad_id] * pad, np.int32)
        labels = np.asarray(buf_labels + [-1] * pad, np.int32)
        segments = np.asarray(buf_segments + [0] * pad, np.int32)
        buf_tokens, buf_labels, buf_segments = [], [], []
        seg = 1
        return {"tokens": tokens, "labels": labels, "segments": segments}

    for doc in docs:
        doc = list(doc)
        while doc:
            space = seq_len - len(buf_tokens)
            take = doc[:space]
            doc = doc[space:]
            labels = list(take)
            if buf_tokens:
                labels[0] = -1  # no cross-document prediction
            buf_tokens += take
            buf_labels += labels
            buf_segments += [seg] * len(take)
            seg += 1
            if len(buf_tokens) == seq_len:
                yield flush()
    if buf_tokens:
        yield flush()


def batch_packed(packed: Iterator[Dict[str, np.ndarray]], batch: int
                 ) -> Iterator[Dict[str, np.ndarray]]:
    rows: List[Dict[str, np.ndarray]] = []
    for row in packed:
        rows.append(row)
        if len(rows) == batch:
            yield {k: np.stack([r[k] for r in rows]) for k in rows[0]}
            rows = []
