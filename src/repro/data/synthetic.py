"""Deterministic synthetic LM data with learnable structure.

Sequences mix a Zipf-distributed token stream with induction patterns
(a random span repeated later in the sequence), so a model trained on this
pipeline shows a real, monotone loss decrease — enough signal to validate
the full training stack end-to-end without external datasets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    zipf_a: float = 1.2
    repeat_frac: float = 0.5   # fraction of the sequence that is a repeat


class SyntheticLM:
    """Infinite deterministic iterator of {"tokens", "labels"} batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        b, s = cfg.global_batch, cfg.seq_len
        # Zipf body (clipped into vocab, reserving 0 for padding/bos)
        toks = rng.zipf(cfg.zipf_a, size=(b, s)).astype(np.int64)
        toks = (toks % (cfg.vocab_size - 1)) + 1
        # Induction: copy an early span later in the sequence.
        span = max(4, int(s * cfg.repeat_frac / 2))
        if 2 * span < s:
            start = rng.integers(0, s - 2 * span, size=b)
            for i in range(b):
                src = slice(start[i], start[i] + span)
                dst = slice(s - span, s)
                toks[i, dst] = toks[i, src]
        toks = toks.astype(np.int32)
        return {"tokens": toks, "labels": toks.copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            batch = self._batch_at(self.step)
            self.step += 1
            yield batch

    def next(self) -> Dict[str, np.ndarray]:
        batch = self._batch_at(self.step)
        self.step += 1
        return batch
