"""Serving metrics: streaming histograms, windowed gauges, snapshots.

Event-driven so both engines can feed it: the engine stamps arrivals,
first tokens, emitted tokens, completions, per-decode-step occupancy and
per-poll gauge observations; ``summary()`` folds those into the serving
KPIs the benchmarks compare, and ``maybe_snapshot()`` emits periodic
point-in-time snapshots (to ``self.snapshots`` and, when tracing, to the
tracer's event log) so a long-running server is observable *while* it
runs, not only after.

Aggregates are **streaming**: latency distributions live in fixed-size
log-bucketed histograms (:class:`StreamingHistogram` — O(1) per sample,
percentiles by linear interpolation inside a bucket) and utilization
gauges in sliding time windows (:class:`WindowedGauge` /
:class:`RateMeter`), so memory is constant no matter how many requests a
server has seen — the old stored-``List[float]`` aggregates grew without
bound and re-sorted per percentile call.

Definitions:

* **TTFT**          — arrival to first emitted token (includes queueing).
* **token latency** — decode wall time / decode tokens (steady-state
  inter-token gap).
* **occupancy**     — live-slot-seconds / (slots x decode time): the
  fraction of *decode-step* slot capacity that produced tokens (prefill
  and host time are excluded by construction, so it isolates the decode
  scheduling policy).  The wave engine's straggler holes show up
  directly here.
* **goodput**       — tokens of *completed* requests per second of wall
  time (tokens of shed / unfinished requests don't count).
* **wall_source**   — which denominator throughput figures used:
  ``"measured"`` when the caller stamped ``record_wall``, else
  ``"decode_time"`` (poll()-style driving never stamps a wall; decode
  time is then the best available denominator and throughput is an
  *upper bound* — surfaced explicitly instead of silently substituted).
"""
from __future__ import annotations

import math
import time
from collections import deque
from typing import Callable, Dict, List, Optional

from repro.serve.tracing import NULL_TRACER


def _percentile(xs: List[float], q: float) -> float:
    """Linear-interpolation percentile of a list (numpy ``quantile``
    semantics).  The old nearest-rank-with-``round()`` variant biased
    small-sample tails: with 20 samples, p95 rounded to the *maximum*
    (rank 19) instead of interpolating between ranks 18 and 19."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    pos = q * (len(ys) - 1)
    lo = int(math.floor(pos))
    hi = min(len(ys) - 1, lo + 1)
    frac = pos - lo
    return ys[lo] * (1.0 - frac) + ys[hi] * frac


class StreamingHistogram:
    """Log-bucketed streaming histogram for positive samples (latencies).

    Buckets are geometric: ``bins_per_decade`` per factor of 10 between
    ``lo`` and ``hi`` (values outside clamp into the edge buckets).  At
    the default 32 bins/decade a bucket spans a factor of 10^(1/32) ~
    1.075, so interpolated percentiles carry <= ~7.5% relative error —
    exact count/mean/min/max, constant memory, O(1) insertion.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 bins_per_decade: int = 32):
        self.lo = lo
        self.hi = hi
        self.bpd = bins_per_decade
        self._log_lo = math.log10(lo)
        self.nbins = int(math.ceil((math.log10(hi) - self._log_lo) * bins_per_decade)) + 1
        self.counts = [0] * self.nbins
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bucket(self, x: float) -> int:
        if x <= self.lo:
            return 0
        if x >= self.hi:
            return self.nbins - 1
        return int((math.log10(x) - self._log_lo) * self.bpd)

    def add(self, x: float) -> None:
        self.counts[self._bucket(x)] += 1
        self.count += 1
        self.total += x
        self.vmin = min(self.vmin, x)
        self.vmax = max(self.vmax, x)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Linear interpolation on the cumulative histogram: rank
        ``q * (count - 1)`` lands inside one bucket; the value
        interpolates geometrically across that bucket's span by the
        rank's fractional position, clamped to the observed min/max."""
        if not self.count:
            return 0.0
        if self.count == 1:
            return self.vmin
        rank = q * (self.count - 1)
        cum = 0
        for b, n in enumerate(self.counts):
            if not n:
                continue
            if rank < cum + n:
                frac = (rank - cum + 0.5) / n
                lo_edge = 10.0 ** (self._log_lo + b / self.bpd)
                v = lo_edge * 10.0 ** (frac / self.bpd)
                return min(max(v, self.vmin), self.vmax)
            cum += n
        return self.vmax

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "p50": self.percentile(0.50),
                "p90": self.percentile(0.90),
                "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class WindowedGauge:
    """Sliding-time-window gauge: last / windowed mean / windowed max of a
    sampled value (slot occupancy, queue depth, resident bytes, ...)."""

    def __init__(self, window_s: float = 10.0):
        self.window_s = window_s
        self._pts: deque = deque()      # (t, value)
        self.last = 0.0

    def record(self, value: float, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        self.last = value
        self._pts.append((now, value))
        self._trim(now)

    def _trim(self, now: float) -> None:
        cut = now - self.window_s
        pts = self._pts
        while pts and pts[0][0] < cut:
            pts.popleft()

    def snapshot(self, now: Optional[float] = None) -> Dict[str, float]:
        now = time.perf_counter() if now is None else now
        self._trim(now)
        n = len(self._pts)
        if not n:
            return {"last": self.last, "mean": self.last,
                    "max": self.last, "n": 0}
        vals = [v for _, v in self._pts]
        return {"last": self.last, "mean": sum(vals) / n,
                "max": max(vals), "n": n}


class RateMeter:
    """Sliding-window event rate (tokens/s): counts per unit time over
    the trailing ``window_s`` seconds."""

    def __init__(self, window_s: float = 10.0):
        self.window_s = window_s
        self._pts: deque = deque()      # (t, n)

    def record(self, n: int = 1, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        self._pts.append((now, n))
        cut = now - self.window_s
        pts = self._pts
        while pts and pts[0][0] < cut:
            pts.popleft()

    def rate(self, now: Optional[float] = None) -> float:
        now = time.perf_counter() if now is None else now
        cut = now - self.window_s
        pts = self._pts
        while pts and pts[0][0] < cut:
            pts.popleft()
        if not pts:
            return 0.0
        span = max(now - pts[0][0], 1e-9)
        return sum(n for _, n in pts) / span


class ServeMetrics:
    def __init__(self, slots: int, tracer=NULL_TRACER,
                 metrics_every: int = 0, gauge_window_s: float = 10.0):
        self.slots = max(1, slots)
        self.tracer = tracer
        self.metrics_every = metrics_every
        self.gauge_window_s = gauge_window_s
        self.reset()

    def reset(self) -> None:
        self.arrivals = 0
        self.completed = 0
        self.shed = 0
        # Shed breakdown (sums to ``shed``): deadline | overload | poison |
        # retry_exhausted (docs/robustness.md).
        self.shed_reasons: Dict[str, int] = {}
        self.rejected = 0           # backpressure: submit() refused (queue full)
        self.quarantined = 0        # slots reset after a poison probe hit
        self.poison_probes = 0      # probe passes executed (overhead witness)
        self.backend_fallbacks = 0  # decode-mode fallback re-dispatches
        self.watchdog_recoveries = 0
        self.retries = 0            # requests requeued by recovery
        self.overload_entries = 0
        self.overload_exits = 0
        self.truncated = 0
        self.emitted_tokens = 0
        self.completed_tokens = 0
        self.ttft = StreamingHistogram()
        self.latency = StreamingHistogram()
        self.step_hist = StreamingHistogram()
        self.prefill_hist = StreamingHistogram()
        self.decode_steps = 0
        self.decode_time_s = 0.0
        self.live_slot_s = 0.0
        self.wall_s = 0.0
        self.prefill_chunks = 0
        self.prefill_tokens = 0
        self.prefill_time_s = 0.0
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_hit_tokens = 0
        self.spec_bursts = 0
        self.spec_rows = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.spec_rollbacks = 0
        self.stragglers = {"decode": 0, "prefill": 0}
        self.watchdog_fires = 0
        self.polls = 0
        self.gauges: Dict[str, WindowedGauge] = {}
        self.tok_rate = RateMeter(self.gauge_window_s)
        self.snapshots: List[dict] = []

    # -- event hooks -------------------------------------------------------
    def record_arrival(self) -> None:
        self.arrivals += 1

    def record_first_token(self, ttft_s: float) -> None:
        self.ttft.add(ttft_s)

    def record_token(self, n: int = 1) -> None:
        self.emitted_tokens += n
        self.tok_rate.record(n)

    def record_finish(self, latency_s: float, n_tokens: int) -> None:
        self.completed += 1
        self.completed_tokens += n_tokens
        self.latency.add(latency_s)

    def record_shed(self, reason: str = "deadline") -> None:
        """One shed request.  ``reason``: why capacity was reclaimed —
        ``deadline`` (SLA passed), ``overload`` (backpressure dropped it),
        ``poison`` (quarantined slot), ``retry_exhausted`` (recovery gave
        up).  The per-reason counts always sum to ``shed``."""
        self.shed += 1
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1

    def record_reject(self) -> None:
        """submit() refused a request outright (bounded admission queue)."""
        self.rejected += 1

    def record_quarantine(self) -> None:
        """A poison probe hit: one slot reset, its request shed."""
        self.quarantined += 1

    def record_poison_probe(self) -> None:
        self.poison_probes += 1

    def record_backend_fallback(self) -> None:
        self.backend_fallbacks += 1

    def record_watchdog_recovery(self, requeued: int) -> None:
        self.watchdog_recoveries += 1
        self.retries += requeued

    def record_overload(self, entered: bool) -> None:
        if entered:
            self.overload_entries += 1
        else:
            self.overload_exits += 1

    def record_step(self, live_slots: int, dt_s: float) -> None:
        """One decode step: ``live_slots`` rows produced useful tokens."""
        self.decode_steps += 1
        self.decode_time_s += dt_s
        self.live_slot_s += live_slots * dt_s
        self.step_hist.add(dt_s)

    def record_wall(self, dt_s: float) -> None:
        self.wall_s += dt_s

    def record_prefill(self, tokens: int, dt_s: float) -> None:
        """One prefill program call (a monolithic bucket or one chunk);
        ``tokens`` = prompt tokens it advanced across live rows."""
        self.prefill_chunks += 1
        self.prefill_tokens += tokens
        self.prefill_time_s += dt_s
        self.prefill_hist.add(dt_s)

    def record_prefix_lookup(self, matched_tokens: int) -> None:
        """One prefix-cache admission lookup: ``matched_tokens`` prompt
        tokens were skipped by restoring a cached snapshot (0 = miss)."""
        if matched_tokens > 0:
            self.prefix_hits += 1
            self.prefix_hit_tokens += matched_tokens
        else:
            self.prefix_misses += 1

    def record_speculative(self, rows: int, drafted: int, accepted: int,
                           emitted: int, rollbacks: int) -> None:
        """One speculative draft/verify burst (``serve/speculative.py``)
        across ``rows`` live slots: ``drafted`` draft tokens, ``accepted``
        of them confirmed by the verify stream, ``emitted`` tokens that
        entered request outputs (accepted + up to one correction per
        row), ``rollbacks`` rows restored to their pre-burst snapshot."""
        self.spec_bursts += 1
        self.spec_rows += rows
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted
        self.spec_rollbacks += rollbacks

    def record_straggler(self, kind: str) -> None:
        """A StepMonitor flagged one decode/prefill step as a straggler."""
        self.stragglers[kind] = self.stragglers.get(kind, 0) + 1

    def observe_gauges(self, **values: float) -> None:
        """Per-poll gauge samples from the engine (queue depth, staging
        depth, live slots, prefix-cache resident bytes, ...)."""
        for key, v in values.items():
            g = self.gauges.get(key)
            if g is None:
                g = self.gauges[key] = WindowedGauge(self.gauge_window_s)
            g.record(v)

    # -- periodic snapshots ------------------------------------------------
    def maybe_snapshot(self,
                       extra_fn: Optional[Callable[[], dict]] = None) -> None:
        """Count one engine poll; every ``metrics_every`` polls (0 = off)
        take a point-in-time snapshot — appended to ``self.snapshots``
        and emitted into the tracer (a counter sample for the plottable
        series plus a full structured instant for the JSONL log)."""
        self.polls += 1
        if not self.metrics_every or self.polls % self.metrics_every:
            return
        snap = self.snapshot()
        if extra_fn is not None:
            snap.update(extra_fn())
        self.snapshots.append(snap)
        if self.tracer.enabled:
            self.tracer.counter("serve_gauges", {
                "queue_depth": snap["gauges"].get("queue_depth",
                                                  {}).get("last", 0.0),
                "staging_depth": snap["gauges"].get("staging_depth",
                                                    {}).get("last", 0.0),
                "live_slots": snap["gauges"].get("live_slots",
                                                 {}).get("last", 0.0),
                "tokens_per_s": snap["tokens_per_s_window"],
                "prefix_resident_mb": snap["gauges"].get(
                    "prefix_resident_bytes", {}).get("last", 0.0) / 2 ** 20,
            })
            self.tracer.instant("metrics_snapshot", **snap)

    def snapshot(self) -> dict:
        """Point-in-time view: cumulative counters + windowed gauges +
        histogram quick stats (cheap — no stored samples to fold)."""
        return {
            "polls": self.polls,
            "arrivals": self.arrivals,
            "completed": self.completed,
            "shed": self.shed,
            "shed_reasons": dict(self.shed_reasons),
            "rejected": self.rejected,
            "quarantined": self.quarantined,
            "backend_fallbacks": self.backend_fallbacks,
            "watchdog_recoveries": self.watchdog_recoveries,
            "emitted_tokens": self.emitted_tokens,
            "tokens_per_s_window": self.tok_rate.rate(),
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "spec_bursts": self.spec_bursts,
            "spec_accept_rate": (self.spec_accepted / self.spec_drafted
                                 if self.spec_drafted else 0.0),
            "stragglers": dict(self.stragglers),
            "watchdog_fires": self.watchdog_fires,
            "ttft": self.ttft.summary(),
            "decode_step": self.step_hist.summary(),
            "prefill_call": self.prefill_hist.summary(),
            "gauges": {k: g.snapshot() for k, g in self.gauges.items()},
        }

    # -- rollup ------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Cumulative KPI rollup.  ``wall_source`` says which denominator
        the throughput figures used (see module docstring) — decode time
        is an upper-bound fallback, not a silent substitute."""
        # (Return type is heterogeneous: shed_reasons is a sub-dict.)
        wall = self.wall_s or self.decode_time_s
        wall_source = ("measured" if self.wall_s else
                       "decode_time" if self.decode_time_s else "none")
        return {
            "requests": self.arrivals,
            "completed": self.completed,
            "shed": self.shed,
            "shed_reasons": dict(self.shed_reasons),
            "rejected": self.rejected,
            "quarantined": self.quarantined,
            "poison_probes": self.poison_probes,
            "backend_fallbacks": self.backend_fallbacks,
            "watchdog_recoveries": self.watchdog_recoveries,
            "retries": self.retries,
            "overload_entries": self.overload_entries,
            "overload_exits": self.overload_exits,
            "generated_tokens": self.emitted_tokens,
            "tokens_per_s": self.emitted_tokens / wall if wall else 0.0,
            "goodput_tokens_per_s":
                self.completed_tokens / wall if wall else 0.0,
            "ttft_mean_s": self.ttft.mean,
            "ttft_p50_s": self.ttft.percentile(0.50),
            "ttft_p90_s": self.ttft.percentile(0.90),
            "ttft_p95_s": self.ttft.percentile(0.95),
            "ttft_p99_s": self.ttft.percentile(0.99),
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "prefill_time_s": self.prefill_time_s,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "spec_bursts": self.spec_bursts,
            "spec_drafted_tokens": self.spec_drafted,
            "spec_accept_rate": (self.spec_accepted / self.spec_drafted
                                 if self.spec_drafted else 0.0),
            "spec_tokens_per_verify": (self.spec_emitted / self.spec_rows
                                       if self.spec_rows else 0.0),
            "spec_rollbacks": self.spec_rollbacks,
            "latency_mean_s": self.latency.mean,
            "token_latency_s": (self.decode_time_s / self.decode_steps
                                if self.decode_steps else 0.0),
            "slot_occupancy": (self.live_slot_s /
                               (self.slots * self.decode_time_s)
                               if self.decode_time_s else 0.0),
            "stragglers_decode": self.stragglers.get("decode", 0),
            "stragglers_prefill": self.stragglers.get("prefill", 0),
            "watchdog_fires": self.watchdog_fires,
            "wall_s": wall,
            "wall_source": wall_source,
        }
