"""Serving metrics: TTFT, per-token latency, slot occupancy, goodput.

Event-driven so both engines can feed it: the engine stamps arrivals,
first tokens, emitted tokens, completions, and per-decode-step occupancy;
``summary()`` folds those into the serving KPIs the benchmarks compare.

Definitions:

* **TTFT**          — arrival to first emitted token (includes queueing).
* **token latency** — decode wall time / decode tokens (steady-state
  inter-token gap).
* **occupancy**     — live-slot-seconds / (slots x decode time): the
  fraction of *decode-step* slot capacity that produced tokens (prefill
  and host time are excluded by construction, so it isolates the decode
  scheduling policy).  The wave engine's straggler holes show up
  directly here.
* **goodput**       — tokens of *completed* requests per second of wall
  time (tokens of shed / unfinished requests don't count).
"""
from __future__ import annotations

from typing import Dict, List


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, int(round(q * (len(ys) - 1))))
    return ys[i]


class ServeMetrics:
    def __init__(self, slots: int):
        self.slots = max(1, slots)
        self.reset()

    def reset(self) -> None:
        self.arrivals = 0
        self.completed = 0
        self.shed = 0
        self.truncated = 0
        self.emitted_tokens = 0
        self.completed_tokens = 0
        self.ttft_s: List[float] = []
        self.latency_s: List[float] = []
        self.decode_steps = 0
        self.decode_time_s = 0.0
        self.live_slot_s = 0.0
        self.wall_s = 0.0
        self.prefill_chunks = 0
        self.prefill_tokens = 0
        self.prefill_time_s = 0.0
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_hit_tokens = 0

    # -- event hooks -------------------------------------------------------
    def record_arrival(self) -> None:
        self.arrivals += 1

    def record_first_token(self, ttft_s: float) -> None:
        self.ttft_s.append(ttft_s)

    def record_token(self, n: int = 1) -> None:
        self.emitted_tokens += n

    def record_finish(self, latency_s: float, n_tokens: int) -> None:
        self.completed += 1
        self.completed_tokens += n_tokens
        self.latency_s.append(latency_s)

    def record_shed(self) -> None:
        self.shed += 1

    def record_step(self, live_slots: int, dt_s: float) -> None:
        """One decode step: ``live_slots`` rows produced useful tokens."""
        self.decode_steps += 1
        self.decode_time_s += dt_s
        self.live_slot_s += live_slots * dt_s

    def record_wall(self, dt_s: float) -> None:
        self.wall_s += dt_s

    def record_prefill(self, tokens: int, dt_s: float) -> None:
        """One prefill program call (a monolithic bucket or one chunk);
        ``tokens`` = prompt tokens it advanced across live rows."""
        self.prefill_chunks += 1
        self.prefill_tokens += tokens
        self.prefill_time_s += dt_s

    def record_prefix_lookup(self, matched_tokens: int) -> None:
        """One prefix-cache admission lookup: ``matched_tokens`` prompt
        tokens were skipped by restoring a cached snapshot (0 = miss)."""
        if matched_tokens > 0:
            self.prefix_hits += 1
            self.prefix_hit_tokens += matched_tokens
        else:
            self.prefix_misses += 1

    # -- rollup ------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Throughput figures use recorded wall time; when the caller never
        stamped one (poll()-style driving), decode time is the best
        available denominator and throughput is an upper bound."""
        wall = self.wall_s or self.decode_time_s
        return {
            "requests": self.arrivals,
            "completed": self.completed,
            "shed": self.shed,
            "generated_tokens": self.emitted_tokens,
            "tokens_per_s": self.emitted_tokens / wall if wall else 0.0,
            "goodput_tokens_per_s":
                self.completed_tokens / wall if wall else 0.0,
            "ttft_mean_s": (sum(self.ttft_s) / len(self.ttft_s)
                            if self.ttft_s else 0.0),
            "ttft_p90_s": _percentile(self.ttft_s, 0.9),
            "ttft_p95_s": _percentile(self.ttft_s, 0.95),
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "prefill_time_s": self.prefill_time_s,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "latency_mean_s": (sum(self.latency_s) / len(self.latency_s)
                               if self.latency_s else 0.0),
            "token_latency_s": (self.decode_time_s / self.decode_steps
                                if self.decode_steps else 0.0),
            "slot_occupancy": (self.live_slot_s /
                               (self.slots * self.decode_time_s)
                               if self.decode_time_s else 0.0),
            "wall_s": wall,
        }
