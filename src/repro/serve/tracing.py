"""Serve-stack span tracing: per-request timelines, engine phases,
recompile sentinels — behind a near-zero-overhead null tracer.

Every question the serving ROADMAP items keep asking ("where does a
request's wall time go?", "is prefill really the bottleneck?", "did
anything retrace after warmup?") needs finer data than aggregate
counters.  This module provides the three primitives the serve stack is
instrumented with:

* :class:`Tracer` — an in-memory span/event recorder whose output is
  **Chrome/Perfetto-compatible trace JSON** (``save``) and a structured
  **JSON-lines event log** (``save_jsonl``).  Spans are complete events
  (``ph: "X"``) on a small set of tracks (engine, host, queue, one per
  slot); gauges are counter events (``ph: "C"``); one-off facts are
  instants (``ph: "i"``).  ``launch/trace_report.py`` folds a saved
  trace into phase breakdowns, TTFT decompositions, and slot timelines.
* :class:`NullTracer` — the default.  Every method is a no-op and
  ``span`` returns a shared do-nothing context manager, so the
  instrumented hot path costs a few dict builds and attribute lookups
  per *engine poll* (each poll contains at least one multi-millisecond
  compiled call; ``benchmarks/bench_serve_continuous.bench_phase``
  measures and asserts the end-to-end overhead of tracing at <= 2%).
* :class:`RecompileSentinel` — the compile-once discipline as a
  first-class check instead of an ad-hoc counter-string diff: it arms on
  a jitted callable's current cache size and counts every later growth
  as a *trip* (optionally raising in ``strict`` mode).  Engines check
  their sentinels every poll and re-arm them on ``reset_stats()`` (i.e.
  after warmup), so a trip always means "retraced after warmup".

Span taxonomy (see ``docs/observability.md`` for the full table):

==================  ====================================================
``serve.run``       one engine ``run()`` drain (engine track)
``poll``            one engine scheduling iteration (engine track)
``admit``           admission: queue pops + staging + prefix lookup
``prefix_lookup``   radix-cache longest-prefix match for one admission
``snapshot_restore``/``snapshot_export``  prefix-cache state row moves
``prefill_bucket``  one monolithic bucketed prefill program call
``prefill_chunk``   one chunked-prefill program call (all staging rows)
``decode_step``     one decode program call across all slots
``pool_insert``/``pool_reset``  state-pool row scatter / zero
``host_gap``        time between polls (host track — idle + caller)
``queue``           per-request: arrival -> admission (queue track)
``staging``         per-request: admission -> first token (slot track)
``decode``          per-request: first token -> finish (slot track)
==================  ====================================================
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

# Track ("tid") layout inside the single serve process ("pid" 0).
TID_ENGINE = 0      # compiled-program calls + host scheduling sections
TID_HOST = 1        # gaps between polls (idle / caller time)
TID_QUEUE = 2       # per-request queue-wait spans (overlapping is fine)
TID_SLOT0 = 100     # slot i's residency spans live on TID_SLOT0 + i

_TRACK_NAMES = {TID_ENGINE: "engine", TID_HOST: "host", TID_QUEUE: "queue"}


class _Span:
    """Context manager recording one complete event on ``__exit__``.

    ``args`` stays mutable until exit so callers can attach facts they
    only learn mid-span (e.g. how many requests an ``admit`` admitted).
    """

    __slots__ = ("_tr", "name", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, tid: int,
                 args: Dict[str, Any]):
        self._tr = tracer
        self.name = name
        self.tid = tid
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._tr.complete(self.name, self._t0, time.perf_counter(),
                          tid=self.tid, **self.args)


class _NullSpan:
    """Shared do-nothing span: ``with NULL_TRACER.span(...):`` costs two
    method calls and nothing else."""

    __slots__ = ("args",)

    def __init__(self):
        self.args: Dict[str, Any] = {}

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        self.args.clear()


class NullTracer:
    """No-op tracer: the default for untraced engines.  ``enabled`` lets
    hot paths skip even argument construction when it matters."""

    enabled = False

    def __init__(self):
        self._null_span = _NullSpan()

    def span(self, name: str, tid: int = TID_ENGINE, **args) -> _NullSpan:
        return self._null_span

    def complete(self, name, t0, t1, tid=TID_ENGINE, **args) -> None:
        pass

    def instant(self, name, tid=TID_ENGINE, **args) -> None:
        pass

    def counter(self, name, values) -> None:
        pass

    def reset(self) -> None:
        pass

    def now(self) -> float:
        return time.perf_counter()


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """In-memory event recorder; timestamps are ``time.perf_counter()``
    seconds converted to trace microseconds relative to construction.

    The hot path appends flat ``(ph, name, tid, t0, t1, args)`` tuples and
    the Chrome-format dicts are materialized lazily by :attr:`events`.
    This is a measured GC fix, not a style choice: per-emit dicts survive
    into the old generations and accelerate the collector's generational
    clock until a full gen-2 pass lands *inside* the serve drain (~100ms
    with jax's heap resident — an 8%+ wall hit on ``bench_phase``).
    Tuples of atoms get untracked at the first young collection, keeping
    the traced hot loop within the <= 2% overhead budget."""

    enabled = True

    def __init__(self):
        super().__init__()
        self._t0 = time.perf_counter()
        # time.time() <-> perf_counter offset, fixed once, so wall-clock
        # stamps (Request.arrival_s) convert onto the trace clock.
        self._epoch = time.time() - self._t0
        self._raw: List[tuple] = []

    # -- clocks ------------------------------------------------------------
    def _us(self, t_pc: float) -> float:
        return (t_pc - self._t0) * 1e6

    def pc_from_walltime(self, t_wall: float) -> float:
        """Convert a ``time.time()`` stamp to the perf_counter clock."""
        return t_wall - self._epoch

    # -- emitters ----------------------------------------------------------
    def span(self, name: str, tid: int = TID_ENGINE, **args) -> _Span:
        return _Span(self, name, tid, args)

    def complete(self, name: str, t0: float, t1: float,
                 tid: int = TID_ENGINE, **args) -> None:
        """Record a complete event from perf_counter stamps ``t0..t1``."""
        self._raw.append(("X", name, tid, t0, t1, args or None))

    def instant(self, name: str, tid: int = TID_ENGINE, **args) -> None:
        self._raw.append(("i", name, tid, time.perf_counter(), 0.0,
                          args or None))

    def counter(self, name: str, values: Dict[str, float]) -> None:
        """One counter sample (Perfetto renders each key as a series)."""
        self._raw.append(("C", name, 0, time.perf_counter(), 0.0, values))

    # -- materialization ---------------------------------------------------
    @property
    def events(self) -> List[Dict[str, Any]]:
        """The recorded events as Chrome-trace dicts, in emission order,
        with each track's ``thread_name`` metadata emitted at first use
        (counter events carry no tid).  Rebuilt per access — read once,
        after the run."""
        out: List[Dict[str, Any]] = []
        named = set()
        for ph, name, tid, t0, t1, args in self._raw:
            if ph != "C" and tid not in named:
                named.add(tid)
                track = _TRACK_NAMES.get(tid, f"slot {tid - TID_SLOT0}")
                out.append({"name": "thread_name", "ph": "M", "pid": 0,
                            "tid": tid, "args": {"name": track}})
            if ph == "X":
                out.append({
                    "name": name, "cat": "serve", "ph": "X", "pid": 0,
                    "tid": tid, "ts": round(self._us(t0), 3),
                    "dur": round(max(0.0, (t1 - t0)) * 1e6, 3),
                    "args": args if args is not None else {}})
            elif ph == "i":
                out.append({
                    "name": name, "cat": "serve", "ph": "i", "s": "t",
                    "pid": 0, "tid": tid, "ts": round(self._us(t0), 3),
                    "args": args if args is not None else {}})
            else:
                out.append({
                    "name": name, "cat": "serve", "ph": "C", "pid": 0,
                    "ts": round(self._us(t0), 3), "args": args})
        return out

    def reset(self) -> None:
        """Drop recorded events (track names re-emit on next use).  The
        clock keeps its original origin so pre/post-reset timestamps stay
        comparable.  Engines call this from ``reset_stats()`` so a
        post-warmup trace starts at the measured window."""
        self._raw.clear()

    # -- output ------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        """Chrome/Perfetto trace JSON (load in ui.perfetto.dev)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")

    def save_jsonl(self, path: str) -> None:
        """Structured event log: one JSON object per line, in emission
        order — greppable / streamable where the Chrome JSON is not."""
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")


class RecompileError(RuntimeError):
    """A recompile sentinel tripped in strict mode."""


class RecompileSentinel:
    """Compile-once discipline as a checkable invariant.

    Wraps a jitted callable; ``check()`` compares the callable's current
    jit-cache size against the armed baseline and counts growth as
    *trips*.  The first check (or :meth:`arm`) sets the baseline without
    counting, so warmup compiles are free; engines re-arm on
    ``reset_stats()``.  In ``strict`` mode a trip raises
    :class:`RecompileError` instead of just counting — benchmarks run
    strict so a retrace fails loudly at the step that caused it.

    On jax versions without a jit cache-size probe the sentinel is
    inert: ``supported`` is False and ``check()`` always returns 0.

    ``program_id`` (a ``serve/program_registry`` id like ``p0:decode``)
    rides in the trip instant so ``trace_report``'s recompile audit
    names the offending *program*, not just a span label.  ``fn_getter``
    defers callable resolution to check time, for programs built lazily
    after the sentinel exists (the state pools' row ops): until the
    getter returns a jitted fn the sentinel reads size -1 and stays
    inert, then lazy-arms on first sight.
    """

    def __init__(self, name: str, fn=None, strict: bool = False, *,
                 program_id: Optional[str] = None, fn_getter=None):
        self.name = name
        self._fn = fn
        self._fn_getter = fn_getter
        self.strict = strict
        self.program_id = program_id
        self.trips = 0
        self._baseline: Optional[int] = None

    @property
    def supported(self) -> bool:
        return self._size() >= 0

    def rebind(self, fn) -> None:
        """Point the sentinel at a rebuilt jit (backend fallback swaps
        the programs underneath); the caller re-arms afterwards."""
        self._fn = fn

    def _size(self) -> int:
        fn = self._fn
        if fn is None and self._fn_getter is not None:
            fn = self._fn_getter()
        try:
            return fn._cache_size()
        except Exception:
            return -1

    def arm(self) -> None:
        """(Re)baseline at the current cache size; zero the trip count."""
        self._baseline = self._size()
        self.trips = 0

    def check(self, tracer: NullTracer = NULL_TRACER) -> int:
        """Count (and optionally raise on) cache growth since arming;
        returns the cumulative trip count."""
        n = self._size()
        if n < 0:
            return 0
        if self._baseline is None or (self._baseline < 1 and n > 0):
            # Lazy arm: the first time the program shows up compiled, all
            # of its traces so far are warmup.  (Benchmarks arm
            # explicitly via reset_stats() after their warmup pass, which
            # also covers multi-bucket prefill programs.)  A baseline
            # below zero means the program didn't exist when armed (a
            # lazily-built op behind fn_getter) — same treatment.
            self._baseline = n
            return self.trips
        if n > self._baseline:
            new = n - self._baseline
            self._baseline = n
            self.trips += new
            extra = ({"program_id": self.program_id}
                     if self.program_id else {})
            tracer.instant("recompile", program=self.name, new_traces=new,
                           trips=self.trips, **extra)
            if self.strict:
                raise RecompileError(
                    f"compiled program {self.name!r} retraced after warmup "
                    f"({new} new trace(s), {self.trips} total trips)")
        return self.trips
