"""Flight recorder: last-N request timelines, dumped on fault events.

The full tracer answers "where did the wall time go" but costs memory
proportional to the run and is usually off in production-shaped runs —
exactly the runs where PR 9's fault machinery (quarantine, watchdog
recovery, shedding, retries) fires.  When it does fire, the question is
always the same: *what was in flight just before this?*

The recorder answers it at near-zero steady-state cost: a bounded ring
(``collections.deque(maxlen=N)``) of compact per-request timelines,
built from stamps the engine already keeps on each ``Request`` (arrival,
admission, first token, finish) — no tracer required, no per-step work,
one dict per *completed request*.  On a fault event the engine calls
:meth:`record_fault`, which appends one dump — a header line, the fault
facts, then the ring contents oldest-first — to a JSON-lines file.
``launch/trace_report.py --flight`` renders dumps for humans; the smoke
path (``make smoke-flight``) drives injected-fault -> dump -> parse end
to end.

Request stamps are ``time.time()`` wall clock except ``admit_pc``
(``perf_counter``); the recorder fixes one epoch offset at construction
to put admission on the wall clock, mirroring ``Tracer``'s clock
bridging in the other direction.
"""
from __future__ import annotations

import collections
import json
import time
from typing import Any, Dict, Optional


class FlightRecorder:
    """Bounded per-request history + fault-triggered JSONL dumps.

    ``capacity`` bounds the ring; ``path`` is the dump file (appended —
    one run can dump several faults; each dump is self-delimiting via
    its header's ``entries`` count).  ``path=None`` keeps the ring in
    memory only (tests introspect it directly).
    """

    def __init__(self, capacity: int = 32, path: Optional[str] = None):
        self.capacity = int(capacity)
        self.path = path
        self._ring: collections.deque = collections.deque(
            maxlen=max(1, self.capacity))
        self._epoch = time.time() - time.perf_counter()
        self.dumps = 0          # fault dumps written so far
        self.recorded = 0       # requests ever recorded (ring may be full)

    # -- recording ---------------------------------------------------------
    def record_request(self, req, *, slot: Optional[int] = None,
                       status: str = "ok") -> None:
        """Fold one finished/failed request's timeline into the ring.

        Derives the queue/staging/decode segments from the stamps the
        engine already maintains; missing stamps (a request shed while
        queued never stages) leave their segments ``None``.
        """
        arrival = getattr(req, "arrival_s", None)
        admit_pc = getattr(req, "admit_pc", None)
        admit = (admit_pc + self._epoch) if admit_pc is not None else None
        first = getattr(req, "first_token_s", None)
        finish = getattr(req, "finish_s", None)

        def seg(a, b):
            return round(b - a, 6) if a is not None and b is not None \
                else None

        self._ring.append({
            "uid": getattr(req, "uid", None),
            "status": status,
            "slot": slot,
            "prompt_tokens": len(getattr(req, "prompt", ()) or ()),
            "tokens": len(getattr(req, "out_tokens", ()) or ()),
            "retries": getattr(req, "retries", 0),
            "arrival_s": arrival,
            "queue_s": seg(arrival, admit),
            "staging_s": seg(admit, first),
            "decode_s": seg(first, finish),
            "latency_s": getattr(req, "latency_s", None) or
            seg(arrival, finish),
        })
        self.recorded += 1

    # -- dumping -----------------------------------------------------------
    def record_fault(self, kind: str, **facts: Any) -> Dict[str, Any]:
        """A fault event fired: snapshot the ring to the dump file.

        Returns the dump header (handy for tests).  The JSONL layout per
        dump is: one ``{"flight_dump": ...}`` header, one
        ``{"fault": ...}`` line, then ``entries`` request lines
        oldest-first.
        """
        header = {
            "flight_dump": self.dumps,
            "time_s": round(time.time(), 3),
            "kind": kind,
            "entries": len(self._ring),
            "capacity": self.capacity,
            "recorded_total": self.recorded,
        }
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(header) + "\n")
                f.write(json.dumps({"fault": {"kind": kind, **facts}}) +
                        "\n")
                for entry in self._ring:
                    f.write(json.dumps(entry) + "\n")
        self.dumps += 1
        self.last_fault = {"kind": kind, **facts}
        return header

    # -- introspection -----------------------------------------------------
    def entries(self):
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)


def load_flight(path: str):
    """Parse a flight-recorder JSONL file back into a list of dumps:
    ``[{"header": ..., "fault": ..., "requests": [...]}, ...]``.

    Tolerant of interleaved foreign lines before the first header (the
    file is append-only and self-delimiting via ``entries``).
    """
    dumps = []
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    i = 0
    while i < len(lines):
        ln = lines[i]
        if not isinstance(ln, dict) or "flight_dump" not in ln:
            i += 1
            continue
        header = ln
        fault = None
        i += 1
        if i < len(lines) and isinstance(lines[i], dict) \
                and "fault" in lines[i]:
            fault = lines[i]["fault"]
            i += 1
        n = int(header.get("entries", 0))
        requests = lines[i:i + n]
        i += n
        dumps.append({"header": header, "fault": fault,
                      "requests": requests})
    return dumps
