"""Self-speculative decoding: the accept rule, as pure host math.

The speculative loop (``docs/serving.md``) drafts ``k`` tokens per burst
with the cheap params (w8 by default), then verifies all ``k`` in ONE
batched ``verify_chunk`` call with the full-precision params.  SSMs make
the rollback side trivial — a rejected draft is undone by restoring an
O(1)-byte state snapshot (``DecodeAPI.export_state`` /
``StatePool.insert_rows``) instead of truncating a KV cache.

Notation, per batch row (vectors below are whole-batch):

* ``t0``           — the pending next-input token before the burst;
* ``d_1 .. d_k``   — the draft stream: token ``d_j`` sampled from the
  draft logits after consuming ``d_{j-1}`` (``d_0`` := ``t0``);
* ``g_0 .. g_{k-1}`` — the verify stream: token ``g_j`` sampled from the
  verify logits at position ``j`` after the chunk consumed inputs
  ``[t0, d_1 .. d_{k-1}]``.  ``g_j`` is exactly what sequential
  full-precision decode would emit after consuming ``d_j`` — so as long
  as the drafts match, the verify stream IS the target stream.

Accept rule: ``m = lcp(d, g)`` (:func:`accept_lengths`) counts drafts
confirmed by the verify stream; the burst emits ``n = min(m + 1, k)``
tokens (:func:`emit_counts`) — the ``m`` accepted drafts plus, when a
mismatch happened inside the window, the verify stream's correction
``g_m`` (the token full-precision decode would have produced instead).
Every emitted token is ``g_j``, never ``d_j``, so the output stream is
the full-precision stream by construction regardless of how bad the
draft is; the draft only controls how *many* verify tokens each burst
can bank.

Rollback (:func:`needs_rollback`) is needed iff ``m < k - 1``: the
verify chunk consumed all ``k`` inputs, which for ``m >= k - 1`` is
precisely the state after emitting ``n = k`` tokens (the last emitted
token is pending, not yet consumed — same convention as plain decode).
For smaller ``m`` the chunk consumed rejected drafts, so the row's
pre-burst snapshot is restored and the emitted tokens are re-consumed
through the ordinary decode program (the engine's overflow drain),
which re-advances the state on exactly the non-speculative trajectory.
``k = 1`` never rolls back.
"""
from __future__ import annotations

import numpy as np


def accept_lengths(draft: np.ndarray, verify: np.ndarray) -> np.ndarray:
    """Per-row longest-common-prefix length of the draft vs verify token
    streams: ``m[i]`` = number of leading positions where
    ``draft[i] == verify[i]`` (both ``(b, k)`` int arrays; returns
    ``(b,)`` int64 in ``[0, k]``)."""
    draft = np.asarray(draft)
    verify = np.asarray(verify)
    if draft.shape != verify.shape or draft.ndim != 2:
        raise ValueError(
            f"draft/verify must share a (b, k) shape: "
            f"{draft.shape} vs {verify.shape}")
    neq = draft != verify
    k = draft.shape[1]
    # argmax of a boolean row = index of the first True; all-False rows
    # (full match) report 0, so gate on any().
    return np.where(neq.any(axis=1), neq.argmax(axis=1), k).astype(np.int64)


def emit_counts(m: np.ndarray, k: int) -> np.ndarray:
    """Tokens emitted per row for accept lengths ``m``: the accepted
    prefix plus one verify correction, capped at the window
    (``min(m + 1, k)`` — a full match has no correction to add)."""
    return np.minimum(np.asarray(m) + 1, k)


def needs_rollback(m: np.ndarray, k: int) -> np.ndarray:
    """Rows whose post-verify state must be discarded: ``m < k - 1``
    means the chunk consumed at least one rejected draft token beyond
    the emitted stream.  ``m >= k - 1`` consumed exactly the emitted
    stream's prefix, so the post-verify state is already correct."""
    return np.asarray(m) < (k - 1)
