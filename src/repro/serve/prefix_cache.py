"""Prefix-state radix cache: cross-request reuse of recurrent state.

Re-prefilling shared prompt prefixes (system prompts, few-shot templates,
multi-turn history) is the continuous engine's biggest source of wasted
compute, and SSMs make eliminating it uniquely cheap: a prefix of ANY
length is fully summarized by a small fixed-size recurrent state (SSM
state + conv tail, RG-LRU ``h``), where a transformer needs
length-proportional KV rows.  This module caches those states at
chunk-boundary snapshots so a new admission can skip straight past any
previously-served prefix (``docs/prefix_cache.md``).

Keying — the padded staged stream, at ``chunk`` granularity
-----------------------------------------------------------
The cache is a radix tree whose edges are fixed-stride token chunks: node
at depth ``d`` holds the state snapshot after consuming the first
``d * chunk`` tokens of a staged prompt.  The key is the stream the chunk
program *actually processes* — the left-padded prompt
(``serve/continuous.py: _admit_chunked``), not the raw prompt — which is
what makes a restored request **bit-identical** to recomputing: the
snapshot is the exact state the same stream produced, so greedy outputs
with the cache on and off cannot diverge.  The flip side is an alignment
rule: two prompts share cache entries only when their padded streams
share chunks, i.e. the shared prefix must sit at the same offset from the
pad (prompt lengths congruent mod ``chunk``).  Template-shaped traffic
(fixed system prompts, fixed-stride turns) aligns naturally; fully ragged
lengths hit at ~1/chunk rate.  Removing the rule needs ragged (masked)
prefill — see the honest accounting in ``docs/prefix_cache.md``.

Mechanics
---------
* **Nodes** are refcounted: the engine pins the matched node at admission
  and every node it traverses/creates while staging, and releases them
  when the request leaves staging.  Eviction only ever removes *unpinned
  leaves*, so (a) an interior node is transitively protected by its
  children and (b) a snapshot a live slot is restoring from can never be
  collected out from under it.  Restores COPY the snapshot into the pool
  row (the same jitted row scatter as slot turnover), so even a
  post-release eviction cannot corrupt a live slot.
* **Byte budget**: snapshots live on the HOST (``model.export_state``
  device->host copies), each node accounting the true clipped bytes of
  its pytree (KV rows clipped to the prefix and window — honest
  accounting, see ``nn/attention.snapshot_keep_len``).  Inserting past
  ``capacity_bytes`` evicts least-recently-used leaves first; if pins
  block eviction the insert is *refused* — residency never exceeds the
  budget.
* **Metrics**: hits / misses / hit-tokens / inserts / refused inserts /
  evictions / resident and peak bytes (``stats()``), surfaced through
  ``ContinuousEngine.counters`` and the ``prefix`` block of
  ``BENCH_serve.json``.

The cache itself is pure host-side Python (dict walks over token tuples);
all device work stays in the jitted row gather/scatter ops shared with
``state_pool`` — compile-once discipline untouched.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.serve.tracing import NULL_TRACER


def snapshot_nbytes(snapshot) -> int:
    """True host bytes of a snapshot pytree."""
    return sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(snapshot))


def chunk_key(tokens: Sequence[int], chunk: int) -> List[Tuple[int, ...]]:
    """Split a (padded) token stream into the cache's edge labels: one
    tuple per full ``chunk`` tokens.  A trailing partial chunk is dropped
    — snapshots exist only at chunk boundaries."""
    toks = [int(t) for t in tokens]
    return [tuple(toks[i:i + chunk])
            for i in range(0, len(toks) - chunk + 1, chunk)]


class _Node:
    __slots__ = ("chunk", "parent", "children", "snapshot", "nbytes",
                 "refs", "stamp")

    def __init__(self, chunk, parent, snapshot, nbytes, stamp):
        self.chunk = chunk          # edge label from parent (token tuple)
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.snapshot = snapshot    # host pytree (batch-1 state rows)
        self.nbytes = nbytes
        self.refs = 0               # pins by in-flight stagings
        self.stamp = stamp          # LRU clock at last touch

    @property
    def depth(self) -> int:
        d, n = 0, self
        while n.parent is not None:
            d, n = d + 1, n.parent
        return d


class PrefixCache:
    """Token-keyed radix cache of chunk-boundary state snapshots."""

    def __init__(self, capacity_bytes: int, chunk: int, tracer=NULL_TRACER):
        if chunk <= 0:
            raise ValueError("prefix cache chunk must be positive")
        self.capacity_bytes = int(capacity_bytes)
        self.chunk = int(chunk)
        self.tracer = tracer
        self.root = _Node(None, None, None, 0, 0)
        self._nodes: List[_Node] = []
        self.resident_bytes = 0
        self._clock = 0
        self.reset_stats()

    # ------------------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the event counters (peak tracks residency from now on);
        the cached entries themselves are kept — use a fresh cache to
        drop contents."""
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.inserts = 0
        self.inserts_refused = 0
        self.evictions = 0
        self.peak_bytes = self.resident_bytes

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.stamp = self._clock

    def __len__(self) -> int:
        return len(self._nodes)

    # ------------------------------------------------------------------
    def match(self, chunks: Sequence[Tuple[int, ...]],
              max_depth: Optional[int] = None,
              pin: bool = True) -> Tuple[Optional[_Node], int]:
        """Longest cached prefix of ``chunks``: ``(node, depth)`` with
        ``depth * chunk`` tokens already summarized by ``node.snapshot``,
        or ``(None, 0)``.  ``max_depth`` caps the walk (the engine always
        leaves at least one chunk to recompute — the final chunk's logits
        produce the first sampled token).  Touches the whole matched path
        (LRU) and, with ``pin``, takes a reference on the matched node
        that the caller must :meth:`release`."""
        node, depth = self.root, 0
        limit = len(chunks) if max_depth is None else min(len(chunks),
                                                          max_depth)
        while depth < limit and chunks[depth] in node.children:
            node = node.children[chunks[depth]]
            depth += 1
            self._touch(node)
        if depth == 0:
            self.misses += 1
            return None, 0
        self.hits += 1
        self.hit_tokens += depth * self.chunk
        if pin:
            node.refs += 1
        return node, depth

    def child(self, node: Optional[_Node],
              chunk: Tuple[int, ...], pin: bool = True) -> Optional[_Node]:
        """Existing child of ``node`` (root when None) along ``chunk``,
        touched and optionally pinned; None when absent."""
        got = (node or self.root).children.get(chunk)
        if got is not None:
            self._touch(got)
            if pin:
                got.refs += 1
        return got

    def insert(self, node: Optional[_Node], chunk: Tuple[int, ...],
               snapshot, pin: bool = True) -> Optional[_Node]:
        """Attach a snapshot under ``node`` (root when None) along edge
        ``chunk``.  Returns the (pinned) new node, the existing child if
        another staging already inserted it, or None when the byte budget
        cannot admit it (nothing evictable) — residency never exceeds
        ``capacity_bytes``."""
        parent = node or self.root
        got = parent.children.get(chunk)
        if got is not None:
            return self.child(parent, chunk, pin=pin)
        nbytes = snapshot_nbytes(snapshot)
        if not self._make_room(nbytes):
            self.inserts_refused += 1
            self.tracer.instant("prefix_insert_refused", nbytes=nbytes,
                                resident_bytes=self.resident_bytes)
            return None
        self._clock += 1
        child = _Node(chunk, parent, snapshot, nbytes, self._clock)
        parent.children[chunk] = child
        self._nodes.append(child)
        self.resident_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)
        self.inserts += 1
        if pin:
            child.refs += 1
        return child

    def release(self, node: _Node) -> None:
        """Drop one pin (inverse of the ``pin=True`` in match/insert)."""
        node.refs -= 1
        assert node.refs >= 0, "prefix-cache refcount underflow"

    # ------------------------------------------------------------------
    def _make_room(self, need: int) -> bool:
        """Evict LRU unpinned leaves until ``need`` fits; False when pins
        (or the budget itself) make that impossible."""
        if need > self.capacity_bytes:
            return False
        while self.resident_bytes + need > self.capacity_bytes:
            victim = None
            for n in self._nodes:
                if n.children or n.refs:
                    continue
                if victim is None or n.stamp < victim.stamp:
                    victim = n
            if victim is None:
                return False
            self._evict(victim)
        return True

    def _evict(self, node: _Node) -> None:
        node.parent.children.pop(node.chunk)
        self._nodes.remove(node)
        self.resident_bytes -= node.nbytes
        node.snapshot = None
        self.evictions += 1
        self.tracer.instant("prefix_evict", nbytes=node.nbytes,
                            resident_bytes=self.resident_bytes)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "nodes": len(self._nodes),
            "resident_bytes": self.resident_bytes,
            "peak_bytes": self.peak_bytes,
            "capacity_bytes": self.capacity_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_tokens": self.hit_tokens,
            "inserts": self.inserts,
            "inserts_refused": self.inserts_refused,
            "evictions": self.evictions,
        }
