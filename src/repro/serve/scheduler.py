"""Request admission: queue policy, priorities, deadlines, bucketing.

The scheduler owns everything that happens *before* a request touches an
accelerator: FCFS or priority ordering, deadline-based load shedding, and
the prompt->prefill-bucket mapping (with explicit truncation accounting —
nothing is silently clipped).  Both engines (wave and continuous) share it,
which is what keeps their admission semantics comparable in benchmarks.
"""
from __future__ import annotations

import dataclasses
import heapq
import logging
import time
from typing import Callable, List, Optional, Sequence, Tuple

from repro.serve.tracing import NULL_TRACER

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0        # arrival -> completion (wall)
    # -- admission metadata -------------------------------------------------
    truncated: bool = False       # prompt exceeded the largest prefill bucket
    priority: int = 0             # lower = served sooner (priority policy)
    deadline_s: Optional[float] = None   # absolute time.time() admission SLA
    expired: bool = False         # shed: deadline passed while queued
    bucket: int = 0               # prefill bucket chosen at admission
    # -- robustness (docs/robustness.md) ------------------------------------
    # Outcome label: "ok" | "shed_deadline" | "shed_overload" | "poisoned"
    # | "retry_exhausted" — callers split completions from casualties.
    status: str = "ok"
    retries: int = 0              # watchdog-recovery requeues so far
    not_before_s: Optional[float] = None  # retry backoff: defer admission
    # -- timing (absolute time.time() stamps) -------------------------------
    arrival_s: float = 0.0
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    # -- tracing (time.perf_counter() stamps; serve/tracing.py) -------------
    admit_pc: Optional[float] = None     # popped from the queue
    decode_pc: Optional[float] = None    # first token -> decode residency
    # -- streaming ----------------------------------------------------------
    on_token: Optional[Callable[[int, int], None]] = None  # (uid, token)

    def emit(self, token: int) -> None:
        self.out_tokens.append(token)
        if self.on_token is not None:
            self.on_token(self.uid, token)


def bucket_for(buckets: Sequence[int], length: int) -> Tuple[int, bool]:
    """Smallest configured bucket that fits ``length``.

    Returns ``(bucket, truncated)`` — ``truncated`` is True when the prompt
    is longer than the largest bucket and only its last ``bucket`` tokens
    can be prefilled.
    """
    for b in buckets:
        if length <= b:
            return b, False
    return buckets[-1], True


def chunk_span(buckets: Sequence[int], chunk: int, length: int) -> int:
    """Padded prefill length under chunked prefill: the prompt (capped at
    the largest bucket, same truncation rule as the monolithic path)
    left-pads to the next ``chunk`` multiple — always at least one chunk,
    so empty/short prompts still produce a first token."""
    capped = min(max(length, 1), buckets[-1])
    return -(-capped // chunk) * chunk


def flag_truncation(req: Request, buckets: Sequence[int]) -> None:
    """Mark (and warn about) prompts that overflow the largest bucket."""
    bucket, truncated = bucket_for(buckets, len(req.prompt))
    if truncated:
        req.truncated = True
        log.warning(
            "request %d: prompt length %d exceeds largest prefill bucket %d; "
            "truncating to the last %d tokens", req.uid, len(req.prompt),
            bucket, bucket)


def build_request(uid: int, prompt: Sequence[int], max_new_tokens: int, *,
                  priority: int = 0, deadline_s: Optional[float] = None,
                  on_token=None, buckets: Sequence[int] = (),
                  metrics=None) -> Request:
    """Shared submit-time bookkeeping for both engines: construct the
    Request, flag truncation, and stamp arrival metrics."""
    req = Request(uid=uid, prompt=list(prompt),
                  max_new_tokens=max_new_tokens, priority=priority,
                  deadline_s=deadline_s, arrival_s=time.time(),
                  on_token=on_token)
    if buckets:
        flag_truncation(req, buckets)
    if metrics is not None:
        metrics.record_arrival()
        if req.truncated:
            metrics.truncated += 1
    return req


class Scheduler:
    """Admission queue shared by both serving engines.

    * ``fcfs``      — strict arrival order.
    * ``priority``  — lower ``Request.priority`` first, FCFS within a level.

    Requests with an absolute ``deadline_s`` that has already passed when a
    slot frees up are shed (``expired=True``) instead of occupying a slot —
    they land in ``self.expired`` for the caller to report.
    """

    def __init__(self, policy: str = "fcfs", tracer=None):
        if policy not in ("fcfs", "priority"):
            raise ValueError(f"unknown scheduler policy {policy!r}")
        self.policy = policy
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._heap: List[Tuple[Tuple[int, int], Request]] = []
        self._seq = 0
        self.expired: List[Request] = []

    def __len__(self) -> int:
        return len(self._heap)

    def submit(self, req: Request) -> None:
        self._seq += 1
        level = req.priority if self.policy == "priority" else 0
        heapq.heappush(self._heap, ((level, self._seq), req))

    def pop_ready(self, now: float) -> Optional[Request]:
        """Next admissible request, shedding any whose deadline passed.

        Requests carrying a retry-backoff stamp (``not_before_s``) are
        deferred: re-pushed at the back of their priority level until the
        stamp passes.  (FCFS position within the level is not preserved
        across a deferral — a retried request yields to fresher arrivals,
        which is the intended penalty.)"""
        deferred: List[Request] = []
        try:
            while self._heap:
                _, req = heapq.heappop(self._heap)
                if req.deadline_s is not None and now > req.deadline_s:
                    req.expired = True
                    req.done = True
                    req.status = "shed_deadline"
                    self.expired.append(req)
                    self.tracer.instant("shed", uid=req.uid,
                                        reason="deadline",
                                        queued_s=now - req.arrival_s)
                    log.warning("request %d: deadline missed while queued; "
                                "shedding", req.uid)
                    continue
                if req.not_before_s is not None and now < req.not_before_s:
                    deferred.append(req)
                    continue
                return req
            return None
        finally:
            for req in deferred:
                self.submit(req)
