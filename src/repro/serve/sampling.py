"""Vectorized token sampling shared by both serving engines.

Temperature sampling uses the Gumbel-max trick — ``argmax(z + g)`` with
``g ~ Gumbel(0, 1)`` samples exactly from ``softmax(z)`` — which replaces
the per-row ``np.random.choice`` Python loop with one batched argmax.

Two keying schemes derive the noise:

* :func:`sample` (wave engine) keys on ``(seed, step)``: a given engine
  configuration replays identically regardless of how many requests came
  before, but the draw a token gets depends on *when* its decode step
  ran relative to everything else in the batch.
* :func:`sample_keyed` (continuous engine) keys on ``(seed, uid,
  position)`` per row: a token's randomness is a pure function of which
  request it belongs to and where in that request's stream it sits —
  independent of slot assignment, batch composition, scheduling history,
  and of whether the token was produced by a plain decode step, a draft
  step, or a speculative verify chunk.  That last invariance is what
  keeps self-speculative decoding (``serve/speculative.py``) exact under
  temperature sampling: the verify chunk samples position ``p`` with the
  *same* noise the non-speculative decode step would have used at ``p``.

Greedy (``temperature <= 0``) is a pure argmax under both schemes.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

_TINY = 1e-20


def step_rng(seed: int, step: int) -> np.random.Generator:
    """Deterministic per-step generator: independent of call history."""
    return np.random.default_rng([seed, step])


def _gumbel(rng: np.random.Generator, shape) -> np.ndarray:
    u = rng.random(size=shape)
    return -np.log(-np.log(u + _TINY) + _TINY)


def sample(logits: np.ndarray, temperature: float,
           rng: np.random.Generator) -> np.ndarray:
    """Greedy (temperature<=0) or Gumbel-max temperature sampling.

    logits: (b, vocab) float; returns (b,) int32 token ids.
    """
    logits = np.asarray(logits, np.float32)
    if temperature <= 0.0:
        return np.argmax(logits, axis=-1).astype(np.int32)
    z = logits / temperature
    return np.argmax(z + _gumbel(rng, z.shape), axis=-1).astype(np.int32)


def keyed_gumbel(seed: int, uids: Sequence[int], positions: Sequence[int],
                 vocab: int) -> np.ndarray:
    """Per-row Gumbel(0, 1) noise keyed by ``(seed, uid, position)``:
    row ``i`` draws from ``default_rng([seed, uids[i], positions[i]])``,
    so the noise a (request, position) pair gets is independent of batch
    shape, row order, and call history.  Returns ``(len(uids), vocab)``
    float32."""
    g = np.empty((len(uids), vocab), np.float32)
    for i, (u, p) in enumerate(zip(uids, positions)):
        g[i] = _gumbel(np.random.default_rng([seed, int(u), int(p)]), vocab)
    return g


def sample_keyed(logits: np.ndarray, temperature: float, seed: int,
                 uids: Sequence[int], positions: Sequence[int]) -> np.ndarray:
    """Gumbel-max sampling with per-row ``(seed, uid, position)`` noise
    (see module docstring; greedy when ``temperature <= 0``).

    logits: (b, vocab) float; ``uids`` / ``positions``: length-b ints —
    the owning request id and the *output* position being sampled (the
    number of tokens the row will have consumed once this token is fed
    back).  Returns (b,) int32 token ids."""
    logits = np.asarray(logits, np.float32)
    if temperature <= 0.0:
        return np.argmax(logits, axis=-1).astype(np.int32)
    z = logits / temperature
    g = keyed_gumbel(seed, uids, positions, z.shape[-1])
    return np.argmax(z + g, axis=-1).astype(np.int32)
