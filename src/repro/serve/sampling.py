"""Vectorized token sampling shared by both serving engines.

Temperature sampling uses the Gumbel-max trick — ``argmax(z + g)`` with
``g ~ Gumbel(0, 1)`` samples exactly from ``softmax(z)`` — which replaces
the per-row ``np.random.choice`` Python loop with one batched argmax.
Randomness is derived per decode step from ``(seed, step)`` so a given
engine configuration replays identically regardless of how many requests
came before.
"""
from __future__ import annotations

import numpy as np

_TINY = 1e-20


def step_rng(seed: int, step: int) -> np.random.Generator:
    """Deterministic per-step generator: independent of call history."""
    return np.random.default_rng([seed, step])


def sample(logits: np.ndarray, temperature: float,
           rng: np.random.Generator) -> np.ndarray:
    """Greedy (temperature<=0) or Gumbel-max temperature sampling.

    logits: (b, vocab) float; returns (b,) int32 token ids.
    """
    logits = np.asarray(logits, np.float32)
    if temperature <= 0.0:
        return np.argmax(logits, axis=-1).astype(np.int32)
    z = logits / temperature
    u = rng.random(size=z.shape)
    g = -np.log(-np.log(u + _TINY) + _TINY)
    return np.argmax(z + g, axis=-1).astype(np.int32)
