"""Per-slot cache state pool: allocate once, scatter/gather rows forever.

Continuous batching hinges on one property XAMBA's Step-1 already bought
us: decode state is a *fixed-shape* pytree with one batch row per request
(SSM state + conv tail for Mamba, KV ring buffers for attention,
per-layer mixtures for Griffin).  The pool allocates that pytree once for
``slots`` rows and exposes three row-wise primitives —

* ``insert_rows``  — scatter freshly-prefilled rows into live slots,
* ``extract_rows`` — gather slot rows out (debug / migration),
* ``reset_rows``   — zero slot rows,
* ``clone_row`` / ``restore_row`` — host-side snapshot of one row and its
  inverse (the prefix cache's primitives, via ``model.export_state`` /
  ``model.import_state``),

each compiled exactly once (slot indices are traced scalars), so slot
turnover never recompiles anything.

The batch axis is *probed*, not assumed: ``init_cache`` is called at two
batch sizes and each leaf's differing axis is recorded.  That keeps the
pool agnostic to layout differences like scan-stacked layers
(``(n_layers, b, ...)``, batch axis 1) vs per-layer lists (batch axis 0).

The continuous engine runs TWO pools over the same layout: the decode
pool (live slot state) and, under chunked prefill, a staging pool whose
rows accumulate per-chunk state until a prompt completes and its row is
scattered into the decode pool (``serve/continuous.py``).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.serve.tracing import NULL_TRACER

Array = jax.Array


def infer_batch_axes(model, max_seq: int, dtype) -> Any:
    """Pytree of ints: the batch axis of every cache leaf, found by probing
    ``init_cache`` at two batch sizes."""
    a = model.init_cache(2, max_seq, dtype)
    b = model.init_cache(3, max_seq, dtype)

    def one(x, y):
        diffs = [i for i, (p, q) in enumerate(zip(x.shape, y.shape))
                 if p != q]
        if len(diffs) != 1:
            raise ValueError(
                f"cannot infer batch axis: shapes {x.shape} vs {y.shape}")
        return diffs[0]

    return jax.tree.map(one, a, b)


def make_row_ops(axes):
    """Jitted row-wise primitives over a cache pytree with per-leaf batch
    axes ``axes``: ``(insert, extract, reset)``.

    ``insert(dst, src, src_row, slot)`` scatters one ``src`` row into
    ``dst`` (``dst`` is DONATED — the arena updates in place);
    ``extract(src, slot)`` gathers one row as a fresh batch-1 pytree (no
    donation — safe to call between donated-arena updates); ``reset(dst,
    slot)`` zeroes one row (``dst`` donated).  Row indices are traced
    scalars, so each op compiles exactly once per cache layout.

    Shared by :class:`StatePool` and the model-level snapshot API
    (``models/base.py: DecodeAPI.export_state/import_state``) so every
    row move in the serve path — slot turnover, staging, prefix-cache
    snapshot/restore — is the same compiled gather/scatter."""

    def insert(dst, src, src_row, slot):
        def leaf(d, s, ax):
            row = jax.lax.dynamic_slice_in_dim(s, src_row, 1, axis=ax)
            return jax.lax.dynamic_update_slice_in_dim(
                d, row.astype(d.dtype), slot, axis=ax)
        return jax.tree.map(leaf, dst, src, axes)

    def extract(src, slot):
        return jax.tree.map(
            lambda s, ax: jax.lax.dynamic_slice_in_dim(s, slot, 1, axis=ax),
            src, axes)

    def reset(dst, slot):
        def leaf(d, ax):
            shape = list(d.shape)
            shape[ax] = 1
            return jax.lax.dynamic_update_slice_in_dim(
                d, jnp.zeros(shape, d.dtype), slot, axis=ax)
        return jax.tree.map(leaf, dst, axes)

    return (jax.jit(insert, donate_argnums=(0,)),
            jax.jit(extract),
            jax.jit(reset, donate_argnums=(0,)))


def jit_cache_size(fn) -> int:
    """Number of compiled programs behind a jitted callable (-1 if the
    running jax version does not expose it)."""
    try:
        return fn._cache_size()
    except Exception:
        return -1


def format_compile_count(n: int):
    """Human-facing compile counter: older jax returns -1 from
    ``jit_cache_size``; surface that as "unavailable" rather than a
    misleading negative count."""
    return n if n >= 0 else "unavailable"


class StatePool:
    """Slot-indexed decode-state arena for one model family.

    ``self.cache`` is the live pytree the decode program reads and writes;
    the row primitives functionally update it (callers never touch leaf
    layout).  Axis probing is lazy so wave-style users that only need the
    one-shot allocation pay nothing for it.
    """

    def __init__(self, model, slots: int, max_seq: int, dtype,
                 tracer=NULL_TRACER):
        self.model = model
        self.slots = slots
        self.max_seq = max_seq
        self.dtype = dtype
        self.tracer = tracer
        self.cache = model.init_cache(slots, max_seq, dtype)
        self._axes = None
        self._insert = None
        self._extract = None
        self._reset = None

    # ------------------------------------------------------------------
    @property
    def batch_axes(self):
        if self._axes is None:
            # One source of truth with the model-level snapshot API: the
            # family's declared layout rule drives BOTH the pool row ops
            # and export_state/import_state — a disagreement would mean
            # clone/restore addressing different rows than insert/reset
            # on the same donated arena.  Probing stays as the fallback
            # for models that predate cache_batch_axes.
            try:
                self._axes = self.model.cache_batch_axes(self.cache)
            except NotImplementedError:
                self._axes = infer_batch_axes(self.model, self.max_seq,
                                              self.dtype)
        return self._axes

    def _build_ops(self):
        # The live pool pytree is DONATED into insert/reset: slot turnover
        # updates the arena in place instead of copying every leaf.
        self._insert, self._extract, self._reset = make_row_ops(
            self.batch_axes)

    # ------------------------------------------------------------------
    def insert_rows(self, src_cache, src_rows: Sequence[int],
                    slots: Sequence[int]) -> None:
        """Scatter ``src_cache`` row ``src_rows[i]`` into live slot
        ``slots[i]`` (e.g. rows of a fresh per-bucket prefill)."""
        if self._insert is None:
            self._build_ops()
        with self.tracer.span("pool_insert", rows=len(slots)):
            for r, s in zip(src_rows, slots):
                self.cache = self._insert(self.cache, src_cache,
                                          jnp.int32(r), jnp.int32(s))

    def extract_rows(self, slots: Sequence[int]):
        """Gather slot rows; returns a cache pytree with batch = len(slots)
        (rows concatenated along each leaf's batch axis)."""
        if self._extract is None:
            self._build_ops()
        rows = [self._extract(self.cache, jnp.int32(s)) for s in slots]
        if len(rows) == 1:
            return rows[0]
        return jax.tree.map(
            lambda ax, *ls: jnp.concatenate(ls, axis=ax),
            self.batch_axes, *rows)

    def clone_row(self, slot: int, index=None):
        """Host-side snapshot of one slot row — the jitted row gather
        (never the donated arena itself) followed by a device->host copy,
        so the snapshot's lifetime is decoupled from the pool: the arena
        can keep being donated into decode/chunk programs while the
        snapshot sits in a prefix cache or migrates to another pool.

        ``index`` — tokens the row has consumed — lets families clip
        length-proportional state (attention KV rows) to the valid prefix;
        ``None`` keeps full rows.  This is the prefix cache's insertion
        primitive (``serve/prefix_cache.py``) and the debug/migration
        snapshot; delegates to ``model.export_state`` so the pool and the
        model-level snapshot API stay one code path."""
        with self.tracer.span("snapshot_export", slot=slot):
            return self.model.export_state(self.cache, index, [slot])

    def restore_row(self, slot: int, snapshot, index=None) -> None:
        """Inverse of :meth:`clone_row`: scatter a host snapshot back into
        one slot row (jitted row scatter, arena donated in place)."""
        with self.tracer.span("snapshot_restore", slot=slot):
            self.cache = self.model.import_state(self.cache, index, [slot],
                                                 snapshot)

    def reset_rows(self, slots: Sequence[int]) -> None:
        """Zero slot rows (freed slots carry no state into their next
        tenant; insert_rows overwrites anyway, this is belt-and-braces)."""
        if self._reset is None:
            self._build_ops()
        with self.tracer.span("pool_reset", rows=len(slots)):
            for s in slots:
                self.cache = self._reset(self.cache, jnp.int32(s))

    # ------------------------------------------------------------------
    def compile_counts(self) -> dict:
        return {"insert": format_compile_count(jit_cache_size(self._insert))
                if self._insert else 0,
                "extract": format_compile_count(jit_cache_size(self._extract))
                if self._extract else 0,
                "reset": format_compile_count(jit_cache_size(self._reset))
                if self._reset else 0}
