"""Compiled-program registry: per-program cost cards and quality budgets.

XAMBA's methodology is bottleneck attribution — the paper found
CumSum/ReduceSum by *measuring per-op cost*, not by staring at wall
clocks — and the serve stack's own history repeats the lesson: the
XLA-CPU layout cliff (ROADMAP; 48 copies and a 1027-instruction block at
IDENTICAL compiled flops/bytes) was found by hand with ``make hlo-diff``
because nothing tracked compiled-program *quality* as a metric.

This module makes every program the engine warms up a first-class
observable.  Engines ``register()`` each jitted program (fused decode
step, per-bucket prefill, ``prefill_chunk``, ``verify_chunk``, the state
pools' row ops) with example argument *shapes*; the registry assigns a
stable **program id** (``p<N>:<name>``) that rides through recompile
sentinels and trace spans so ``launch/trace_report`` can attribute wall
time per program.  On demand — never on the serve hot path — it builds a
**program card** per program via jax's AOT API
(``fn.lower(*ShapeDtypeStructs).compile()``):

* ``cost_analysis``      — compiled flops / bytes accessed;
* ``memory_analysis``    — argument / output / temp-arena / codegen bytes;
* op fingerprint         — instruction count, opcode mix, **copy count**
  (``launch/hlo_analysis.op_fingerprint``);
* compile wall time      — the AOT compile of this exact program;
* roofline terms         — ``hlo_analysis.roofline_terms`` seconds.

Cards carry an optional :class:`ProgramBudget` — copy-count and
temp-arena ceilings — that fails loudly (``check_budgets``) when a
layout regression reappears: the budget trips, not a human with a diff.

Card building deliberately uses ``lower().compile()``, which does NOT
share the jit dispatch cache: a card costs one extra AOT compile.  That
is why cards are lazy (benchmarks, CLIs and tests build them; serving
never does) — registration itself only records shapes, so the hot path
and warmup stay untouched and the <= 2% tracing-overhead budget holds
trivially.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

MB = 2 ** 20

# Decode-cache layout pinned per family by benchmarks/bench_kpi_decode
# (BENCH_decode.json's ``decode_layout``): the layout each family's full
# -size decode program actually serves with, i.e. the one its budget
# must hold on.
PINNED_SCAN_LAYERS = {"mamba2-130m": True, "mamba-130m": False}

# Full-size budgets (docs/benchmarks.md, "layout cliff"): the good
# mamba2-130m decode layout compiles with 1 copy and a 37.7 MB temp
# arena; the per-layer cliff inserts 48 copies (and, scan-stacked on
# mamba1's side, a 191.8 MB temp blow-up).  Ceilings sit above the good
# layout with headroom and far below the cliff, keyed on the FULL
# d_model so reduced test configs never inherit them.
DEFAULT_BUDGETS = {
    ("mamba2-130m", "decode"): {"max_copies": 8,
                                "max_temp_bytes": 64 * MB,
                                "min_d_model": 768},
    ("mamba-130m", "decode"): {"max_copies": 64,
                               "max_temp_bytes": 64 * MB,
                               "min_d_model": 768},
}


def budget_for(cfg, program: str) -> Optional["ProgramBudget"]:
    """Default budget for ``(model config, program name)`` — None when the
    config is a reduced variant (budgets describe full-size programs)."""
    spec = DEFAULT_BUDGETS.get((getattr(cfg, "name", None), program))
    if spec is None:
        return None
    if getattr(cfg, "d_model", 0) < spec["min_d_model"]:
        return None
    return ProgramBudget(max_copies=spec["max_copies"],
                         max_temp_bytes=spec["max_temp_bytes"])


def shape_args(args: Sequence[Any]):
    """Example arguments -> ``jax.ShapeDtypeStruct`` pytrees (per leaf),
    so the registry never holds live buffers: donated arenas can be
    consumed freely after registration, and card builds lower from
    shapes alone."""
    import jax

    def leaf(x):
        if isinstance(x, jax.ShapeDtypeStruct):
            return x
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            return jax.ShapeDtypeStruct(tuple(x.shape), x.dtype)
        return x          # static python leaf (rare) — lowered as-is

    return tuple(jax.tree.map(leaf, a) for a in args)


@dataclasses.dataclass(frozen=True)
class ProgramBudget:
    """Quality ceilings for one compiled program.  ``None`` disables a
    dimension.  Copy count is the layout-cliff tripwire (the cliff shows
    as copy/transpose insertion at equal flops); the temp-arena ceiling
    catches buffer-assignment blow-ups the op mix cannot see."""

    max_copies: Optional[int] = None
    max_temp_bytes: Optional[int] = None

    def to_dict(self) -> dict:
        return {"max_copies": self.max_copies,
                "max_temp_bytes": self.max_temp_bytes}


@dataclasses.dataclass
class ProgramCard:
    """One compiled program's cost/quality card (see module docstring)."""

    name: str
    program_id: str
    flops: float = 0.0
    bytes_accessed: float = 0.0
    argument_bytes: Optional[int] = None
    output_bytes: Optional[int] = None
    temp_bytes: Optional[int] = None
    generated_code_bytes: Optional[int] = None
    instructions: int = 0
    opcodes: int = 0
    copies: int = 0
    copy_bytes: int = 0
    compile_s: float = 0.0
    roofline: Dict[str, Any] = dataclasses.field(default_factory=dict)
    budget: Optional[ProgramBudget] = None

    @property
    def roofline_s(self) -> float:
        """Modeled best-case seconds per call: the binding roofline term
        (compute vs memory; serve programs have no collectives)."""
        return max(self.roofline.get("compute_s", 0.0),
                   self.roofline.get("memory_s", 0.0))

    def check_budget(self) -> List[str]:
        """Budget violations (empty = within budget / no budget)."""
        out: List[str] = []
        b = self.budget
        if b is None:
            return out
        if b.max_copies is not None and self.copies > b.max_copies:
            out.append(
                f"program {self.name!r} ({self.program_id}): {self.copies} "
                f"copy ops exceed budget {b.max_copies} — layout "
                f"regression (see ROADMAP layout cliff / make hlo-diff)")
        if b.max_temp_bytes is not None and self.temp_bytes is not None \
                and self.temp_bytes > b.max_temp_bytes:
            out.append(
                f"program {self.name!r} ({self.program_id}): temp arena "
                f"{self.temp_bytes / MB:.1f} MB exceeds budget "
                f"{b.max_temp_bytes / MB:.1f} MB")
        return out

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out.pop("budget", None)
        out["budget"] = self.budget.to_dict() if self.budget else None
        out["roofline_s"] = self.roofline_s
        out["budget_violations"] = self.check_budget()
        return out


def build_card(name: str, program_id: str, fn, example_args,
               budget: Optional[ProgramBudget] = None) -> ProgramCard:
    """AOT-compile ``fn`` at ``example_args`` shapes and measure the card.

    One fresh XLA compile per call (the AOT path shares no dispatch
    cache) — callers amortize via :meth:`ProgramRegistry.cards`."""
    from repro.launch.hlo_analysis import (buffer_assignment_stats,
                                           op_fingerprint, roofline_terms)

    t0 = time.perf_counter()
    compiled = fn.lower(*example_args).compile()
    compile_s = time.perf_counter() - t0

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0) or 0.0)
    bytes_accessed = float(ca.get("bytes accessed", 0.0) or 0.0)

    mem = buffer_assignment_stats(compiled)
    fp = op_fingerprint(compiled.as_text())
    copies = fp.get("copy", {"count": 0, "bytes": 0})

    card = ProgramCard(
        name=name, program_id=program_id,
        flops=flops, bytes_accessed=bytes_accessed,
        argument_bytes=mem.get("argument_size_in_bytes"),
        output_bytes=mem.get("output_size_in_bytes"),
        temp_bytes=mem.get("temp_size_in_bytes"),
        generated_code_bytes=mem.get("generated_code_size_in_bytes"),
        instructions=sum(v["count"] for v in fp.values()),
        opcodes=len(fp),
        copies=copies["count"], copy_bytes=copies["bytes"],
        compile_s=round(compile_s, 4),
        roofline=roofline_terms(flops, bytes_accessed, 0.0, 0.0),
        budget=budget)
    return card


class ProgramRegistry:
    """Name -> (program id, lowering recipe, budget) for every compiled
    program one engine warms up.  Registration is cheap (shapes only);
    cards build lazily and cache until ``invalidate()`` (e.g. a backend
    -fallback rebuild swaps the jits underneath)."""

    def __init__(self):
        self._entries: Dict[str, dict] = {}
        self._order: List[str] = []

    # -- registration ------------------------------------------------------
    def register(self, name: str, fn=None, example_args=None, *,
                 fn_thunk: Optional[Callable[[], Any]] = None,
                 budget: Optional[ProgramBudget] = None) -> str:
        """Register (or refresh) a program.  ``fn`` is the jitted
        callable; ``fn_thunk`` defers resolution to card-build time (for
        lazily-built programs like the pools' row ops).  Re-registering a
        name keeps its id — a backend rebuild swaps the recipe, not the
        identity the trace spans reference."""
        if fn is None and fn_thunk is None:
            raise ValueError(f"program {name!r}: need fn or fn_thunk")
        if name in self._entries:
            entry = self._entries[name]
        else:
            entry = {"id": f"p{len(self._order)}:{name}"}
            self._entries[name] = entry
            self._order.append(name)
        entry["fn_thunk"] = fn_thunk if fn_thunk is not None \
            else (lambda f=fn: f)
        entry["example_args"] = (shape_args(example_args)
                                 if example_args is not None else None)
        if budget is not None or "budget" not in entry:
            entry["budget"] = budget
        entry.pop("card", None)      # recipe changed -> stale card
        return entry["id"]

    def set_example_args(self, name: str, example_args) -> None:
        entry = self._entries[name]
        entry["example_args"] = shape_args(example_args)
        entry.pop("card", None)

    # -- lookups -----------------------------------------------------------
    def names(self) -> List[str]:
        return list(self._order)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def program_id(self, name: str) -> Optional[str]:
        entry = self._entries.get(name)
        return entry["id"] if entry else None

    def budget(self, name: str) -> Optional[ProgramBudget]:
        return self._entries[name].get("budget")

    # -- cards -------------------------------------------------------------
    def card(self, name: str, rebuild: bool = False) -> ProgramCard:
        """Build (or return the cached) card for one program.  Raises
        ``KeyError`` for unknown names and ``ValueError`` for programs
        registered without example args (no lowering recipe)."""
        entry = self._entries[name]
        if not rebuild and "card" in entry:
            return entry["card"]
        if entry.get("example_args") is None:
            raise ValueError(
                f"program {name!r} registered without example args — "
                f"no shapes to lower the card from")
        fn = entry["fn_thunk"]()
        if fn is None:
            raise ValueError(f"program {name!r}: recipe resolved to None "
                             f"(not built yet?)")
        entry["card"] = build_card(name, entry["id"], fn,
                                   entry["example_args"],
                                   budget=entry.get("budget"))
        return entry["card"]

    def cards(self, names: Optional[Sequence[str]] = None,
              rebuild: bool = False) -> Dict[str, ProgramCard]:
        """Cards for ``names`` (default: every program with example
        args).  Programs whose recipe cannot build (lazy op not built
        yet) are skipped when building the default set, and raise when
        requested by name."""
        if names is not None:
            return {n: self.card(n, rebuild=rebuild) for n in names}
        out = {}
        for n in self._order:
            if self._entries[n].get("example_args") is None:
                continue
            try:
                out[n] = self.card(n, rebuild=rebuild)
            except ValueError:
                continue
        return out

    def invalidate(self) -> None:
        """Drop cached cards (the jits were rebuilt, e.g. by a backend
        fallback); ids and budgets survive."""
        for entry in self._entries.values():
            entry.pop("card", None)

    # -- budgets -----------------------------------------------------------
    def check_budgets(self, names: Optional[Sequence[str]] = None
                      ) -> List[str]:
        """Build cards for every budgeted program and collect violations
        (empty list = all budgets hold)."""
        out: List[str] = []
        targets = names if names is not None else [
            n for n in self._order
            if self._entries[n].get("budget") is not None]
        for n in targets:
            out.extend(self.card(n).check_budget())
        return out

    def assert_budgets(self, names: Optional[Sequence[str]] = None) -> None:
        problems = self.check_budgets(names)
        if problems:
            raise RuntimeError("program budget violation(s):\n  " +
                               "\n  ".join(problems))

    def to_dict(self) -> Dict[str, dict]:
        """Every *built* card as plain dicts (for BENCH blocks / JSON
        dumps); call :meth:`cards` first to force building."""
        return {n: self._entries[n]["card"].to_dict()
                for n in self._order if "card" in self._entries[n]}
