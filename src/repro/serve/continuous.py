"""Continuous-batching engine: slot-level refill under static shapes.

The wave engine decodes lockstep batches: one straggler request holds
every finished slot hostage, and queued requests wait for the whole wave
to drain.  This engine keeps ``max_batch`` persistent *slots* backed by a
:class:`~repro.serve.state_pool.StatePool`; the moment a slot's request
finishes (EOS / token budget), the scheduler admits the next queued
request into that slot mid-decode.

Compile-once discipline (the paper's Step-1 constraint) is preserved with
exactly three compiled programs (plus one prefill variant per bucket):

* **decode**  — ``(params, tok (slots,1), cache, pos (slots,))``; the
  position vector gives every slot its own offset, so freshly admitted
  requests decode next to old ones without recompiling.  Dead slots keep
  decoding into a sink row (static shapes, zero recompiles).  The pool's
  cache pytree is *donated* into the program: slot state updates in place
  every step — no per-step state copies, no fresh pytree allocations.
* **prefill** — per-bucket, always at batch ``slots`` (unused rows are
  padding): a refill of one slot reuses the same program as a full wave.
* **insert**  — the pool's row scatter moves a prefilled request's state
  (SSM state + conv tail / KV rows) into its slot; slot index is traced.

Position realignment: a request prefilled at bucket ``B`` starts decoding
at position ``B`` regardless of what its neighbours are doing — SSM rows
carry position in their state, attention rows take the per-row position
vector (RoPE + KV write + causal mask all realign per row).

Chunked prefill (``ServeConfig.prefill_chunk``): the monolithic per-bucket
prefill blocks every live slot for the whole prompt — a long prompt stalls
the decode wave and spikes the running requests' inter-token latency and
the queue's TTFT tail.  With a chunk size configured, admitted prompts
left-pad to a chunk multiple and advance **one chunk per poll** (per the
token budget), batched across all prefilling slots in a second state pool,
interleaved with the decode step.  That adds ONE more compiled program —
``prefill_chunk`` at ``(slots, chunk)`` with a per-row offset vector — so
the compile-once discipline still holds (0 decode recompiles after
warmup); ``models/base.py: DecodeAPI.prefill_chunk`` guarantees the result
is numerically the whole-sequence prefill.

Self-speculative decoding (``ServeConfig.speculate_k``): when every live
slot is caught up, a poll runs a *burst* instead of one decode step —
``k`` decode-program calls with the cheap draft params (w8 by default) on
a scratch copy of the slot states, then ONE ``verify_chunk`` call at
``(slots, k)`` with the full-precision params on the decode pool.  Each
row emits its longest verified prefix plus one correction token (accept
rule: ``serve/speculative.py``); rows whose window contained a rejected
draft restore their pre-burst snapshot (a compile-once pool row scatter —
O(1) state bytes, the SSM advantage) and re-consume their emitted tokens
through the ordinary decode program, one per poll, before the next burst.
That re-advance keeps rolled-back state bit-exact with the
non-speculative trajectory; emitted tokens are always the verify
stream's, so outputs match the non-speculative engine byte-for-byte
under greedy AND under keyed temperature sampling.  Three more compiled
programs (draft decode = the decode program retraced for the quantized
param pytree, ``verify``, and the two extra pools' row ops), all fixed
shape — compile-once discipline holds.

Prefix-state cache (``ServeConfig.prefix_cache_mb``): on top of chunked
prefill, admission consults a radix cache of chunk-boundary state
snapshots (``serve/prefix_cache.py``): the longest cached prefix of the
staged (padded) stream seeds the staging row — the snapshot scatters into
the row via the same jitted row ops as slot turnover — and chunking
resumes from the matched offset, inserting snapshots of new boundaries on
the way.  Still zero extra compiled programs in the steady state: the
chunk program is offset-vectorized already, and snapshot gather/scatter
are the pool's compile-once row ops.
"""
from __future__ import annotations

import logging
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.elastic import backoff_delay_s
from repro.runtime.faults import as_injector
from repro.runtime.health import StepMonitor, Watchdog
from repro.serve.engine import EngineBase, ServeConfig
from repro.serve.flight_recorder import FlightRecorder
from repro.serve.prefix_cache import PrefixCache, chunk_key
from repro.serve.program_registry import budget_for
from repro.serve.scheduler import Request, bucket_for, chunk_span
from repro.serve.speculative import accept_lengths, emit_counts, \
    needs_rollback
from repro.serve.state_pool import (StatePool, format_compile_count,
                                    jit_cache_size)
from repro.serve.tracing import (TID_HOST, TID_QUEUE, TID_SLOT0,
                                 RecompileSentinel)

log = logging.getLogger("repro.serve")

# Backend degradation ladder (docs/robustness.md): on a compiled-call
# failure the engine rebuilds the model one decode mode down and retries.
# Every xamba decode mode shares one cache layout (``init_cache`` is
# mode-independent), so the live pools survive the swap untouched.
_FALLBACK_NEXT = {"pallas": "cumba", "pallas_interpret": "cumba",
                  "cumba": "naive"}


class ContinuousEngine(EngineBase):
    """Slot-scheduled serving over a shared per-slot state pool."""

    def __init__(self, model, params, cfg: ServeConfig, *,
                 draft_params=None):
        super().__init__(model, params, cfg)
        self.slots = cfg.max_batch
        self.buckets = tuple(sorted(cfg.prefill_buckets))
        # Normalize "disabled" spellings (None and 0) to None so every
        # downstream gate can test `self.chunk` / `is None` consistently.
        self.chunk = cfg.prefill_chunk or None
        self.spec_k = int(getattr(cfg, "speculate_k", 0) or 0)
        if self.spec_k < 0:
            raise ValueError(f"speculate_k must be >= 0, got {self.spec_k}")
        # One static cache length covers every tenant a slot can host; with
        # chunked prefill the longest padded prompt can overshoot the
        # largest bucket by up to chunk-1 pad tokens.  A speculative burst
        # near the output budget can consume up to k tokens past the last
        # decode position, so attention-bearing caches get that headroom.
        max_prompt = (chunk_span(self.buckets, self.chunk, self.buckets[-1])
                      if self.chunk else self.buckets[-1])
        self.max_seq = max_prompt + cfg.max_new_tokens + \
            (self.spec_k + 1 if self.spec_k else 0)
        dtype = model.cfg.dtype
        self.pool = StatePool(model, self.slots, self.max_seq, dtype,
                              tracer=self.tracer)
        # Zeroed prefill input cache, reused by every admission (prefill is
        # functional; its output rows are scattered into the pool).
        self._scratch = model.init_cache(self.slots, self.max_seq, dtype)
        self.scheduler = self._scheduler
        self._slot_req: List[Optional[Request]] = [None] * self.slots
        self._pos = np.zeros(self.slots, np.int32)
        self._next_tok = np.full(self.slots, cfg.pad_id, np.int32)
        self._finished: List[Request] = []
        if self.spec_k:
            # Draft params: a w8 quantization of the serve params unless
            # the caller hands in its own pair (e.g. bf16 verify + w8
            # draft in the benchmarks).  Pre-sliced like the decode view
            # so the draft steps reuse the SAME decode program — the
            # quantized pytree is one extra trace of it, not a new
            # program shape.
            if draft_params is None:
                from repro.nn import quant
                draft_params = quant.quantize_params_for_mode(
                    params, getattr(cfg, "speculate_draft", "w8"))
            # Raw (un-sliced) draft pytree kept for backend-fallback
            # rebuilds: decode_view must re-derive from it, not from its
            # own output.
            self._raw_draft = draft_params
            self._draft_params = getattr(model, "decode_view",
                                         lambda p: p)(draft_params)
            # Two more arenas over the decode-pool layout: the draft
            # scratch rows (refreshed from live state each burst) and the
            # pre-burst backup rows rollback restores from.  All row
            # moves are the pools' compile-once scatters.
            self._dpool = StatePool(model, self.slots, self.max_seq, dtype,
                                    tracer=self.tracer)
            self._bpool = StatePool(model, self.slots, self.max_seq, dtype,
                                    tracer=self.tracer)
            self._verify = jax.jit(
                lambda p, toks, cache, off:
                model.verify_chunk(p, toks, cache, off),
                donate_argnums=(2,))
            self.sentinels["verify"] = RecompileSentinel(
                "verify", self._verify,
                strict=getattr(cfg, "strict_recompile", False))
            # Rolled-back rows park their already-emitted tokens here and
            # re-consume them through the ordinary decode step (one per
            # poll); bursts only run when every live row has drained.
            self._overflow: List[List[int]] = [[] for _ in range(self.slots)]
            # Pre-trace the burst programs at serve shapes on a throwaway
            # cache: the full-precision decode variant first runs at the
            # first rollback drain — possibly many polls in — and a lazy
            # first trace there would read as a post-warmup retrace.
            # Order matters for mixed-precision drafts (e.g. a w8 draft
            # with fp32 scales inside a bf16 serve stack): verify must be
            # traced on a SERVE-dtype cache (the live pool, which drafts
            # never touch), and the draft step on both cache dtypes it
            # will see — the serve-dtype arena it starts each burst from
            # and its own (possibly promoted) output dtype.  When draft
            # and serve dtypes agree the second draft call hits the
            # existing trace and compiles nothing.
            tok = jnp.zeros((self.slots, 1), jnp.int32)
            pos = jnp.zeros(self.slots, jnp.int32)
            tmp = model.init_cache(self.slots, self.max_seq, dtype)
            _, tmp = self._decode(self._decode_params, tok, tmp, pos)
            _, tmp = self._verify(
                self.params, jnp.zeros((self.slots, self.spec_k), jnp.int32),
                tmp, pos)
            _, tmp = self._decode(self._draft_params, tok, tmp, pos)
            self._decode(self._draft_params, tok, tmp, pos)
        if self.chunk:
            # Chunk-prefill state accumulates in a SECOND pool (one row per
            # slot, donated into the chunk program) until the prompt is
            # fully consumed, then the row is scattered into the decode
            # pool.  Slot i prefills in row i: a request reserves its
            # decode slot at admission, so prefill work can never outrun
            # decode capacity.
            self._ppool = StatePool(model, self.slots, self.max_seq, dtype,
                                    tracer=self.tracer)
            self._chunk_step = jax.jit(
                lambda p, toks, cache, off:
                model.prefill_chunk(p, toks, cache, off),
                donate_argnums=(2,))
            self.sentinels["prefill_chunk"] = RecompileSentinel(
                "prefill_chunk", self._chunk_step,
                strict=getattr(cfg, "strict_recompile", False))
            self._pref_req: List[Optional[Request]] = [None] * self.slots
            self._pref_toks: List[Optional[np.ndarray]] = [None] * self.slots
            self._pref_off = np.zeros(self.slots, np.int32)
        self._pcache: Optional[PrefixCache] = None
        if cfg.prefix_cache_mb:
            if not self.chunk:
                raise ValueError(
                    "prefix_cache_mb requires chunked prefill: snapshots "
                    "live at chunk boundaries (set prefill_chunk)")
            grain = cfg.prefix_chunk or self.chunk
            if grain % self.chunk:
                raise ValueError(
                    f"prefix_chunk ({grain}) must be a multiple of "
                    f"prefill_chunk ({self.chunk}): snapshots are taken "
                    "between chunk program calls")
            self._pcache = PrefixCache(int(cfg.prefix_cache_mb * 2 ** 20),
                                       grain, tracer=self.tracer)
            # Per-slot trie walk state while staging: the chunk key of the
            # padded stream, the deepest visited node (the cursor new
            # snapshots attach under), the pins released when the request
            # leaves staging, and an insert gate that closes when the
            # byte budget refuses a node (children would dangle).
            self._pref_key: List[Optional[list]] = [None] * self.slots
            self._pref_node: List[Optional[object]] = [None] * self.slots
            self._pref_pins: List[list] = [[] for _ in range(self.slots)]
            self._pref_insert_ok = [True] * self.slots
        # -- observability (docs/observability.md) --------------------------
        # Flight recorder: a bounded ring of the last-N completed-request
        # timelines, dumped to JSONL whenever a fault event fires
        # (quarantine / shed / retry / watchdog / backend fallback) —
        # created before the watchdog so its thread can always dump.
        self.flight: Optional[FlightRecorder] = None
        if getattr(cfg, "flight_records", 0):
            self.flight = FlightRecorder(cfg.flight_records,
                                         getattr(cfg, "flight_path", None))
        # Program registry: every compiled program above registers its
        # serve shapes (ShapeDtypeStructs only — card builds are lazy and
        # off the hot path) so ids thread through spans and sentinels.
        self._register_programs()
        # Host scheduling gaps: time between the end of one poll and the
        # start of the next (caller time + idle waits) gets its own trace
        # track so phase breakdowns account for ALL wall time.
        self._last_poll_end: Optional[float] = None
        # Step-time health: rolling-median straggler flags on decode and
        # prefill program calls (runtime/health.StepMonitor), plus an
        # optional deadline watchdog that fires when no compiled call
        # completes within cfg.watchdog_s (a hung device/compile).
        self.monitor_decode = StepMonitor()
        self.monitor_prefill = StepMonitor()
        self.monitor_spec = StepMonitor()
        self._watchdog: Optional[Watchdog] = None
        if getattr(cfg, "watchdog_s", 0.0):
            if getattr(cfg, "watchdog_action", "log") not in ("log",
                                                              "recover"):
                raise ValueError(
                    f"watchdog_action must be 'log' or 'recover', got "
                    f"{cfg.watchdog_action!r}")
            self._watchdog = Watchdog(cfg.watchdog_s, on_hang=self._on_hang)
        # -- fault tolerance (docs/robustness.md) ---------------------------
        probe = getattr(cfg, "poison_probe", "off") or "off"
        if probe not in ("off", "logits", "state"):
            raise ValueError(f"poison_probe must be off|logits|state, "
                             f"got {probe!r}")
        self._poison_probe = probe
        self._injector = as_injector(getattr(cfg, "fault_plan", None))
        self._poll_idx = 0          # engine poll clock (fault schedule base)
        self._overloaded = False    # degraded overload mode latch
        self._recover_pending = False   # watchdog asked for a recovery
        self._state_probe = None
        if probe == "state":
            self._state_probe = self._build_state_probe()
            # Warm the probe now so its one compile lands in construction,
            # not mid-serve (the pool cache is read, never donated).
            np.asarray(self._state_probe(self.pool.cache))

    @property
    def poll_index(self) -> int:
        """The engine's poll clock — fault-plan event polls are absolute,
        so chaos drivers arm plans relative to this after warmup."""
        return self._poll_idx

    def set_fault_plan(self, plan) -> None:
        """(Re)arm the fault injector mid-run: chaos harnesses warm the
        compiled programs fault-free, then schedule events at
        ``poll_index + k`` (None disarms)."""
        self._injector = as_injector(plan)

    def _on_hang(self) -> None:
        self.metrics.watchdog_fires += 1
        self.tracer.instant("watchdog_hang",
                            deadline_s=self.cfg.watchdog_s)
        log.error("serve watchdog: no compiled call completed within "
                  "%.1fs — engine may be hung", self.cfg.watchdog_s)
        if getattr(self, "flight", None) is not None:
            # Runs on the watchdog thread; the recorder only appends to
            # its file, which is safe from here.
            self.flight.record_fault("watchdog_hang",
                                     deadline_s=self.cfg.watchdog_s)
        if getattr(self.cfg, "watchdog_action", "log") == "recover":
            # The watchdog thread cannot abort a compiled call; it flags
            # the engine and the next poll() aborts the stuck burst and
            # requeues its requests (bounded retries + backoff).
            self._recover_pending = True

    def close(self) -> None:
        """Stop the hang watchdog thread (idempotent); asserts the thread
        actually joined so a leaked watchdog fails loudly in tests."""
        if self._watchdog is not None:
            wd = self._watchdog
            wd.stop()
            assert not wd.alive, "watchdog thread failed to join in close()"
            self._watchdog = None

    def reset_stats(self) -> None:
        # Fresh health baselines too: warmup steps include compiles, which
        # would pollute the rolling-median straggler threshold.  The
        # cleared poll stamp keeps the first post-warmup poll from
        # emitting a host_gap that spans the whole warmup.
        self.monitor_decode = StepMonitor()
        self.monitor_prefill = StepMonitor()
        self.monitor_spec = StepMonitor()
        self._last_poll_end = None
        super().reset_stats()

    # ------------------------------------------------------------------
    # program registry (docs/observability.md)
    # ------------------------------------------------------------------
    def _register_programs(self) -> None:
        """(Re)attach every compiled program this engine warms up to the
        registry at its serve shapes.  Cheap — ShapeDtypeStructs only, no
        compiles — and re-run by a backend rebuild so program cards
        always lower the jits currently serving.  Ids are stable across
        re-registration; sentinels pick up their program ids here so a
        recompile trip names the program, not just a span label."""
        reg = self.registry
        i32 = jnp.int32
        tok = jax.ShapeDtypeStruct((self.slots, 1), i32)
        pos = jax.ShapeDtypeStruct((self.slots,), i32)
        reg.register("decode", self._decode,
                     (self._decode_params, tok, self.pool.cache, pos),
                     budget=budget_for(self.model.cfg, "decode"))
        reg.register(
            "prefill", self._prefill,
            (self.params,
             {"tokens": jax.ShapeDtypeStruct((self.slots, self.buckets[-1]),
                                             i32)},
             self._scratch))
        if self.chunk:
            reg.register(
                "prefill_chunk", self._chunk_step,
                (self.params,
                 jax.ShapeDtypeStruct((self.slots, self.chunk), i32),
                 self._ppool.cache, pos))
        if self.spec_k:
            reg.register(
                "verify", self._verify,
                (self.params,
                 jax.ShapeDtypeStruct((self.slots, self.spec_k), i32),
                 self.pool.cache, pos))
            # The draft step is the decode program's second trace (the
            # quantized pytree) — its own card shows the int8 variant.
            reg.register("draft", self._decode,
                         (self._draft_params, tok, self.pool.cache, pos))
            # The W8 dequant-matmul the draft trace calls into, at a
            # representative (slots, d_model) x (d_model, d_model) shape.
            # On CPU the serving path is nn/quant.qdot's XLA variant
            # (dot_general on the int8 payload + per-channel scale); the
            # fused pallas kernel only lowers on accelerator backends.
            d = self.model.cfg.d_model
            qx = jax.ShapeDtypeStruct((self.slots, d), jnp.float32)
            qw = jax.ShapeDtypeStruct((d, d), jnp.int8)
            qs = jax.ShapeDtypeStruct((1, d), jnp.float32)
            if jax.default_backend() == "cpu":
                def _qmm(x, q, scale):
                    y = jax.lax.dot_general(
                        x, q, (((x.ndim - 1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)
                    return y * scale.reshape(-1)
                reg.register("qmatmul", jax.jit(_qmm), (qx, qw, qs))
            else:
                from repro.kernels import ops as kops
                reg.register("qmatmul", kops.qmatmul, (qx, qw, qs))
        # The decode pool's row ops (slot turnover, snapshot export /
        # import share the same compiled gather/scatter) build lazily on
        # first use — thunks resolve at card-build time.
        scalar = jax.ShapeDtypeStruct((), i32)
        pool = self.pool

        def pool_op(attr):
            def thunk():
                if getattr(pool, attr) is None:
                    pool._build_ops()
                return getattr(pool, attr)
            return thunk

        reg.register("pool_insert", fn_thunk=pool_op("_insert"),
                     example_args=(pool.cache, pool.cache, scalar, scalar))
        reg.register("pool_extract", fn_thunk=pool_op("_extract"),
                     example_args=(pool.cache, scalar))
        reg.register("pool_reset", fn_thunk=pool_op("_reset"),
                     example_args=(pool.cache, scalar))
        # Sentinels: existing ones learn their program id; the lazily
        # -built pool ops get inert-until-first-sight sentinels of their
        # own (fn_getter reads size -1 until the op exists).
        strict = getattr(self.cfg, "strict_recompile", False)
        for name, s in self.sentinels.items():
            if name in reg:
                s.program_id = reg.program_id(name)
        for name, attr in (("pool_insert", "_insert"),
                           ("pool_reset", "_reset")):
            if name not in self.sentinels:
                # NB: the sentinel getter must NOT force-build the ops
                # (pool_op does, for cards) — it just observes them.
                self.sentinels[name] = RecompileSentinel(
                    name, strict=strict,
                    fn_getter=lambda p=pool, a=attr: getattr(p, a),
                    program_id=reg.program_id(name))
        # Hot-path span args use these pre-resolved id strings — constant
        # string refs, not registry lookups, per compiled call.
        self._pid_decode = reg.program_id("decode")
        self._pid_prefill = reg.program_id("prefill")
        self._pid_chunk = (reg.program_id("prefill_chunk")
                           if self.chunk else None)
        self._pid_verify = (reg.program_id("verify")
                            if self.spec_k else None)
        self._pid_draft = (reg.program_id("draft")
                           if self.spec_k else None)

    def _observe_step(self, monitor: StepMonitor, kind: str,
                      dt_s: float) -> None:
        """Feed one compiled-call duration to its StepMonitor; surface
        straggler flags through metrics and the trace, pet the watchdog."""
        # step=None -> the monitor's cumulative count (its record list is
        # a trimmed rolling window, so len(records) is NOT the step index).
        rec = monitor.observe(None, dt_s)
        if rec.straggler:
            self.metrics.record_straggler(kind)
            self.tracer.instant(f"straggler_{kind}", seconds=dt_s)
        if self._watchdog is not None:
            self._watchdog.pet()

    # ------------------------------------------------------------------
    # fault tolerance (docs/robustness.md)
    # ------------------------------------------------------------------
    def _guarded_call(self, program: str, fn):
        """Run one compiled call behind the fault boundary: the injector's
        pre-call hook (stalls; simulated failures raise *before* the jit
        executes, so donated arenas stay intact) and the backend fallback
        chain.  ``fn`` must re-read the engine's program attributes
        (``self._decode`` etc.) so a retry picks up the rebuilt jits."""
        try:
            if self._injector is not None:
                self._injector.pre_call(program, self._poll_idx)
            return fn()
        except Exception as e:  # noqa: BLE001 — the fallback boundary
            if not self._try_fallback(program, e):
                raise
            return fn()

    def _try_fallback(self, program: str, err: Exception) -> bool:
        """Degrade one decode mode down the ladder and report whether a
        retry is worth attempting.  A real (non-injected) failure that
        already consumed a donated arena will fail its retry too — that
        re-raise is the honest outcome."""
        if not getattr(self.cfg, "backend_fallback", True):
            return False
        mode = self.model.cfg.xamba.decode
        nxt = _FALLBACK_NEXT.get(mode)
        if nxt is None:
            log.error("backend failure in %s with decode mode %r and no "
                      "fallback left: %s", program, mode, err)
            return False
        log.error("backend failure in %s (decode mode %r): %s — falling "
                  "back to %r", program, mode, err, nxt)
        self._rebuild_backend(nxt)
        self.metrics.record_backend_fallback()
        self.tracer.instant("backend_fallback", program=program,
                            from_mode=mode, to_mode=nxt, error=str(err))
        if self.flight is not None:
            self.flight.record_fault("backend_fallback", program=program,
                                     from_mode=mode, to_mode=nxt)
        return True

    def _rebuild_backend(self, mode: str) -> None:
        """Rebuild the model and every compiled program at decode mode
        ``mode``, then re-warm them all at serve shapes.  Cache layouts
        are identical across xamba decode modes, so the pools (and their
        compiled row ops) survive; the fresh jits get fresh sentinels
        armed over the re-warmup's traces — a fallback never reads as a
        post-warmup retrace."""
        from repro.models.registry import build_model
        model = build_model(self.model.cfg.with_decode_mode(mode))
        self.model = model
        strict = getattr(self.cfg, "strict_recompile", False)
        self._decode_params = getattr(model, "decode_view",
                                      lambda p: p)(self.params)
        self._prefill = jax.jit(
            lambda p, batch, cache: model.prefill(p, batch, cache))
        self._decode = jax.jit(
            lambda p, tok, cache, idx: model.decode_step(p, tok, cache, idx),
            donate_argnums=(2,))
        self.sentinels["decode"] = RecompileSentinel("decode", self._decode,
                                                     strict=strict)
        self.sentinels["prefill"] = RecompileSentinel("prefill",
                                                      self._prefill,
                                                      strict=strict)
        for pool in (self.pool,
                     getattr(self, "_ppool", None),
                     getattr(self, "_dpool", None),
                     getattr(self, "_bpool", None)):
            if pool is not None:
                pool.model = model  # snapshot export/import path
        if self.chunk:
            self._chunk_step = jax.jit(
                lambda p, toks, cache, off:
                model.prefill_chunk(p, toks, cache, off),
                donate_argnums=(2,))
            self.sentinels["prefill_chunk"] = RecompileSentinel(
                "prefill_chunk", self._chunk_step, strict=strict)
        if self.spec_k:
            self._draft_params = getattr(model, "decode_view",
                                         lambda p: p)(self._raw_draft)
            self._verify = jax.jit(
                lambda p, toks, cache, off:
                model.verify_chunk(p, toks, cache, off),
                donate_argnums=(2,))
            self.sentinels["verify"] = RecompileSentinel(
                "verify", self._verify, strict=strict)
        if self._state_probe is not None:
            self._state_probe = self._build_state_probe()
        # The rebuilt jits replace the registry's lowering recipes (same
        # ids — spans keep meaning the same program) and drop any cached
        # cards; the fresh sentinels re-learn their program ids.
        self._register_programs()
        # A rebuild is a new warmup: trace every rebuilt program at its
        # serve shapes NOW, on throwaway inputs, so all compiles land
        # inside the fallback event.  The sentinels arm lazily, but only
        # until the next poll's check — a program first *used* polls later
        # (e.g. prefill at the next admission) would otherwise read as a
        # post-warmup retrace.
        dtype = model.cfg.dtype
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        pos = jnp.zeros(self.slots, jnp.int32)
        tmp = model.init_cache(self.slots, self.max_seq, dtype)
        _, tmp = self._decode(self._decode_params, tok, tmp, pos)
        if self.chunk:
            ctmp = model.init_cache(self.slots, self.max_seq, dtype)
            self._chunk_step(self.params,
                             jnp.zeros((self.slots, self.chunk), jnp.int32),
                             ctmp, pos)
        else:
            # Monolithic prefill is functional (no donation): _scratch
            # rides through untouched, exactly like a real admission.
            for bucket in self.buckets:
                self._prefill(self.params,
                              {"tokens": jnp.full((self.slots, bucket),
                                                  self.cfg.pad_id,
                                                  jnp.int32)},
                              self._scratch)
        if self.spec_k:
            _, tmp = self._verify(
                self.params,
                jnp.zeros((self.slots, self.spec_k), jnp.int32), tmp, pos)
            _, tmp = self._decode(self._draft_params, tok, tmp, pos)
            self._decode(self._draft_params, tok, tmp, pos)

    def _build_state_probe(self):
        """Jitted all-rows finiteness probe over the decode pool: one
        ``bool[slots]`` gather per check (compiled once — the cache layout
        never changes).  Integer leaves are trivially finite but ride
        through the float32 cast rather than special-casing the tree."""
        slots = self.slots
        axes = self.pool.batch_axes

        def probe(cache):
            def leaf(x, ax):
                flat = jnp.moveaxis(x.astype(jnp.float32), ax, 0)
                flat = flat.reshape(slots, -1)
                return jnp.all(jnp.isfinite(flat), axis=1)
            flags = jax.tree.map(leaf, cache, axes)
            return jnp.all(jnp.stack(jax.tree.leaves(flags)), axis=0)

        return jax.jit(probe)

    def _quarantine(self, slot: int, now: float, where: str) -> None:
        """Contain a poisoned slot: zero its pool row (the compile-once
        reset scatter), finish its request with status ``poisoned`` (NOT
        counted as a completion), and free the slot.  Neighbour slots and
        the prefix cache are untouched."""
        req = self._slot_req[slot]
        self.pool.reset_rows([slot])
        self._pos[slot] = 0
        self._next_tok[slot] = self.cfg.pad_id
        if self.spec_k:
            self._overflow[slot] = []
        self._slot_req[slot] = None
        req.done = True
        req.status = "poisoned"
        req.finish_s = now
        req.latency_s = now - req.arrival_s
        self.metrics.record_quarantine()
        self.metrics.record_shed("poison")
        self.tracer.instant("quarantine", uid=req.uid, slot=slot,
                            where=where, tokens=len(req.out_tokens))
        if self.flight is not None:
            self.flight.record_request(req, slot=slot, status="poisoned")
            self.flight.record_fault("quarantine", uid=req.uid, slot=slot,
                                     where=where)
        log.error("request %d: non-finite %s output in slot %d — "
                  "quarantined (row reset, request shed)", req.uid, where,
                  slot)
        self._finished.append(req)

    def _probe_rows(self, live: List[int], host_logits: np.ndarray,
                    now: float, where: str) -> List[int]:
        """Poison probe over one step's live rows: NaN/Inf in the (already
        host-side) logits, plus — in ``state`` mode — the jitted per-row
        state finiteness probe.  Quarantines every hit; returns the
        quarantined slots."""
        if self._poison_probe == "off" or not live:
            return []
        every = max(1, getattr(self.cfg, "poison_check_every", 1))
        if self._poll_idx % every:
            return []
        self.metrics.record_poison_probe()
        lg = host_logits.reshape(host_logits.shape[0], -1)
        # One vectorized pass over the whole batch, then bail on the
        # all-finite common case: the probe runs every poll of every
        # hardened serve, and per-row np calls are ~5x the cost (a few %
        # of a reduced-model poll; BENCH_serve.json's probe_overhead arm
        # bounds the healthy-path total at 3%).
        row_ok = np.isfinite(lg).all(axis=1)
        if row_ok.all() and self._state_probe is None:
            return []
        bad = {i for i in live if not row_ok[i]}
        if self._state_probe is not None:
            finite = np.asarray(self._state_probe(self.pool.cache))
            bad.update(i for i in live if not finite[i])
        for i in sorted(bad):
            self._quarantine(i, now, where)
        return sorted(bad)

    def _inject_poison(self) -> None:
        """Apply due state-poison faults: corrupt the slot's row through
        the pool's host snapshot/restore pair (the fault path may be slow;
        the serving path must stay compile-once)."""
        live = [i for i, r in enumerate(self._slot_req) if r is not None]
        for slot, mode in self._injector.poison_targets(self._poll_idx,
                                                        live):
            snap = self.pool.clone_row(slot)
            self.pool.restore_row(slot, self._injector.corrupt(snap, mode))

    def _snapshot_finite(self, snap) -> bool:
        """Host-side finiteness gate for a prefix-cache snapshot."""
        for x in jax.tree.leaves(snap):
            a = np.asarray(x)
            if np.issubdtype(a.dtype, np.floating) and \
                    not np.isfinite(a.astype(np.float32)).all():
                return False
        return True

    def _update_overload(self) -> None:
        """Degraded-mode state machine (docs/robustness.md): enter when
        queue depth or cumulative TTFT p95 crosses its threshold; while
        degraded the prefill token budget collapses to one chunk per poll
        and speculative bursts pause.  Exit on queue depth alone, with
        hysteresis (``overload_clear_frac``) — TTFT p95 is cumulative and
        would latch forever."""
        cfg = self.cfg
        q_thresh = getattr(cfg, "overload_queue_depth", 0)
        t_thresh = getattr(cfg, "overload_ttft_p95_s", 0.0)
        if not q_thresh and not t_thresh:
            return
        depth = len(self.scheduler)
        if not self._overloaded:
            trip = bool(q_thresh and depth >= q_thresh) or bool(
                t_thresh and self.metrics.ttft.count and
                self.metrics.ttft.percentile(0.95) > t_thresh)
            if trip:
                self._overloaded = True
                self.metrics.record_overload(True)
                self.tracer.instant("overload_enter", queue_depth=depth)
                log.warning("overload: entering degraded mode (queue "
                            "depth %d) — prefill budget 0, speculation "
                            "paused", depth)
        else:
            clear_at = (getattr(cfg, "overload_clear_frac", 0.5) * q_thresh
                        if q_thresh else 0)
            if depth <= clear_at:
                self._overloaded = False
                self.metrics.record_overload(False)
                self.tracer.instant("overload_exit", queue_depth=depth)
                log.info("overload cleared (queue depth %d): restoring "
                         "prefill budget and speculation", depth)

    def _shed_inflight(self, now: float) -> None:
        """Deadline shedding for requests already past admission: decoding
        tenants and staged (prefilling) rows whose SLA has passed free
        their capacity for work that can still meet its deadline."""
        for i, req in enumerate(self._slot_req):
            if req is None or req.deadline_s is None or \
                    now <= req.deadline_s:
                continue
            self.pool.reset_rows([i])
            self._pos[i] = 0
            self._next_tok[i] = self.cfg.pad_id
            if self.spec_k:
                self._overflow[i] = []
            self._slot_req[i] = None
            self._shed_request(req, now, "deadline", "shed_deadline")
        if self.chunk:
            for i, req in enumerate(self._pref_req):
                if req is None or req.deadline_s is None or \
                        now <= req.deadline_s:
                    continue
                if self._pcache is not None:
                    self._prefix_release(i)
                self._pref_req[i] = None
                self._pref_toks[i] = None
                self._shed_request(req, now, "deadline", "shed_deadline")

    def _shed_request(self, req: Request, now: float, reason: str,
                      status: str) -> None:
        """Common in-flight shed bookkeeping (deadline / retry-exhausted):
        the request finishes unsuccessfully and lands in both
        ``_finished`` (the caller sees it) and ``scheduler.expired``."""
        req.done = True
        req.expired = True
        req.status = status
        req.finish_s = now
        req.latency_s = now - req.arrival_s
        self.metrics.record_shed(reason)
        self.scheduler.expired.append(req)
        self.tracer.instant("shed", uid=req.uid, reason=reason,
                            inflight=True)
        if self.flight is not None:
            self.flight.record_request(req, status=status)
            self.flight.record_fault("shed", uid=req.uid, reason=reason)
        log.warning("request %d: shed in flight (%s)", req.uid, reason)
        self._finished.append(req)

    def _watchdog_recover(self, now: float) -> None:
        """Engine-level hang recovery (``watchdog_action="recover"``):
        abort every in-flight tenant and staged row, requeue each with a
        bounded retry budget and exponential backoff, and reset their
        rows.  Requeued requests restart from scratch — keyed sampling
        makes the replayed stream identical, so a recovered request's
        final output matches an undisturbed run."""
        self._recover_pending = False
        requeued = 0
        for i, req in enumerate(self._slot_req):
            if req is None:
                continue
            self.pool.reset_rows([i])
            self._pos[i] = 0
            self._next_tok[i] = self.cfg.pad_id
            if self.spec_k:
                self._overflow[i] = []
            self._slot_req[i] = None
            requeued += self._retry_or_shed(req, now)
        if self.chunk:
            for i, req in enumerate(self._pref_req):
                if req is None:
                    continue
                if self._pcache is not None:
                    self._prefix_release(i)
                self._pref_req[i] = None
                self._pref_toks[i] = None
                requeued += self._retry_or_shed(req, now)
        self.metrics.record_watchdog_recovery(requeued)
        self.tracer.instant("watchdog_recover", requeued=requeued)
        if self.flight is not None:
            self.flight.record_fault("watchdog_recover", requeued=requeued)
        log.error("watchdog recovery: aborted stuck burst, requeued %d "
                  "request(s)", requeued)

    def _retry_or_shed(self, req: Request, now: float) -> int:
        """Requeue an aborted request (1) or shed it (0) once its retry
        budget is exhausted.  A retried request restarts clean: emitted
        tokens are discarded (streaming callbacks will re-emit them) and
        admission defers by the shared exponential-backoff curve."""
        req.retries += 1
        if req.retries > getattr(self.cfg, "max_retries", 1):
            self._shed_request(req, now, "retry_exhausted",
                               "retry_exhausted")
            return 0
        req.out_tokens.clear()
        req.done = False
        req.first_token_s = None
        req.decode_pc = None
        req.admit_pc = None
        base = getattr(self.cfg, "retry_backoff_s", 0.0)
        req.not_before_s = (now + backoff_delay_s(req.retries, base)
                            if base else None)
        self.tracer.instant("retry", uid=req.uid, attempt=req.retries)
        if self.flight is not None:
            self.flight.record_fault("retry", uid=req.uid,
                                     attempt=req.retries)
        self.scheduler.submit(req)
        return 1

    def _snapshot_extra(self) -> dict:
        """Engine-side facts folded into each periodic metrics snapshot."""
        out = {"monitor_decode": self.monitor_decode.summary(),
               "monitor_prefill": self.monitor_prefill.summary(),
               "recompile_trips": {name: s.trips
                                   for name, s in self.sentinels.items()}}
        if self.spec_k:
            out["monitor_spec"] = self.monitor_spec.summary()
        if self._pcache is not None:
            out["prefix_cache"] = self._pcache.stats()
        if self._injector is not None:
            out["fault_injector"] = self._injector.summary()
        out["overloaded"] = self._overloaded
        return out

    def _buckets(self):
        return self.buckets

    @property
    def busy(self) -> bool:
        return (len(self.scheduler) > 0 or
                any(r is not None for r in self._slot_req) or
                (self.chunk is not None and
                 any(r is not None for r in self._pref_req)))

    @property
    def counters(self) -> dict:
        out = {**super().counters,
               **{f"pool_{k}_compiles": v
                  for k, v in self.pool.compile_counts().items()}}
        if self.chunk:
            out["prefill_chunk_compiles"] = format_compile_count(
                jit_cache_size(self._chunk_step))
            out.update({f"ppool_{k}_compiles": v
                        for k, v in self._ppool.compile_counts().items()})
        if self.spec_k:
            out["verify_compiles"] = format_compile_count(
                jit_cache_size(self._verify))
            out.update({f"dpool_{k}_compiles": v
                        for k, v in self._dpool.compile_counts().items()})
        if self._pcache is not None:
            out["prefix_cache"] = self._pcache.stats()
        return out

    @property
    def prefix_cache(self) -> Optional[PrefixCache]:
        """The prefix-state cache (None unless ``prefix_cache_mb`` set)."""
        return self._pcache

    # ------------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slot_req)
                if r is None and
                (self.chunk is None or self._pref_req[i] is None)]

    def _finish(self, req: Request, now: float, slot: int) -> None:
        req.done = True
        req.finish_s = now
        req.latency_s = now - req.arrival_s
        self.metrics.record_finish(req.latency_s, len(req.out_tokens))
        if self.flight is not None:
            self.flight.record_request(req, slot=slot,
                                       status=getattr(req, "status", "ok"))
        if self.tracer.enabled:
            if req.decode_pc is not None:
                self.tracer.complete("decode", req.decode_pc,
                                     time.perf_counter(),
                                     tid=TID_SLOT0 + slot, uid=req.uid,
                                     tokens=len(req.out_tokens))
            self.tracer.instant("finish", uid=req.uid,
                                tokens=len(req.out_tokens),
                                latency_s=req.latency_s)
        self._finished.append(req)

    def _start_tenant(self, slot: int, req: Request, span: int, tok: int,
                      t_first: float) -> None:
        """Request-start semantics shared by both admission paths
        (monolithic ``_admit`` and chunked ``_prefill_step``): clamp the
        output budget to the slot's remaining cache, stamp first-token
        metrics, emit, and either finish immediately (EOS on the prefill
        token / 1-token budget — the request never occupies a decode
        step, the slot stays free) or install the request as the slot's
        decoding tenant at position ``span``."""
        cfg = self.cfg
        budget = max(1, min(req.max_new_tokens, self.max_seq - span))
        if budget < req.max_new_tokens:
            log.warning(
                "request %d: max_new_tokens %d exceeds slot budget; "
                "clamping to %d", req.uid, req.max_new_tokens, budget)
            req.max_new_tokens = budget
        req.first_token_s = t_first
        t_first_pc = time.perf_counter()
        if self.tracer.enabled and req.admit_pc is not None:
            # Per-slot staging residency: queue pop -> first token (covers
            # all the request's prefill chunks and the waits between them).
            self.tracer.complete("staging", req.admit_pc, t_first_pc,
                                 tid=TID_SLOT0 + slot, uid=req.uid,
                                 span=span)
        self.metrics.record_first_token(t_first - req.arrival_s)
        self.metrics.record_token()
        req.emit(tok)
        if (cfg.eos_id >= 0 and tok == cfg.eos_id) or \
                len(req.out_tokens) >= req.max_new_tokens:
            self._finish(req, t_first, slot)
        else:
            req.decode_pc = t_first_pc
            self._slot_req[slot] = req
            self._pos[slot] = span
            self._next_tok[slot] = tok

    def _admit(self, now: float) -> int:
        """Fill free slots from the queue; returns requests admitted."""
        cfg = self.cfg
        free = self._free_slots()
        n_shed0 = len(self.scheduler.expired)
        batch = []
        while free and len(self.scheduler):
            req = self.scheduler.pop_ready(now)
            if req is None:
                break
            req.admit_pc = time.perf_counter()
            if self.tracer.enabled:
                self.tracer.complete(
                    "queue", self.tracer.pc_from_walltime(req.arrival_s),
                    req.admit_pc, tid=TID_QUEUE, uid=req.uid)
            batch.append((free.pop(0), req))
        for _ in range(len(self.scheduler.expired) - n_shed0):
            self.metrics.record_shed()
        if not batch:
            return 0

        groups = {}
        for slot, req in batch:
            b, _ = bucket_for(self.buckets, len(req.prompt))
            groups.setdefault(b, []).append((slot, req))

        for bucket, group in groups.items():
            tokens = np.full((self.slots, bucket), cfg.pad_id, np.int32)
            for row, (_, req) in enumerate(group):
                p = req.prompt[-bucket:]
                tokens[row, bucket - len(p):] = p
            t0 = time.perf_counter()
            toks_dev = jnp.asarray(tokens)
            logits, cache = self._guarded_call(
                "prefill",
                lambda: self._prefill(self.params, {"tokens": toks_dev},
                                      self._scratch))
            # First tokens sample at position = bucket (tokens consumed so
            # far), keyed per owning request — see _sample_rows.
            uids = np.zeros(self.slots, np.int64)
            for row, (_, req) in enumerate(group):
                uids[row] = req.uid
            first = self._sample_rows(logits, uids,
                                      np.full(self.slots, bucket, np.int64))
            t1 = time.perf_counter()
            self.tracer.complete("prefill_bucket", t0, t1, bucket=bucket,
                                 rows=len(group),
                                 tokens=bucket * len(group),
                                 program=self._pid_prefill)
            self._observe_step(self.monitor_prefill, "prefill", t1 - t0)
            self.metrics.record_prefill(bucket * len(group), t1 - t0)
            self.pool.insert_rows(cache,
                                  [row for row in range(len(group))],
                                  [slot for slot, _ in group])
            t_first = time.time()
            for row, (slot, req) in enumerate(group):
                req.bucket = bucket
                self._start_tenant(slot, req, bucket, int(first[row]),
                                   t_first)
        return len(batch)

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------
    def _admit_chunked(self, now: float) -> int:
        """Reserve free slots for queued requests and stage their padded
        prompts for chunk-wise prefill.  No model work happens here — the
        chunks run in ``_prefill_step`` under the poll's token budget."""
        cfg = self.cfg
        free = self._free_slots()
        n_shed0 = len(self.scheduler.expired)
        admitted = 0
        while free and len(self.scheduler):
            req = self.scheduler.pop_ready(now)
            if req is None:
                break
            req.admit_pc = time.perf_counter()
            if self.tracer.enabled:
                self.tracer.complete(
                    "queue", self.tracer.pc_from_walltime(req.arrival_s),
                    req.admit_pc, tid=TID_QUEUE, uid=req.uid)
            slot = free.pop(0)
            p = req.prompt[-self.buckets[-1]:]
            span = chunk_span(self.buckets, self.chunk, len(p))
            toks = np.full(span, cfg.pad_id, np.int32)
            toks[span - len(p):] = p
            req.bucket = span
            off = 0
            if self._pcache is not None:
                off = self._prefix_match(slot, toks, span)
            if not off:
                # The row's previous tenant left state behind; the chunk
                # program accumulates into the row, so it must start from
                # zero (a prefix-cache restore overwrites the whole row
                # instead — reset would be a wasted scatter).
                self._ppool.reset_rows([slot])
            self._pref_req[slot] = req
            self._pref_toks[slot] = toks
            self._pref_off[slot] = off
            admitted += 1
        for _ in range(len(self.scheduler.expired) - n_shed0):
            self.metrics.record_shed()
        return admitted

    # -- prefix-state cache -------------------------------------------------
    def _prefix_match(self, slot: int, toks: np.ndarray, span: int) -> int:
        """Longest-prefix lookup for a staged (padded) stream: restore the
        matched snapshot into the staging row and return the offset
        chunking resumes from (0 = miss).  The match is capped so at
        least one prefill chunk always runs — the final chunk's logits
        produce the request's first token."""
        cache = self._pcache
        with self.tracer.span("prefix_lookup") as sp:
            key = chunk_key(toks, cache.chunk)
            cap = max(0, (span - self.chunk) // cache.chunk)
            node, depth = cache.match(key, max_depth=cap)
            off = depth * cache.chunk
            sp.args["matched_tokens"] = off
        self.metrics.record_prefix_lookup(off)
        self._pref_key[slot] = key
        self._pref_node[slot] = node
        self._pref_pins[slot] = [node] if node is not None else []
        self._pref_insert_ok[slot] = True
        if node is not None:
            self._ppool.restore_row(slot, node.snapshot, index=off)
        return off

    def _prefix_insert(self, row: int) -> None:
        """After a chunk call: if the row crossed a snapshot boundary the
        cache hasn't seen, clone the staging row (jitted gather + host
        copy, off the donated arena) and attach it under the row's trie
        cursor.  A budget refusal closes the gate — deeper nodes would
        have no parent path."""
        cache = self._pcache
        off = int(self._pref_off[row])
        if off % cache.chunk or not self._pref_insert_ok[row]:
            return
        depth = off // cache.chunk
        key = self._pref_key[row]
        if depth > len(key):
            return
        nxt = cache.child(self._pref_node[row], key[depth - 1])
        if nxt is None:
            snap = self._ppool.clone_row(row, index=off)
            if self._injector is not None:
                fault = self._injector.snapshot_fault(self._poll_idx)
                if fault == "drop":
                    # Lost write: close the gate like a budget refusal —
                    # deeper nodes would have no parent path.
                    self._pref_insert_ok[row] = False
                    return
                if fault == "corrupt":
                    snap = self._injector.corrupt(snap)
            if self._poison_probe != "off" and \
                    not self._snapshot_finite(snap):
                # Poison gate: a corrupt snapshot must never enter the
                # cross-request cache — refuse it and stop attaching
                # deeper nodes for this request.
                self._pref_insert_ok[row] = False
                self.tracer.instant("snapshot_poison_refused", slot=row,
                                    offset=off)
                log.error("prefix snapshot at offset %d (row %d) is "
                          "non-finite — refused", off, row)
                return
            nxt = cache.insert(self._pref_node[row], key[depth - 1], snap)
            if nxt is None:
                self._pref_insert_ok[row] = False
                return
        self._pref_node[row] = nxt
        self._pref_pins[row].append(nxt)

    def _prefix_release(self, row: int) -> None:
        """Staging is over (first token sampled or request finished):
        unpin the row's trie path — its nodes become evictable again."""
        for node in self._pref_pins[row]:
            self._pcache.release(node)
        self._pref_pins[row] = []
        self._pref_node[row] = None
        self._pref_key[row] = None

    def _prefill_step(self) -> int:
        """Advance every prefilling slot by one chunk (one compiled call at
        ``(slots, chunk)`` + offset vector); finished prompts sample their
        first token and move their state rows into the decode pool.
        Returns prompt tokens advanced (0 when nothing is prefilling)."""
        cfg = self.cfg
        rows = [i for i, r in enumerate(self._pref_req) if r is not None]
        if not rows:
            return 0
        C = self.chunk
        tokens = np.full((self.slots, C), cfg.pad_id, np.int32)
        for i in rows:
            off = self._pref_off[i]
            tokens[i] = self._pref_toks[i][off:off + C]
        t0 = time.perf_counter()
        toks_dev = jnp.asarray(tokens)
        off_dev = jnp.asarray(self._pref_off)
        logits, self._ppool.cache = self._guarded_call(
            "prefill_chunk",
            lambda: self._chunk_step(self.params, toks_dev,
                                     self._ppool.cache, off_dev))
        # Synchronize before the host-side bookkeeping so the recorded
        # chunk time is the compiled call alone — snapshot exports and
        # sampling get their own spans (phase attribution stays honest).
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        self.tracer.complete("prefill_chunk", t0, t1, rows=len(rows),
                             tokens=C * len(rows),
                             program=self._pid_chunk)
        self._observe_step(self.monitor_prefill, "prefill", t1 - t0)
        self.metrics.record_prefill(C * len(rows), t1 - t0)
        done_rows = []
        for i in rows:
            self._pref_off[i] += C
            if self._pcache is not None:
                self._prefix_insert(i)
            if self._pref_off[i] >= len(self._pref_toks[i]):
                if self._pcache is not None:
                    self._prefix_release(i)
                done_rows.append(i)
        if done_rows and self._poison_probe != "off":
            # Gate the staging->decode handoff: a non-finite final-chunk
            # logits row means the staged state is poisoned — shed it here
            # so it never reaches the decode pool (or the prefix cache,
            # whose inserts are separately gated in _prefix_insert).
            lg = np.asarray(logits, np.float32)
            now_p = time.time()
            kept = []
            for i in done_rows:
                if np.isfinite(lg[i]).all():
                    kept.append(i)
                    continue
                req = self._pref_req[i]
                if self._pcache is not None:
                    self._prefix_release(i)
                self._pref_req[i] = None
                self._pref_toks[i] = None
                self._ppool.reset_rows([i])
                req.status = "poisoned"
                req.done = True
                req.finish_s = now_p
                req.latency_s = now_p - req.arrival_s
                self.metrics.record_quarantine()
                self.metrics.record_shed("poison")
                self.tracer.instant("quarantine", uid=req.uid, slot=i,
                                    where="prefill")
                if self.flight is not None:
                    self.flight.record_request(req, slot=i,
                                               status="poisoned")
                    self.flight.record_fault("quarantine", uid=req.uid,
                                             slot=i, where="prefill")
                log.error("request %d: non-finite prefill output in "
                          "staging row %d — quarantined", req.uid, i)
                self._finished.append(req)
            done_rows = kept
        if done_rows:
            uids = np.zeros(self.slots, np.int64)
            poss = np.zeros(self.slots, np.int64)
            for i in done_rows:
                uids[i] = self._pref_req[i].uid
                poss[i] = len(self._pref_toks[i])
            first = self._sample_rows(logits, uids, poss)
            # Row i prefilled in the second pool becomes slot i's decode
            # state (same index — the slot was reserved at admission).
            self.pool.insert_rows(self._ppool.cache, done_rows, done_rows)
            t_first = time.time()
            for i in done_rows:
                req = self._pref_req[i]
                span = len(self._pref_toks[i])
                self._pref_req[i] = None
                self._pref_toks[i] = None
                self._start_tenant(i, req, span, int(first[i]), t_first)
        return C * len(rows)

    # ------------------------------------------------------------------
    # self-speculative decoding
    # ------------------------------------------------------------------
    def _row_uids(self) -> List[int]:
        """Per-slot owning-request uids (0 for dead/staging rows — their
        sampled tokens are discarded anyway)."""
        return [r.uid if r is not None else 0 for r in self._slot_req]

    def _spec_burst(self, live: List[int]) -> None:
        """One speculative burst across the live slots (accept rule and
        notation: ``serve/speculative.py``): snapshot live rows, draft
        ``k`` tokens with the draft params on the scratch pool, verify
        all ``k`` in one chunk call on the decode pool, emit per-row
        ``min(m + 1, k)`` verify-stream tokens, restore rows that
        consumed a rejected draft and park their emitted tokens in the
        overflow queue for the decode-step drain."""
        cfg = self.cfg
        k = self.spec_k
        uids = self._row_uids()
        # Pre-burst snapshot + draft working copies: compile-once pool
        # row scatters, no host roundtrip.  Dead/staging rows are left
        # stale — the verify chunk advances them as garbage sinks and a
        # refill overwrites the whole row (same discipline as decode).
        with self.tracer.span("spec_copy", rows=len(live)):
            self._bpool.insert_rows(self.pool.cache, live, live)
            self._dpool.insert_rows(self.pool.cache, live, live)

        # Draft pass: k calls of the ordinary decode program (the
        # quantized pytree is a second trace of it, warmed up with
        # everything else), donating the scratch pool's arena.
        drafts = np.zeros((self.slots, k), np.int32)
        cur = self._next_tok.copy()
        t0 = time.perf_counter()
        for j in range(k):
            cur_dev = jnp.asarray(cur[:, None])
            posj_dev = jnp.asarray(self._pos + j)
            logits, self._dpool.cache = self._guarded_call(
                "draft",
                lambda: self._decode(self._draft_params, cur_dev,
                                     self._dpool.cache, posj_dev))
            cur = self._sample_rows(logits, uids, self._pos + j + 1)
            drafts[:, j] = cur
        t1 = time.perf_counter()
        self.tracer.complete("draft", t0, t1, rows=len(live), k=k,
                             tokens=k * len(live),
                             program=self._pid_draft)
        self._observe_step(self.monitor_spec, "draft", t1 - t0)

        # Verify pass: ONE chunk call over [t0, d_1 .. d_{k-1}], donating
        # the decode pool — rows that keep their window inherit the
        # post-chunk state for free.
        vtoks = np.empty((self.slots, k), np.int32)
        vtoks[:, 0] = self._next_tok
        if k > 1:
            vtoks[:, 1:] = drafts[:, :k - 1]
        t0 = time.perf_counter()
        vtoks_dev = jnp.asarray(vtoks)
        vpos_dev = jnp.asarray(self._pos)
        vlogits, self.pool.cache = self._guarded_call(
            "verify",
            lambda: self._verify(self.params, vtoks_dev, self.pool.cache,
                                 vpos_dev))
        vl = np.asarray(vlogits, np.float32)
        t1 = time.perf_counter()
        self.tracer.complete("verify", t0, t1, rows=len(live),
                             tokens=k * len(live),
                             program=self._pid_verify)
        self._observe_step(self.monitor_spec, "verify", t1 - t0)
        self.metrics.record_step(len(live), t1 - t0)

        # The verify stream: position j's token samples with the same
        # (uid, position) key the plain decode step would use there.
        verify = np.empty((self.slots, k), np.int32)
        for j in range(k):
            verify[:, j] = self._sample_rows(vl[:, j], uids,
                                             self._pos + j + 1)
        m = accept_lengths(drafts, verify)
        n_emit = emit_counts(m, k)
        rollback = needs_rollback(m, k)
        now = time.time()
        # Poison probe on the verify logits (+ state probe in "state"
        # mode): quarantined rows drop out of the emit loop below.
        self._probe_rows(live, vl, now, "verify")
        emitted_total = 0
        accepted = 0
        rollbacks = 0
        for i in live:
            req = self._slot_req[i]
            if req is None:         # quarantined by the probe above
                continue
            accepted += int(min(m[i], k))
            emitted: List[int] = []
            finished = False
            for j in range(int(n_emit[i])):
                tok = int(verify[i, j])
                req.emit(tok)
                emitted.append(tok)
                self.metrics.record_token()
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) or \
                        len(req.out_tokens) >= req.max_new_tokens:
                    self._finish(req, now, i)
                    self._slot_req[i] = None
                    finished = True
                    break
            emitted_total += len(emitted)
            if finished:
                self._overflow[i] = []
                continue
            if rollback[i]:
                rollbacks += 1
                with self.tracer.span("rollback", slot=i,
                                      accepted=int(m[i])):
                    self.pool.insert_rows(self._bpool.cache, [i], [i])
                # _pos / _next_tok stay pre-burst: the decode-step drain
                # re-consumes the emitted tokens from the restored state.
                self._overflow[i] = emitted
            else:
                # The verify chunk consumed exactly the emitted stream's
                # prefix — its output state IS the post-emission state.
                self._pos[i] = min(int(self._pos[i]) + k, self.max_seq - 1)
                self._next_tok[i] = int(verify[i, k - 1])
        self.metrics.record_speculative(
            rows=len(live), drafted=k * len(live), accepted=accepted,
            emitted=emitted_total, rollbacks=rollbacks)

    # ------------------------------------------------------------------
    def poll(self) -> List[Request]:
        """Admit waiting requests into free slots, then run one decode
        step across all slots; returns requests completed this poll.

        With ``prefill_chunk`` set, admission only *stages* prompts: each
        poll advances the prefilling slots by one chunk (or more, up to
        ``prefill_token_budget`` prompt tokens) before the decode step, so
        long prompts stream in next to the running decode batch instead of
        stalling it."""
        cfg = self.cfg
        done0 = len(self._finished)
        t_poll0 = time.perf_counter()
        if self.tracer.enabled and self._last_poll_end is not None:
            # Host scheduling gap: everything between polls (the caller's
            # arrival loop, sleeps, network...) on its own trace track.
            self.tracer.complete("host_gap", self._last_poll_end, t_poll0,
                                 tid=TID_HOST)
        poll_span = self.tracer.span("poll")
        poll_span.__enter__()
        now = time.time()
        # -- fault-tolerance pre-work (docs/robustness.md) ------------------
        self._poll_idx += 1
        if self._recover_pending:
            self._watchdog_recover(now)
        if self._injector is not None:
            self._inject_poison()
        if getattr(cfg, "shed_inflight", False):
            self._shed_inflight(now)
        self._update_overload()
        if self.chunk:
            with self.tracer.span("admit") as sp:
                sp.args["admitted"] = self._admit_chunked(now)
            spent = self._prefill_step()
            # Degraded overload mode collapses the budget: exactly one
            # chunk call per poll, protecting decode latency while the
            # queue drains.
            budget = 0 if self._overloaded else cfg.prefill_token_budget
            while spent and budget > spent:
                # A finished prefill may have freed nothing, but an
                # EOS-on-prefill finish frees its slot for the queue.
                with self.tracer.span("admit") as sp:
                    sp.args["admitted"] = self._admit_chunked(time.time())
                adv = self._prefill_step()
                if not adv:
                    break
                spent += adv
        else:
            # Re-admit until slots are full or the queue drains (a request
            # that EOS'd on its prefill token frees its slot immediately).
            while self._free_slots() and len(self.scheduler):
                with self.tracer.span("admit") as sp:
                    n_admitted = sp.args["admitted"] = self._admit(now)
                if not n_admitted:
                    break
                now = time.time()

        live = [i for i, r in enumerate(self._slot_req) if r is not None]
        if live and self.spec_k and not self._overloaded and \
                not any(self._overflow[i] for i in live):
            self._spec_burst(live)
        elif live:
            t0 = time.perf_counter()
            tok_dev = jnp.asarray(self._next_tok[:, None])
            pos_dev = jnp.asarray(self._pos)
            logits, cache = self._guarded_call(
                "decode",
                lambda: self._decode(self._decode_params, tok_dev,
                                     self.pool.cache, pos_dev))
            lg = np.asarray(logits, np.float32)
            nxt = self._sample_rows(lg, self._row_uids(), self._pos + 1)
            self.pool.cache = cache
            t1 = time.perf_counter()
            self.tracer.complete("decode_step", t0, t1, live=len(live),
                                 tokens=len(live),
                                 program=self._pid_decode)
            self._observe_step(self.monitor_decode, "decode", t1 - t0)
            self.metrics.record_step(len(live), t1 - t0)
            # Dead slots decode into a sink: their position pins to the last
            # cache column until a refill overwrites the whole row.
            self._pos = np.minimum(self._pos + 1, self.max_seq - 1)
            now = time.time()
            # Poison probe on this step's logits (+ state in "state"
            # mode): quarantined rows drop out of the emit loop.
            self._probe_rows(live, lg, now, "decode")
            for i in live:
                req = self._slot_req[i]
                if req is None:     # quarantined by the probe above
                    continue
                if self.spec_k and self._overflow[i]:
                    # Rollback drain: this step re-consumed a token the
                    # burst already emitted, re-advancing the restored
                    # state on the exact non-speculative trajectory; the
                    # freshly sampled token is discarded (once the queue
                    # empties, the next step recomputes it from
                    # bit-identical state).
                    self._next_tok[i] = self._overflow[i].pop(0)
                    continue
                tok = int(nxt[i])
                req.emit(tok)
                self.metrics.record_token()
                self._next_tok[i] = tok
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) or \
                        len(req.out_tokens) >= req.max_new_tokens:
                    self._finish(req, now, i)
                    self._slot_req[i] = None
        poll_span.__exit__(None, None, None)
        self._last_poll_end = time.perf_counter()
        self.check_sentinels()
        self.metrics.observe_gauges(
            queue_depth=len(self.scheduler),
            live_slots=len(live),
            overloaded=float(self._overloaded),
            staging_depth=(sum(r is not None for r in self._pref_req)
                           if self.chunk else 0),
            **({"prefix_resident_bytes": self._pcache.resident_bytes}
               if self._pcache is not None else {}))
        self.metrics.maybe_snapshot(self._snapshot_extra)
        return self._finished[done0:]

    def run(self) -> List[Request]:
        """Serve until queue and slots drain; returns completed requests."""
        t0 = time.perf_counter()
        done: List[Request] = []
        while self.busy:
            done.extend(self.poll())
        t1 = time.perf_counter()
        self.tracer.complete("serve.run", t0, t1)
        self.metrics.record_wall(t1 - t0)
        return done

    def stats(self, requests: Optional[List[Request]] = None) -> dict:
        del requests  # parity with Engine.stats; metrics already aggregate
        return self.metrics.summary()
