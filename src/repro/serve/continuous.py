"""Continuous-batching engine: slot-level refill under static shapes.

The wave engine decodes lockstep batches: one straggler request holds
every finished slot hostage, and queued requests wait for the whole wave
to drain.  This engine keeps ``max_batch`` persistent *slots* backed by a
:class:`~repro.serve.state_pool.StatePool`; the moment a slot's request
finishes (EOS / token budget), the scheduler admits the next queued
request into that slot mid-decode.

Compile-once discipline (the paper's Step-1 constraint) is preserved with
exactly three compiled programs (plus one prefill variant per bucket):

* **decode**  — ``(params, tok (slots,1), cache, pos (slots,))``; the
  position vector gives every slot its own offset, so freshly admitted
  requests decode next to old ones without recompiling.  Dead slots keep
  decoding into a sink row (static shapes, zero recompiles).  The pool's
  cache pytree is *donated* into the program: slot state updates in place
  every step — no per-step state copies, no fresh pytree allocations.
* **prefill** — per-bucket, always at batch ``slots`` (unused rows are
  padding): a refill of one slot reuses the same program as a full wave.
* **insert**  — the pool's row scatter moves a prefilled request's state
  (SSM state + conv tail / KV rows) into its slot; slot index is traced.

Position realignment: a request prefilled at bucket ``B`` starts decoding
at position ``B`` regardless of what its neighbours are doing — SSM rows
carry position in their state, attention rows take the per-row position
vector (RoPE + KV write + causal mask all realign per row).

Chunked prefill (``ServeConfig.prefill_chunk``): the monolithic per-bucket
prefill blocks every live slot for the whole prompt — a long prompt stalls
the decode wave and spikes the running requests' inter-token latency and
the queue's TTFT tail.  With a chunk size configured, admitted prompts
left-pad to a chunk multiple and advance **one chunk per poll** (per the
token budget), batched across all prefilling slots in a second state pool,
interleaved with the decode step.  That adds ONE more compiled program —
``prefill_chunk`` at ``(slots, chunk)`` with a per-row offset vector — so
the compile-once discipline still holds (0 decode recompiles after
warmup); ``models/base.py: DecodeAPI.prefill_chunk`` guarantees the result
is numerically the whole-sequence prefill.

Self-speculative decoding (``ServeConfig.speculate_k``): when every live
slot is caught up, a poll runs a *burst* instead of one decode step —
``k`` decode-program calls with the cheap draft params (w8 by default) on
a scratch copy of the slot states, then ONE ``verify_chunk`` call at
``(slots, k)`` with the full-precision params on the decode pool.  Each
row emits its longest verified prefix plus one correction token (accept
rule: ``serve/speculative.py``); rows whose window contained a rejected
draft restore their pre-burst snapshot (a compile-once pool row scatter —
O(1) state bytes, the SSM advantage) and re-consume their emitted tokens
through the ordinary decode program, one per poll, before the next burst.
That re-advance keeps rolled-back state bit-exact with the
non-speculative trajectory; emitted tokens are always the verify
stream's, so outputs match the non-speculative engine byte-for-byte
under greedy AND under keyed temperature sampling.  Three more compiled
programs (draft decode = the decode program retraced for the quantized
param pytree, ``verify``, and the two extra pools' row ops), all fixed
shape — compile-once discipline holds.

Prefix-state cache (``ServeConfig.prefix_cache_mb``): on top of chunked
prefill, admission consults a radix cache of chunk-boundary state
snapshots (``serve/prefix_cache.py``): the longest cached prefix of the
staged (padded) stream seeds the staging row — the snapshot scatters into
the row via the same jitted row ops as slot turnover — and chunking
resumes from the matched offset, inserting snapshots of new boundaries on
the way.  Still zero extra compiled programs in the steady state: the
chunk program is offset-vectorized already, and snapshot gather/scatter
are the pool's compile-once row ops.
"""
from __future__ import annotations

import logging
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.health import StepMonitor, Watchdog
from repro.serve.engine import EngineBase, ServeConfig
from repro.serve.prefix_cache import PrefixCache, chunk_key
from repro.serve.scheduler import Request, bucket_for, chunk_span
from repro.serve.speculative import accept_lengths, emit_counts, \
    needs_rollback
from repro.serve.state_pool import (StatePool, format_compile_count,
                                    jit_cache_size)
from repro.serve.tracing import (TID_HOST, TID_QUEUE, TID_SLOT0,
                                 RecompileSentinel)

log = logging.getLogger("repro.serve")


class ContinuousEngine(EngineBase):
    """Slot-scheduled serving over a shared per-slot state pool."""

    def __init__(self, model, params, cfg: ServeConfig, *,
                 draft_params=None):
        super().__init__(model, params, cfg)
        self.slots = cfg.max_batch
        self.buckets = tuple(sorted(cfg.prefill_buckets))
        # Normalize "disabled" spellings (None and 0) to None so every
        # downstream gate can test `self.chunk` / `is None` consistently.
        self.chunk = cfg.prefill_chunk or None
        self.spec_k = int(getattr(cfg, "speculate_k", 0) or 0)
        if self.spec_k < 0:
            raise ValueError(f"speculate_k must be >= 0, got {self.spec_k}")
        # One static cache length covers every tenant a slot can host; with
        # chunked prefill the longest padded prompt can overshoot the
        # largest bucket by up to chunk-1 pad tokens.  A speculative burst
        # near the output budget can consume up to k tokens past the last
        # decode position, so attention-bearing caches get that headroom.
        max_prompt = (chunk_span(self.buckets, self.chunk, self.buckets[-1])
                      if self.chunk else self.buckets[-1])
        self.max_seq = max_prompt + cfg.max_new_tokens + \
            (self.spec_k + 1 if self.spec_k else 0)
        dtype = model.cfg.dtype
        self.pool = StatePool(model, self.slots, self.max_seq, dtype,
                              tracer=self.tracer)
        # Zeroed prefill input cache, reused by every admission (prefill is
        # functional; its output rows are scattered into the pool).
        self._scratch = model.init_cache(self.slots, self.max_seq, dtype)
        self.scheduler = self._scheduler
        self._slot_req: List[Optional[Request]] = [None] * self.slots
        self._pos = np.zeros(self.slots, np.int32)
        self._next_tok = np.full(self.slots, cfg.pad_id, np.int32)
        self._finished: List[Request] = []
        if self.spec_k:
            # Draft params: a w8 quantization of the serve params unless
            # the caller hands in its own pair (e.g. bf16 verify + w8
            # draft in the benchmarks).  Pre-sliced like the decode view
            # so the draft steps reuse the SAME decode program — the
            # quantized pytree is one extra trace of it, not a new
            # program shape.
            if draft_params is None:
                from repro.nn import quant
                draft_params = quant.quantize_params_for_mode(
                    params, getattr(cfg, "speculate_draft", "w8"))
            self._draft_params = getattr(model, "decode_view",
                                         lambda p: p)(draft_params)
            # Two more arenas over the decode-pool layout: the draft
            # scratch rows (refreshed from live state each burst) and the
            # pre-burst backup rows rollback restores from.  All row
            # moves are the pools' compile-once scatters.
            self._dpool = StatePool(model, self.slots, self.max_seq, dtype,
                                    tracer=self.tracer)
            self._bpool = StatePool(model, self.slots, self.max_seq, dtype,
                                    tracer=self.tracer)
            self._verify = jax.jit(
                lambda p, toks, cache, off:
                model.verify_chunk(p, toks, cache, off),
                donate_argnums=(2,))
            self.sentinels["verify"] = RecompileSentinel(
                "verify", self._verify,
                strict=getattr(cfg, "strict_recompile", False))
            # Rolled-back rows park their already-emitted tokens here and
            # re-consume them through the ordinary decode step (one per
            # poll); bursts only run when every live row has drained.
            self._overflow: List[List[int]] = [[] for _ in range(self.slots)]
            # Pre-trace the burst programs at serve shapes on a throwaway
            # cache: the full-precision decode variant first runs at the
            # first rollback drain — possibly many polls in — and a lazy
            # first trace there would read as a post-warmup retrace.
            # Order matters for mixed-precision drafts (e.g. a w8 draft
            # with fp32 scales inside a bf16 serve stack): verify must be
            # traced on a SERVE-dtype cache (the live pool, which drafts
            # never touch), and the draft step on both cache dtypes it
            # will see — the serve-dtype arena it starts each burst from
            # and its own (possibly promoted) output dtype.  When draft
            # and serve dtypes agree the second draft call hits the
            # existing trace and compiles nothing.
            tok = jnp.zeros((self.slots, 1), jnp.int32)
            pos = jnp.zeros(self.slots, jnp.int32)
            tmp = model.init_cache(self.slots, self.max_seq, dtype)
            _, tmp = self._decode(self._decode_params, tok, tmp, pos)
            _, tmp = self._verify(
                self.params, jnp.zeros((self.slots, self.spec_k), jnp.int32),
                tmp, pos)
            _, tmp = self._decode(self._draft_params, tok, tmp, pos)
            self._decode(self._draft_params, tok, tmp, pos)
        if self.chunk:
            # Chunk-prefill state accumulates in a SECOND pool (one row per
            # slot, donated into the chunk program) until the prompt is
            # fully consumed, then the row is scattered into the decode
            # pool.  Slot i prefills in row i: a request reserves its
            # decode slot at admission, so prefill work can never outrun
            # decode capacity.
            self._ppool = StatePool(model, self.slots, self.max_seq, dtype,
                                    tracer=self.tracer)
            self._chunk_step = jax.jit(
                lambda p, toks, cache, off:
                model.prefill_chunk(p, toks, cache, off),
                donate_argnums=(2,))
            self.sentinels["prefill_chunk"] = RecompileSentinel(
                "prefill_chunk", self._chunk_step,
                strict=getattr(cfg, "strict_recompile", False))
            self._pref_req: List[Optional[Request]] = [None] * self.slots
            self._pref_toks: List[Optional[np.ndarray]] = [None] * self.slots
            self._pref_off = np.zeros(self.slots, np.int32)
        self._pcache: Optional[PrefixCache] = None
        if cfg.prefix_cache_mb:
            if not self.chunk:
                raise ValueError(
                    "prefix_cache_mb requires chunked prefill: snapshots "
                    "live at chunk boundaries (set prefill_chunk)")
            grain = cfg.prefix_chunk or self.chunk
            if grain % self.chunk:
                raise ValueError(
                    f"prefix_chunk ({grain}) must be a multiple of "
                    f"prefill_chunk ({self.chunk}): snapshots are taken "
                    "between chunk program calls")
            self._pcache = PrefixCache(int(cfg.prefix_cache_mb * 2 ** 20),
                                       grain, tracer=self.tracer)
            # Per-slot trie walk state while staging: the chunk key of the
            # padded stream, the deepest visited node (the cursor new
            # snapshots attach under), the pins released when the request
            # leaves staging, and an insert gate that closes when the
            # byte budget refuses a node (children would dangle).
            self._pref_key: List[Optional[list]] = [None] * self.slots
            self._pref_node: List[Optional[object]] = [None] * self.slots
            self._pref_pins: List[list] = [[] for _ in range(self.slots)]
            self._pref_insert_ok = [True] * self.slots
        # -- observability (docs/observability.md) --------------------------
        # Host scheduling gaps: time between the end of one poll and the
        # start of the next (caller time + idle waits) gets its own trace
        # track so phase breakdowns account for ALL wall time.
        self._last_poll_end: Optional[float] = None
        # Step-time health: rolling-median straggler flags on decode and
        # prefill program calls (runtime/health.StepMonitor), plus an
        # optional deadline watchdog that fires when no compiled call
        # completes within cfg.watchdog_s (a hung device/compile).
        self.monitor_decode = StepMonitor()
        self.monitor_prefill = StepMonitor()
        self.monitor_spec = StepMonitor()
        self._watchdog: Optional[Watchdog] = None
        if getattr(cfg, "watchdog_s", 0.0):
            self._watchdog = Watchdog(cfg.watchdog_s, on_hang=self._on_hang)

    def _on_hang(self) -> None:
        self.metrics.watchdog_fires += 1
        self.tracer.instant("watchdog_hang",
                            deadline_s=self.cfg.watchdog_s)
        log.error("serve watchdog: no compiled call completed within "
                  "%.1fs — engine may be hung", self.cfg.watchdog_s)

    def close(self) -> None:
        """Stop the hang watchdog thread (idempotent)."""
        if self._watchdog is not None:
            self._watchdog.stop()
            self._watchdog = None

    def reset_stats(self) -> None:
        # Fresh health baselines too: warmup steps include compiles, which
        # would pollute the rolling-median straggler threshold.  The
        # cleared poll stamp keeps the first post-warmup poll from
        # emitting a host_gap that spans the whole warmup.
        self.monitor_decode = StepMonitor()
        self.monitor_prefill = StepMonitor()
        self.monitor_spec = StepMonitor()
        self._last_poll_end = None
        super().reset_stats()

    def _observe_step(self, monitor: StepMonitor, kind: str,
                      dt_s: float) -> None:
        """Feed one compiled-call duration to its StepMonitor; surface
        straggler flags through metrics and the trace, pet the watchdog."""
        rec = monitor.observe(len(monitor.records), dt_s)
        if rec.straggler:
            self.metrics.record_straggler(kind)
            self.tracer.instant(f"straggler_{kind}", seconds=dt_s)
        if self._watchdog is not None:
            self._watchdog.pet()

    def _snapshot_extra(self) -> dict:
        """Engine-side facts folded into each periodic metrics snapshot."""
        out = {"monitor_decode": self.monitor_decode.summary(),
               "monitor_prefill": self.monitor_prefill.summary(),
               "recompile_trips": {name: s.trips
                                   for name, s in self.sentinels.items()}}
        if self.spec_k:
            out["monitor_spec"] = self.monitor_spec.summary()
        if self._pcache is not None:
            out["prefix_cache"] = self._pcache.stats()
        return out

    def _buckets(self):
        return self.buckets

    @property
    def busy(self) -> bool:
        return (len(self.scheduler) > 0 or
                any(r is not None for r in self._slot_req) or
                (self.chunk is not None and
                 any(r is not None for r in self._pref_req)))

    @property
    def counters(self) -> dict:
        out = {**super().counters,
               **{f"pool_{k}_compiles": v
                  for k, v in self.pool.compile_counts().items()}}
        if self.chunk:
            out["prefill_chunk_compiles"] = format_compile_count(
                jit_cache_size(self._chunk_step))
            out.update({f"ppool_{k}_compiles": v
                        for k, v in self._ppool.compile_counts().items()})
        if self.spec_k:
            out["verify_compiles"] = format_compile_count(
                jit_cache_size(self._verify))
            out.update({f"dpool_{k}_compiles": v
                        for k, v in self._dpool.compile_counts().items()})
        if self._pcache is not None:
            out["prefix_cache"] = self._pcache.stats()
        return out

    @property
    def prefix_cache(self) -> Optional[PrefixCache]:
        """The prefix-state cache (None unless ``prefix_cache_mb`` set)."""
        return self._pcache

    # ------------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slot_req)
                if r is None and
                (self.chunk is None or self._pref_req[i] is None)]

    def _finish(self, req: Request, now: float, slot: int) -> None:
        req.done = True
        req.finish_s = now
        req.latency_s = now - req.arrival_s
        self.metrics.record_finish(req.latency_s, len(req.out_tokens))
        if self.tracer.enabled:
            if req.decode_pc is not None:
                self.tracer.complete("decode", req.decode_pc,
                                     time.perf_counter(),
                                     tid=TID_SLOT0 + slot, uid=req.uid,
                                     tokens=len(req.out_tokens))
            self.tracer.instant("finish", uid=req.uid,
                                tokens=len(req.out_tokens),
                                latency_s=req.latency_s)
        self._finished.append(req)

    def _start_tenant(self, slot: int, req: Request, span: int, tok: int,
                      t_first: float) -> None:
        """Request-start semantics shared by both admission paths
        (monolithic ``_admit`` and chunked ``_prefill_step``): clamp the
        output budget to the slot's remaining cache, stamp first-token
        metrics, emit, and either finish immediately (EOS on the prefill
        token / 1-token budget — the request never occupies a decode
        step, the slot stays free) or install the request as the slot's
        decoding tenant at position ``span``."""
        cfg = self.cfg
        budget = max(1, min(req.max_new_tokens, self.max_seq - span))
        if budget < req.max_new_tokens:
            log.warning(
                "request %d: max_new_tokens %d exceeds slot budget; "
                "clamping to %d", req.uid, req.max_new_tokens, budget)
            req.max_new_tokens = budget
        req.first_token_s = t_first
        t_first_pc = time.perf_counter()
        if self.tracer.enabled and req.admit_pc is not None:
            # Per-slot staging residency: queue pop -> first token (covers
            # all the request's prefill chunks and the waits between them).
            self.tracer.complete("staging", req.admit_pc, t_first_pc,
                                 tid=TID_SLOT0 + slot, uid=req.uid,
                                 span=span)
        self.metrics.record_first_token(t_first - req.arrival_s)
        self.metrics.record_token()
        req.emit(tok)
        if (cfg.eos_id >= 0 and tok == cfg.eos_id) or \
                len(req.out_tokens) >= req.max_new_tokens:
            self._finish(req, t_first, slot)
        else:
            req.decode_pc = t_first_pc
            self._slot_req[slot] = req
            self._pos[slot] = span
            self._next_tok[slot] = tok

    def _admit(self, now: float) -> int:
        """Fill free slots from the queue; returns requests admitted."""
        cfg = self.cfg
        free = self._free_slots()
        n_shed0 = len(self.scheduler.expired)
        batch = []
        while free and len(self.scheduler):
            req = self.scheduler.pop_ready(now)
            if req is None:
                break
            req.admit_pc = time.perf_counter()
            if self.tracer.enabled:
                self.tracer.complete(
                    "queue", self.tracer.pc_from_walltime(req.arrival_s),
                    req.admit_pc, tid=TID_QUEUE, uid=req.uid)
            batch.append((free.pop(0), req))
        for _ in range(len(self.scheduler.expired) - n_shed0):
            self.metrics.record_shed()
        if not batch:
            return 0

        groups = {}
        for slot, req in batch:
            b, _ = bucket_for(self.buckets, len(req.prompt))
            groups.setdefault(b, []).append((slot, req))

        for bucket, group in groups.items():
            tokens = np.full((self.slots, bucket), cfg.pad_id, np.int32)
            for row, (_, req) in enumerate(group):
                p = req.prompt[-bucket:]
                tokens[row, bucket - len(p):] = p
            t0 = time.perf_counter()
            logits, cache = self._prefill(
                self.params, {"tokens": jnp.asarray(tokens)}, self._scratch)
            # First tokens sample at position = bucket (tokens consumed so
            # far), keyed per owning request — see _sample_rows.
            uids = np.zeros(self.slots, np.int64)
            for row, (_, req) in enumerate(group):
                uids[row] = req.uid
            first = self._sample_rows(logits, uids,
                                      np.full(self.slots, bucket, np.int64))
            t1 = time.perf_counter()
            self.tracer.complete("prefill_bucket", t0, t1, bucket=bucket,
                                 rows=len(group))
            self._observe_step(self.monitor_prefill, "prefill", t1 - t0)
            self.metrics.record_prefill(bucket * len(group), t1 - t0)
            self.pool.insert_rows(cache,
                                  [row for row in range(len(group))],
                                  [slot for slot, _ in group])
            t_first = time.time()
            for row, (slot, req) in enumerate(group):
                req.bucket = bucket
                self._start_tenant(slot, req, bucket, int(first[row]),
                                   t_first)
        return len(batch)

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------
    def _admit_chunked(self, now: float) -> int:
        """Reserve free slots for queued requests and stage their padded
        prompts for chunk-wise prefill.  No model work happens here — the
        chunks run in ``_prefill_step`` under the poll's token budget."""
        cfg = self.cfg
        free = self._free_slots()
        n_shed0 = len(self.scheduler.expired)
        admitted = 0
        while free and len(self.scheduler):
            req = self.scheduler.pop_ready(now)
            if req is None:
                break
            req.admit_pc = time.perf_counter()
            if self.tracer.enabled:
                self.tracer.complete(
                    "queue", self.tracer.pc_from_walltime(req.arrival_s),
                    req.admit_pc, tid=TID_QUEUE, uid=req.uid)
            slot = free.pop(0)
            p = req.prompt[-self.buckets[-1]:]
            span = chunk_span(self.buckets, self.chunk, len(p))
            toks = np.full(span, cfg.pad_id, np.int32)
            toks[span - len(p):] = p
            req.bucket = span
            off = 0
            if self._pcache is not None:
                off = self._prefix_match(slot, toks, span)
            if not off:
                # The row's previous tenant left state behind; the chunk
                # program accumulates into the row, so it must start from
                # zero (a prefix-cache restore overwrites the whole row
                # instead — reset would be a wasted scatter).
                self._ppool.reset_rows([slot])
            self._pref_req[slot] = req
            self._pref_toks[slot] = toks
            self._pref_off[slot] = off
            admitted += 1
        for _ in range(len(self.scheduler.expired) - n_shed0):
            self.metrics.record_shed()
        return admitted

    # -- prefix-state cache -------------------------------------------------
    def _prefix_match(self, slot: int, toks: np.ndarray, span: int) -> int:
        """Longest-prefix lookup for a staged (padded) stream: restore the
        matched snapshot into the staging row and return the offset
        chunking resumes from (0 = miss).  The match is capped so at
        least one prefill chunk always runs — the final chunk's logits
        produce the request's first token."""
        cache = self._pcache
        with self.tracer.span("prefix_lookup") as sp:
            key = chunk_key(toks, cache.chunk)
            cap = max(0, (span - self.chunk) // cache.chunk)
            node, depth = cache.match(key, max_depth=cap)
            off = depth * cache.chunk
            sp.args["matched_tokens"] = off
        self.metrics.record_prefix_lookup(off)
        self._pref_key[slot] = key
        self._pref_node[slot] = node
        self._pref_pins[slot] = [node] if node is not None else []
        self._pref_insert_ok[slot] = True
        if node is not None:
            self._ppool.restore_row(slot, node.snapshot, index=off)
        return off

    def _prefix_insert(self, row: int) -> None:
        """After a chunk call: if the row crossed a snapshot boundary the
        cache hasn't seen, clone the staging row (jitted gather + host
        copy, off the donated arena) and attach it under the row's trie
        cursor.  A budget refusal closes the gate — deeper nodes would
        have no parent path."""
        cache = self._pcache
        off = int(self._pref_off[row])
        if off % cache.chunk or not self._pref_insert_ok[row]:
            return
        depth = off // cache.chunk
        key = self._pref_key[row]
        if depth > len(key):
            return
        nxt = cache.child(self._pref_node[row], key[depth - 1])
        if nxt is None:
            snap = self._ppool.clone_row(row, index=off)
            nxt = cache.insert(self._pref_node[row], key[depth - 1], snap)
            if nxt is None:
                self._pref_insert_ok[row] = False
                return
        self._pref_node[row] = nxt
        self._pref_pins[row].append(nxt)

    def _prefix_release(self, row: int) -> None:
        """Staging is over (first token sampled or request finished):
        unpin the row's trie path — its nodes become evictable again."""
        for node in self._pref_pins[row]:
            self._pcache.release(node)
        self._pref_pins[row] = []
        self._pref_node[row] = None
        self._pref_key[row] = None

    def _prefill_step(self) -> int:
        """Advance every prefilling slot by one chunk (one compiled call at
        ``(slots, chunk)`` + offset vector); finished prompts sample their
        first token and move their state rows into the decode pool.
        Returns prompt tokens advanced (0 when nothing is prefilling)."""
        cfg = self.cfg
        rows = [i for i, r in enumerate(self._pref_req) if r is not None]
        if not rows:
            return 0
        C = self.chunk
        tokens = np.full((self.slots, C), cfg.pad_id, np.int32)
        for i in rows:
            off = self._pref_off[i]
            tokens[i] = self._pref_toks[i][off:off + C]
        t0 = time.perf_counter()
        logits, self._ppool.cache = self._chunk_step(
            self.params, jnp.asarray(tokens), self._ppool.cache,
            jnp.asarray(self._pref_off))
        # Synchronize before the host-side bookkeeping so the recorded
        # chunk time is the compiled call alone — snapshot exports and
        # sampling get their own spans (phase attribution stays honest).
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        self.tracer.complete("prefill_chunk", t0, t1, rows=len(rows),
                             tokens=C * len(rows))
        self._observe_step(self.monitor_prefill, "prefill", t1 - t0)
        self.metrics.record_prefill(C * len(rows), t1 - t0)
        done_rows = []
        for i in rows:
            self._pref_off[i] += C
            if self._pcache is not None:
                self._prefix_insert(i)
            if self._pref_off[i] >= len(self._pref_toks[i]):
                if self._pcache is not None:
                    self._prefix_release(i)
                done_rows.append(i)
        if done_rows:
            uids = np.zeros(self.slots, np.int64)
            poss = np.zeros(self.slots, np.int64)
            for i in done_rows:
                uids[i] = self._pref_req[i].uid
                poss[i] = len(self._pref_toks[i])
            first = self._sample_rows(logits, uids, poss)
            # Row i prefilled in the second pool becomes slot i's decode
            # state (same index — the slot was reserved at admission).
            self.pool.insert_rows(self._ppool.cache, done_rows, done_rows)
            t_first = time.time()
            for i in done_rows:
                req = self._pref_req[i]
                span = len(self._pref_toks[i])
                self._pref_req[i] = None
                self._pref_toks[i] = None
                self._start_tenant(i, req, span, int(first[i]), t_first)
        return C * len(rows)

    # ------------------------------------------------------------------
    # self-speculative decoding
    # ------------------------------------------------------------------
    def _row_uids(self) -> List[int]:
        """Per-slot owning-request uids (0 for dead/staging rows — their
        sampled tokens are discarded anyway)."""
        return [r.uid if r is not None else 0 for r in self._slot_req]

    def _spec_burst(self, live: List[int]) -> None:
        """One speculative burst across the live slots (accept rule and
        notation: ``serve/speculative.py``): snapshot live rows, draft
        ``k`` tokens with the draft params on the scratch pool, verify
        all ``k`` in one chunk call on the decode pool, emit per-row
        ``min(m + 1, k)`` verify-stream tokens, restore rows that
        consumed a rejected draft and park their emitted tokens in the
        overflow queue for the decode-step drain."""
        cfg = self.cfg
        k = self.spec_k
        uids = self._row_uids()
        # Pre-burst snapshot + draft working copies: compile-once pool
        # row scatters, no host roundtrip.  Dead/staging rows are left
        # stale — the verify chunk advances them as garbage sinks and a
        # refill overwrites the whole row (same discipline as decode).
        with self.tracer.span("spec_copy", rows=len(live)):
            self._bpool.insert_rows(self.pool.cache, live, live)
            self._dpool.insert_rows(self.pool.cache, live, live)

        # Draft pass: k calls of the ordinary decode program (the
        # quantized pytree is a second trace of it, warmed up with
        # everything else), donating the scratch pool's arena.
        drafts = np.zeros((self.slots, k), np.int32)
        cur = self._next_tok.copy()
        t0 = time.perf_counter()
        for j in range(k):
            logits, self._dpool.cache = self._decode(
                self._draft_params, jnp.asarray(cur[:, None]),
                self._dpool.cache, jnp.asarray(self._pos + j))
            cur = self._sample_rows(logits, uids, self._pos + j + 1)
            drafts[:, j] = cur
        t1 = time.perf_counter()
        self.tracer.complete("draft", t0, t1, rows=len(live), k=k)
        self._observe_step(self.monitor_spec, "draft", t1 - t0)

        # Verify pass: ONE chunk call over [t0, d_1 .. d_{k-1}], donating
        # the decode pool — rows that keep their window inherit the
        # post-chunk state for free.
        vtoks = np.empty((self.slots, k), np.int32)
        vtoks[:, 0] = self._next_tok
        if k > 1:
            vtoks[:, 1:] = drafts[:, :k - 1]
        t0 = time.perf_counter()
        vlogits, self.pool.cache = self._verify(
            self.params, jnp.asarray(vtoks), self.pool.cache,
            jnp.asarray(self._pos))
        vl = np.asarray(vlogits, np.float32)
        t1 = time.perf_counter()
        self.tracer.complete("verify", t0, t1, rows=len(live),
                             tokens=k * len(live))
        self._observe_step(self.monitor_spec, "verify", t1 - t0)
        self.metrics.record_step(len(live), t1 - t0)

        # The verify stream: position j's token samples with the same
        # (uid, position) key the plain decode step would use there.
        verify = np.empty((self.slots, k), np.int32)
        for j in range(k):
            verify[:, j] = self._sample_rows(vl[:, j], uids,
                                             self._pos + j + 1)
        m = accept_lengths(drafts, verify)
        n_emit = emit_counts(m, k)
        rollback = needs_rollback(m, k)
        now = time.time()
        emitted_total = 0
        accepted = 0
        rollbacks = 0
        for i in live:
            req = self._slot_req[i]
            accepted += int(min(m[i], k))
            emitted: List[int] = []
            finished = False
            for j in range(int(n_emit[i])):
                tok = int(verify[i, j])
                req.emit(tok)
                emitted.append(tok)
                self.metrics.record_token()
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) or \
                        len(req.out_tokens) >= req.max_new_tokens:
                    self._finish(req, now, i)
                    self._slot_req[i] = None
                    finished = True
                    break
            emitted_total += len(emitted)
            if finished:
                self._overflow[i] = []
                continue
            if rollback[i]:
                rollbacks += 1
                with self.tracer.span("rollback", slot=i,
                                      accepted=int(m[i])):
                    self.pool.insert_rows(self._bpool.cache, [i], [i])
                # _pos / _next_tok stay pre-burst: the decode-step drain
                # re-consumes the emitted tokens from the restored state.
                self._overflow[i] = emitted
            else:
                # The verify chunk consumed exactly the emitted stream's
                # prefix — its output state IS the post-emission state.
                self._pos[i] = min(int(self._pos[i]) + k, self.max_seq - 1)
                self._next_tok[i] = int(verify[i, k - 1])
        self.metrics.record_speculative(
            rows=len(live), drafted=k * len(live), accepted=accepted,
            emitted=emitted_total, rollbacks=rollbacks)

    # ------------------------------------------------------------------
    def poll(self) -> List[Request]:
        """Admit waiting requests into free slots, then run one decode
        step across all slots; returns requests completed this poll.

        With ``prefill_chunk`` set, admission only *stages* prompts: each
        poll advances the prefilling slots by one chunk (or more, up to
        ``prefill_token_budget`` prompt tokens) before the decode step, so
        long prompts stream in next to the running decode batch instead of
        stalling it."""
        cfg = self.cfg
        done0 = len(self._finished)
        t_poll0 = time.perf_counter()
        if self.tracer.enabled and self._last_poll_end is not None:
            # Host scheduling gap: everything between polls (the caller's
            # arrival loop, sleeps, network...) on its own trace track.
            self.tracer.complete("host_gap", self._last_poll_end, t_poll0,
                                 tid=TID_HOST)
        poll_span = self.tracer.span("poll")
        poll_span.__enter__()
        now = time.time()
        if self.chunk:
            with self.tracer.span("admit") as sp:
                sp.args["admitted"] = self._admit_chunked(now)
            spent = self._prefill_step()
            budget = cfg.prefill_token_budget
            while spent and budget > spent:
                # A finished prefill may have freed nothing, but an
                # EOS-on-prefill finish frees its slot for the queue.
                with self.tracer.span("admit") as sp:
                    sp.args["admitted"] = self._admit_chunked(time.time())
                adv = self._prefill_step()
                if not adv:
                    break
                spent += adv
        else:
            # Re-admit until slots are full or the queue drains (a request
            # that EOS'd on its prefill token frees its slot immediately).
            while self._free_slots() and len(self.scheduler):
                with self.tracer.span("admit") as sp:
                    n_admitted = sp.args["admitted"] = self._admit(now)
                if not n_admitted:
                    break
                now = time.time()

        live = [i for i, r in enumerate(self._slot_req) if r is not None]
        if live and self.spec_k and \
                not any(self._overflow[i] for i in live):
            self._spec_burst(live)
        elif live:
            t0 = time.perf_counter()
            logits, cache = self._decode(
                self._decode_params, jnp.asarray(self._next_tok[:, None]),
                self.pool.cache, jnp.asarray(self._pos))
            nxt = self._sample_rows(logits, self._row_uids(), self._pos + 1)
            self.pool.cache = cache
            t1 = time.perf_counter()
            self.tracer.complete("decode_step", t0, t1, live=len(live))
            self._observe_step(self.monitor_decode, "decode", t1 - t0)
            self.metrics.record_step(len(live), t1 - t0)
            # Dead slots decode into a sink: their position pins to the last
            # cache column until a refill overwrites the whole row.
            self._pos = np.minimum(self._pos + 1, self.max_seq - 1)
            now = time.time()
            for i in live:
                req = self._slot_req[i]
                if self.spec_k and self._overflow[i]:
                    # Rollback drain: this step re-consumed a token the
                    # burst already emitted, re-advancing the restored
                    # state on the exact non-speculative trajectory; the
                    # freshly sampled token is discarded (once the queue
                    # empties, the next step recomputes it from
                    # bit-identical state).
                    self._next_tok[i] = self._overflow[i].pop(0)
                    continue
                tok = int(nxt[i])
                req.emit(tok)
                self.metrics.record_token()
                self._next_tok[i] = tok
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) or \
                        len(req.out_tokens) >= req.max_new_tokens:
                    self._finish(req, now, i)
                    self._slot_req[i] = None
        poll_span.__exit__(None, None, None)
        self._last_poll_end = time.perf_counter()
        self.check_sentinels()
        self.metrics.observe_gauges(
            queue_depth=len(self.scheduler),
            live_slots=len(live),
            staging_depth=(sum(r is not None for r in self._pref_req)
                           if self.chunk else 0),
            **({"prefix_resident_bytes": self._pcache.resident_bytes}
               if self._pcache is not None else {}))
        self.metrics.maybe_snapshot(self._snapshot_extra)
        return self._finished[done0:]

    def run(self) -> List[Request]:
        """Serve until queue and slots drain; returns completed requests."""
        t0 = time.perf_counter()
        done: List[Request] = []
        while self.busy:
            done.extend(self.poll())
        t1 = time.perf_counter()
        self.tracer.complete("serve.run", t0, t1)
        self.metrics.record_wall(t1 - t0)
        return done

    def stats(self, requests: Optional[List[Request]] = None) -> dict:
        del requests  # parity with Engine.stats; metrics already aggregate
        return self.metrics.summary()
