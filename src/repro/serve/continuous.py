"""Continuous-batching engine: slot-level refill under static shapes.

The wave engine decodes lockstep batches: one straggler request holds
every finished slot hostage, and queued requests wait for the whole wave
to drain.  This engine keeps ``max_batch`` persistent *slots* backed by a
:class:`~repro.serve.state_pool.StatePool`; the moment a slot's request
finishes (EOS / token budget), the scheduler admits the next queued
request into that slot mid-decode.

Compile-once discipline (the paper's Step-1 constraint) is preserved with
exactly three compiled programs (plus one prefill variant per bucket):

* **decode**  — ``(params, tok (slots,1), cache, pos (slots,))``; the
  position vector gives every slot its own offset, so freshly admitted
  requests decode next to old ones without recompiling.  Dead slots keep
  decoding into a sink row (static shapes, zero recompiles).  The pool's
  cache pytree is *donated* into the program: slot state updates in place
  every step — no per-step state copies, no fresh pytree allocations.
* **prefill** — per-bucket, always at batch ``slots`` (unused rows are
  padding): a refill of one slot reuses the same program as a full wave.
* **insert**  — the pool's row scatter moves a prefilled request's state
  (SSM state + conv tail / KV rows) into its slot; slot index is traced.

Position realignment: a request prefilled at bucket ``B`` starts decoding
at position ``B`` regardless of what its neighbours are doing — SSM rows
carry position in their state, attention rows take the per-row position
vector (RoPE + KV write + causal mask all realign per row).
"""
from __future__ import annotations

import logging
import time
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.engine import EngineBase, ServeConfig
from repro.serve.scheduler import Request, bucket_for
from repro.serve.state_pool import StatePool

log = logging.getLogger("repro.serve")


class ContinuousEngine(EngineBase):
    """Slot-scheduled serving over a shared per-slot state pool."""

    def __init__(self, model, params, cfg: ServeConfig):
        super().__init__(model, params, cfg)
        self.slots = cfg.max_batch
        self.buckets = tuple(sorted(cfg.prefill_buckets))
        # One static cache length covers every tenant a slot can host.
        self.max_seq = self.buckets[-1] + cfg.max_new_tokens
        dtype = model.cfg.dtype
        self.pool = StatePool(model, self.slots, self.max_seq, dtype)
        # Zeroed prefill input cache, reused by every admission (prefill is
        # functional; its output rows are scattered into the pool).
        self._scratch = model.init_cache(self.slots, self.max_seq, dtype)
        self.scheduler = self._scheduler
        self._slot_req: List[Optional[Request]] = [None] * self.slots
        self._pos = np.zeros(self.slots, np.int32)
        self._next_tok = np.full(self.slots, cfg.pad_id, np.int32)
        self._finished: List[Request] = []

    def _buckets(self):
        return self.buckets

    @property
    def busy(self) -> bool:
        return (len(self.scheduler) > 0 or
                any(r is not None for r in self._slot_req))

    @property
    def counters(self) -> dict:
        return {**super().counters,
                **{f"pool_{k}_compiles": v
                   for k, v in self.pool.compile_counts().items()}}

    # ------------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slot_req) if r is None]

    def _finish(self, req: Request, now: float) -> None:
        req.done = True
        req.finish_s = now
        req.latency_s = now - req.arrival_s
        self.metrics.record_finish(req.latency_s, len(req.out_tokens))
        self._finished.append(req)

    def _admit(self, now: float) -> int:
        """Fill free slots from the queue; returns requests admitted."""
        cfg = self.cfg
        free = self._free_slots()
        n_shed0 = len(self.scheduler.expired)
        batch = []
        while free and len(self.scheduler):
            req = self.scheduler.pop_ready(now)
            if req is None:
                break
            batch.append((free.pop(0), req))
        for _ in range(len(self.scheduler.expired) - n_shed0):
            self.metrics.record_shed()
        if not batch:
            return 0

        groups = {}
        for slot, req in batch:
            b, _ = bucket_for(self.buckets, len(req.prompt))
            groups.setdefault(b, []).append((slot, req))

        for bucket, group in groups.items():
            tokens = np.full((self.slots, bucket), cfg.pad_id, np.int32)
            for row, (_, req) in enumerate(group):
                p = req.prompt[-bucket:]
                tokens[row, bucket - len(p):] = p
            logits, cache = self._prefill(
                self.params, {"tokens": jnp.asarray(tokens)}, self._scratch)
            first = self._sample(logits)
            self.pool.insert_rows(cache,
                                  [row for row in range(len(group))],
                                  [slot for slot, _ in group])
            t_first = time.time()
            for row, (slot, req) in enumerate(group):
                req.bucket = bucket
                budget = max(1, min(req.max_new_tokens,
                                    self.max_seq - bucket))
                if budget < req.max_new_tokens:
                    log.warning(
                        "request %d: max_new_tokens %d exceeds slot budget; "
                        "clamping to %d", req.uid, req.max_new_tokens, budget)
                    req.max_new_tokens = budget
                tok = int(first[row])
                req.first_token_s = t_first
                self.metrics.record_first_token(t_first - req.arrival_s)
                self.metrics.record_token()
                req.emit(tok)
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) or \
                        len(req.out_tokens) >= req.max_new_tokens:
                    # EOS on the prefill token (or a 1-token budget): the
                    # request never occupies a decode step; slot stays free.
                    self._finish(req, t_first)
                else:
                    self._slot_req[slot] = req
                    self._pos[slot] = bucket
                    self._next_tok[slot] = tok
        return len(batch)

    # ------------------------------------------------------------------
    def poll(self) -> List[Request]:
        """Admit waiting requests into free slots, then run one decode
        step across all slots; returns requests completed this poll."""
        cfg = self.cfg
        done0 = len(self._finished)
        now = time.time()
        # Re-admit until slots are full or the queue drains (a request that
        # EOS'd on its prefill token frees its slot immediately).
        while self._free_slots() and len(self.scheduler):
            if not self._admit(now):
                break
            now = time.time()

        live = [i for i, r in enumerate(self._slot_req) if r is not None]
        if live:
            t0 = time.perf_counter()
            logits, cache = self._decode(
                self._decode_params, jnp.asarray(self._next_tok[:, None]),
                self.pool.cache, jnp.asarray(self._pos))
            nxt = self._sample(logits)
            self.pool.cache = cache
            self.metrics.record_step(len(live), time.perf_counter() - t0)
            # Dead slots decode into a sink: their position pins to the last
            # cache column until a refill overwrites the whole row.
            self._pos = np.minimum(self._pos + 1, self.max_seq - 1)
            now = time.time()
            for i in live:
                req = self._slot_req[i]
                tok = int(nxt[i])
                req.emit(tok)
                self.metrics.record_token()
                self._next_tok[i] = tok
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) or \
                        len(req.out_tokens) >= req.max_new_tokens:
                    self._finish(req, now)
                    self._slot_req[i] = None
        return self._finished[done0:]

    def run(self) -> List[Request]:
        """Serve until queue and slots drain; returns completed requests."""
        t0 = time.perf_counter()
        done: List[Request] = []
        while self.busy:
            done.extend(self.poll())
        self.metrics.record_wall(time.perf_counter() - t0)
        return done

    def stats(self, requests: Optional[List[Request]] = None) -> dict:
        del requests  # parity with Engine.stats; metrics already aggregate
        return self.metrics.summary()
