"""Static-shape serving engine — the paper's Step-1 as a subsystem.

NPUs (and jit) require fixed shapes, so the paper enables SSMs with a
fixed-token prefill model (padding shorter inputs) plus a separate
cached-state decode model.  This engine generalizes that to every assigned
architecture:

* **Bucketed prefill**: prompts left-pad to the smallest configured bucket;
  one compiled prefill program per bucket (compile-once, reuse forever).
* **Wave decoding**: requests are grouped into fixed-size batches that
  decode in lockstep with a single compiled decode program; EOS'd rows keep
  decoding into a sink but stop being reported (static shapes, zero
  recompile).
* Caches are whatever the model family needs — KV ring buffers, SSM states,
  conv states — allocated once per wave.

Left-padding keeps every live request aligned at the same position index,
which is what lets SSM (position-free) and attention (position-indexed)
families share one engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    prefill_buckets: Sequence[int] = (32, 128, 512)
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stops early
    pad_id: int = 0
    temperature: float = 0.0    # 0 => greedy
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class Engine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(
            lambda p, batch, cache: model.prefill(p, batch, cache))
        self._decode = jax.jit(
            lambda p, tok, cache, idx: model.decode_step(p, tok, cache, idx))
        self._uid = 0
        self._queue: List[Request] = []
        self._rng = np.random.default_rng(cfg.seed)

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int],
               max_new_tokens: Optional[int] = None) -> int:
        self._uid += 1
        self._queue.append(Request(
            uid=self._uid, prompt=list(prompt),
            max_new_tokens=max_new_tokens or self.cfg.max_new_tokens))
        return self._uid

    def _bucket_for(self, length: int) -> int:
        for b in self.cfg.prefill_buckets:
            if length <= b:
                return b
        return self.cfg.prefill_buckets[-1]

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.cfg.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / self.cfg.temperature
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array([self._rng.choice(p.shape[-1], p=row)
                         for row in p], np.int32)

    # ------------------------------------------------------------------
    def run(self) -> List[Request]:
        """Serve everything in the queue; returns completed requests."""
        done: List[Request] = []
        while self._queue:
            wave = self._queue[:self.cfg.max_batch]
            self._queue = self._queue[self.cfg.max_batch:]
            done.extend(self._run_wave(wave))
        return done

    def _run_wave(self, wave: List[Request]) -> List[Request]:
        cfg = self.cfg
        t0 = time.time()
        b = cfg.max_batch
        longest = max(len(r.prompt) for r in wave)
        bucket = self._bucket_for(longest)
        max_new = max(r.max_new_tokens for r in wave)

        # Left-pad prompts into the bucket (static shape).
        tokens = np.full((b, bucket), cfg.pad_id, np.int32)
        for i, r in enumerate(wave):
            p = r.prompt[-bucket:]
            tokens[i, bucket - len(p):] = p

        cache = self.model.init_cache(b, bucket + max_new,
                                      self.model.cfg.dtype)
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)},
                                      cache)
        next_tok = self._sample(np.asarray(logits, np.float32))

        alive = np.array([True] * len(wave) + [False] * (b - len(wave)))
        for i, r in enumerate(wave):
            r.out_tokens.append(int(next_tok[i]))
            if cfg.eos_id >= 0 and next_tok[i] == cfg.eos_id:
                r.done = True
                alive[i] = False

        for t in range(1, max_new):
            tok = jnp.asarray(next_tok[:, None])
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(bucket + t - 1))
            next_tok = self._sample(np.asarray(logits, np.float32))
            for i, r in enumerate(wave):
                if alive[i] and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(next_tok[i]))
                    if cfg.eos_id >= 0 and next_tok[i] == cfg.eos_id:
                        alive[i] = False
                        r.done = True
            if not alive[:len(wave)].any():
                break

        dt = time.time() - t0
        for r in wave:
            r.done = True
            r.latency_s = dt
        return wave

    # ------------------------------------------------------------------
    def stats(self, requests: List[Request]) -> Dict[str, float]:
        toks = sum(len(r.out_tokens) for r in requests)
        wall = max(r.latency_s for r in requests) if requests else 0.0
        return {"requests": len(requests), "generated_tokens": toks,
                "tokens_per_s": toks / wall if wall else 0.0}
