"""Wave-mode serving engine — lockstep batches over the shared state pool.

NPUs (and jit) require fixed shapes, so the paper enables SSMs with a
fixed-token prefill model (padding shorter inputs) plus a separate
cached-state decode model.  The serve subsystem realizes that discipline
twice, over the same building blocks (``scheduler`` admission, ``sampling``,
``state_pool`` allocation, ``metrics``):

* **this module** — *wave* policy: requests are grouped into fixed-size
  batches that prefill together (bucketed, left-padded) and decode in
  lockstep; EOS'd rows keep decoding into a sink but stop being reported.
  Simple, but a straggler holds every finished slot until the wave drains.
* **``continuous``** — slot policy: finished slots are refilled from the
  queue mid-decode (see ``repro/serve/continuous.py``).

Left-padding keeps every live request in a wave aligned at the same
position index, which is what lets SSM (position-free) and attention
(position-indexed) families share one engine.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import sampling
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (Request, Scheduler, bucket_for,
                                   build_request)
from repro.serve.program_registry import ProgramRegistry
from repro.serve.state_pool import (StatePool, format_compile_count,
                                    jit_cache_size)
from repro.serve.tracing import (NULL_TRACER, TID_QUEUE, TID_SLOT0,
                                 RecompileSentinel, Tracer)

Array = jax.Array
log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    prefill_buckets: Sequence[int] = (32, 128, 512)
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stops early
    pad_id: int = 0
    temperature: float = 0.0    # 0 => greedy
    seed: int = 0
    policy: str = "fcfs"        # admission order: fcfs | priority
    # -- chunked prefill (continuous engine only) ---------------------------
    # Chunk size in tokens: prompts left-pad to a chunk multiple and prefill
    # one chunk per engine step, interleaved with the decode batch, instead
    # of running one monolithic bucketed prefill that stalls every live
    # slot.  None keeps the monolithic path.  The wave engine ignores it.
    prefill_chunk: Optional[int] = None
    # Max prefill tokens processed per poll, counted as chunk_size per
    # actively-prefilling slot per chunk call.  0 = exactly one chunk call
    # per poll (the lowest decode-latency jitter); larger budgets drain
    # long prompts faster at the cost of stalling decode for longer.
    prefill_token_budget: int = 0
    # -- prefix-state cache (continuous engine, needs prefill_chunk) --------
    # Host-byte budget (MB) for cross-request reuse of chunk-boundary
    # state snapshots: admissions skip past any cached prompt prefix
    # (``serve/prefix_cache.py``; docs/prefix_cache.md).  0 disables.
    prefix_cache_mb: float = 0.0
    # Snapshot granularity in tokens — must be a multiple of
    # prefill_chunk; None means one snapshot per prefill chunk.  Coarser
    # grains store fewer, larger entries (less snapshot overhead, less
    # sharing resolution).
    prefix_chunk: Optional[int] = None
    # -- self-speculative decoding (continuous engine only) -----------------
    # Draft this many tokens per burst with the cheap draft params (a w8
    # quantization of the serve params unless the engine is given one
    # explicitly), verify them in ONE batched full-precision verify_chunk
    # call, emit the longest verified prefix + one correction token, and
    # restore mismatching rows from their pre-burst state snapshot (O(1)
    # bytes for SSM families).  Greedy outputs are byte-identical to the
    # non-speculative path; sampled outputs too, because the continuous
    # engine keys sampling noise on (seed, uid, position) rather than the
    # step counter (``serve/sampling.py: sample_keyed``).  0 disables.
    # See serve/speculative.py and docs/serving.md.
    speculate_k: int = 0
    # Quant mode for the auto-derived draft params (``nn/quant.py``).
    speculate_draft: str = "w8"
    # -- observability (docs/observability.md) ------------------------------
    # Truthy enables per-request span tracing (``serve/tracing.py``); the
    # engine records events in memory and the caller saves them
    # (``engine.tracer.save(path)`` — launch/serve wires --trace PATH).
    # Falsy keeps the near-zero-overhead null tracer.
    trace: object = None
    # Emit a metrics snapshot every N engine polls (0 = off): windowed
    # gauges + histogram quick stats into ``engine.metrics.snapshots``
    # and, when tracing, the trace's counter track / JSONL log.
    metrics_every: int = 0
    # Recompile sentinels raise RecompileError on any post-warmup retrace
    # of a compiled serve program instead of just counting trips.
    strict_recompile: bool = False
    # Deadline (seconds) for the continuous engine's hang watchdog: fires
    # when no compiled call completes within the deadline.  0 disables.
    watchdog_s: float = 0.0
    # -- fault tolerance (continuous engine; docs/robustness.md) ------------
    # Fault-injection plan: a FaultInjector, a spec string (see
    # runtime/faults.parse_plan), or an iterable of FaultEvent.  None (the
    # default) serves with zero injection machinery in the hot path.
    fault_plan: object = None
    # Bounded admission queue: submit() REFUSES (returns None, counted in
    # metrics.rejected) once the queue holds this many requests — explicit
    # backpressure instead of unbounded memory growth.  0 = unbounded.
    max_queue_depth: int = 0
    # Degraded overload mode: entered when queue depth reaches
    # overload_queue_depth OR windowed TTFT p95 crosses
    # overload_ttft_p95_s (either 0 disables that trigger); while
    # degraded, the prefill token budget drops to 0 (one chunk per poll)
    # and speculative bursts pause so decode latency of admitted work is
    # protected.  Cleared with hysteresis: queue depth must fall to
    # overload_clear_frac * overload_queue_depth.
    overload_queue_depth: int = 0
    overload_ttft_p95_s: float = 0.0
    overload_clear_frac: float = 0.5
    # Poison quarantine probes: "off" | "logits" (np.isfinite over the
    # step's already-host logits — near-free) | "state" (adds a jitted
    # per-row finiteness probe over the decode pool).  A poisoned slot is
    # reset and its request finished with status "poisoned".
    poison_probe: str = "off"
    poison_check_every: int = 1   # probe every N polls (amortize "state")
    # Backend fallback chain: on a compiled-call failure, rebuild the
    # model one decode mode down (pallas -> cumba -> naive) and retry —
    # once per mode per process.  False re-raises immediately.
    backend_fallback: bool = True
    # Watchdog escalation: "log" (default, metrics + trace instant only)
    # or "recover" (abort the stuck burst at the next poll, requeue its
    # requests with bounded retries + exponential backoff).
    watchdog_action: str = "log"
    max_retries: int = 1
    retry_backoff_s: float = 0.0  # base for runtime.elastic.backoff_delay_s
    # Deadline shedding for requests already *in flight* (staged or
    # decoding), not just queued ones.  Off by default: pre-existing
    # deployments treat deadline_s as an admission SLA only.
    shed_inflight: bool = False
    # -- flight recorder (continuous engine; serve/flight_recorder.py) ------
    # Keep the last N completed-request timelines in a bounded ring and
    # dump them (JSONL at flight_path) whenever a fault event fires —
    # quarantine, watchdog hang/recovery, shed, retry, backend fallback.
    # 0 disables the recorder entirely.  Near-zero steady-state cost
    # (one small dict per completed request, no per-step work).
    flight_records: int = 0
    flight_path: Optional[str] = None


class EngineBase:
    """Plumbing shared by the wave and continuous engines: the two jitted
    programs, uid / sampling-step counters, submit-time bookkeeping, and
    compile counters.  Subclasses provide the serving policy (``run``)
    and must create ``self._scheduler``."""

    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        # One-time pre-sliced view of stacked layer weights for the decode
        # program (zero per-step weight copies); prefill keeps the stacked
        # layout (scan-over-layers, one trace).
        self._decode_params = getattr(model, "decode_view",
                                      lambda p: p)(params)
        self._prefill = jax.jit(
            lambda p, batch, cache: model.prefill(p, batch, cache))
        # The cache pytree is DONATED into the decode program: every step
        # updates slot state in place (zero per-step state copies) while
        # the compile-once discipline keeps the program count at one.
        # (Prefill must NOT donate: its input cache is a reused scratch.)
        self._decode = jax.jit(
            lambda p, tok, cache, idx: model.decode_step(p, tok, cache, idx),
            donate_argnums=(2,))
        self.tracer = Tracer() if getattr(cfg, "trace", None) else NULL_TRACER
        self._scheduler = Scheduler(getattr(cfg, "policy", "fcfs"),
                                    tracer=self.tracer)
        self._uid = 0
        self._step = 0              # sampling-rng step counter
        self.metrics = ServeMetrics(cfg.max_batch, tracer=self.tracer,
                                    metrics_every=getattr(cfg,
                                                          "metrics_every", 0))
        # Every compiled program the engine warms up registers here for
        # program-level attribution: stable ids ride through sentinels
        # and trace spans, and cost/quality cards build lazily on demand
        # (never on the hot path — see serve/program_registry.py).  The
        # wave engine registers decode/prefill name-only (its shapes
        # vary per wave); the continuous engine attaches example shapes.
        self.registry = ProgramRegistry()
        self.registry.register("decode", self._decode)
        self.registry.register("prefill", self._prefill)
        # Compile-once discipline as first-class sentinels: checked every
        # poll/wave, re-armed by reset_stats() (i.e. after warmup), so a
        # trip always means a *post-warmup* retrace.
        strict = getattr(cfg, "strict_recompile", False)
        self.sentinels = {
            "decode": RecompileSentinel(
                "decode", self._decode, strict=strict,
                program_id=self.registry.program_id("decode")),
            "prefill": RecompileSentinel(
                "prefill", self._prefill, strict=strict,
                program_id=self.registry.program_id("prefill")),
        }

    def _buckets(self) -> Sequence[int]:
        return self.cfg.prefill_buckets

    def submit(self, prompt: Sequence[int],
               max_new_tokens: Optional[int] = None, *,
               priority: int = 0, deadline_s: Optional[float] = None,
               on_token=None) -> Optional[int]:
        """Queue a request; returns its uid, or **None** when the bounded
        admission queue (``max_queue_depth``) is full — explicit
        backpressure the caller must handle (resubmit later or surface
        the rejection upstream)."""
        depth_cap = getattr(self.cfg, "max_queue_depth", 0)
        if depth_cap and len(self._scheduler) >= depth_cap:
            self.metrics.record_reject()
            self.tracer.instant("reject", queue_depth=len(self._scheduler))
            log.warning("admission queue full (%d): rejecting request",
                        depth_cap)
            return None
        self._uid += 1
        req = build_request(
            self._uid, prompt,
            max_new_tokens or self.cfg.max_new_tokens,
            priority=priority, deadline_s=deadline_s, on_token=on_token,
            buckets=self._buckets(), metrics=self.metrics)
        self._scheduler.submit(req)
        return req.uid

    def _sample(self, logits) -> np.ndarray:
        out = sampling.sample(np.asarray(logits, np.float32),
                              self.cfg.temperature,
                              sampling.step_rng(self.cfg.seed, self._step))
        self._step += 1
        return out

    def _sample_rows(self, logits, uids, positions) -> np.ndarray:
        """Keyed sampling (continuous engine): noise is a pure function of
        ``(seed, uid, position)``, so a token's draw doesn't depend on
        slot assignment, batch composition, or whether it came from a
        decode step or a speculative verify chunk — spec-on/off streams
        match even under temperature (``serve/sampling.py``)."""
        return sampling.sample_keyed(np.asarray(logits, np.float32),
                                     self.cfg.temperature, self.cfg.seed,
                                     uids, positions)

    @property
    def busy(self) -> bool:
        return len(self._scheduler) > 0

    @property
    def counters(self) -> dict:
        return {"decode_compiles":
                format_compile_count(jit_cache_size(self._decode)),
                "prefill_compiles":
                format_compile_count(jit_cache_size(self._prefill)),
                "recompile_trips":
                {name: s.trips for name, s in self.sentinels.items()}}

    def check_sentinels(self) -> None:
        """Run every recompile sentinel (cheap jit-cache-size probes)."""
        for s in self.sentinels.values():
            s.check(self.tracer)

    @property
    def expired(self) -> List[Request]:
        """Requests shed because their deadline passed while queued."""
        return self._scheduler.expired

    def reset_stats(self) -> None:
        """Drop accumulated metrics and trace events and re-arm the
        recompile sentinels (e.g. after a compile warmup) — everything
        observed afterwards is post-warmup."""
        self.metrics.reset()
        self.tracer.reset()
        for s in self.sentinels.values():
            s.arm()

    def close(self) -> None:
        """Release background resources (watchdog threads); engines stay
        usable for inspection afterwards."""


class Engine(EngineBase):
    def __init__(self, model, params, cfg: ServeConfig):
        super().__init__(model, params, cfg)
        self._wall_s = 0.0          # summed sequential wave wall time

    def _bucket_for(self, length: int) -> int:
        return bucket_for(self.cfg.prefill_buckets, length)[0]

    def reset_stats(self) -> None:
        self._wall_s = 0.0
        super().reset_stats()

    # ------------------------------------------------------------------
    def run(self) -> List[Request]:
        """Serve everything in the queue; returns completed requests."""
        done: List[Request] = []
        while len(self._scheduler):
            wave: List[Request] = []
            now = time.time()
            n_shed0 = len(self._scheduler.expired)
            while len(wave) < self.cfg.max_batch and len(self._scheduler):
                req = self._scheduler.pop_ready(now)
                if req is None:
                    break
                req.admit_pc = time.perf_counter()
                if self.tracer.enabled:
                    self.tracer.complete(
                        "queue", self.tracer.pc_from_walltime(req.arrival_s),
                        req.admit_pc, tid=TID_QUEUE, uid=req.uid)
                wave.append(req)
            for _ in range(len(self._scheduler.expired) - n_shed0):
                self.metrics.record_shed()
            if wave:
                done.extend(self._run_wave(wave))
        return done

    def _run_wave(self, wave: List[Request]) -> List[Request]:
        cfg = self.cfg
        t0 = time.time()
        wave_span = self.tracer.span("poll", requests=len(wave))
        wave_span.__enter__()
        b = cfg.max_batch
        longest = max(len(r.prompt) for r in wave)
        bucket = self._bucket_for(longest)
        max_new = max(r.max_new_tokens for r in wave)

        # Left-pad prompts into the bucket (static shape).
        tokens = np.full((b, bucket), cfg.pad_id, np.int32)
        for i, r in enumerate(wave):
            r.bucket = bucket
            p = r.prompt[-bucket:]
            tokens[i, bucket - len(p):] = p

        # Wave policy over the shared pool: allocate a slot block for this
        # wave's lifetime (the continuous engine keeps one pool forever).
        # The cache length is padded to the configured budget so per-wave
        # max_new variation doesn't change compiled shapes for attention
        # families (compile-once per bucket).
        pool = StatePool(self.model, b,
                         bucket + max(self.cfg.max_new_tokens, max_new),
                         self.model.cfg.dtype)
        with self.tracer.span("prefill_bucket", bucket=bucket,
                              rows=len(wave)):
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(tokens)},
                                          pool.cache)
            next_tok = self._sample(np.asarray(logits, np.float32))

        def finish(r: Request, slot: int) -> None:
            r.done = True
            r.finish_s = time.time()
            r.latency_s = r.finish_s - r.arrival_s
            self.metrics.record_finish(r.latency_s, len(r.out_tokens))
            if self.tracer.enabled and r.decode_pc is not None:
                self.tracer.complete("decode", r.decode_pc,
                                     time.perf_counter(),
                                     tid=TID_SLOT0 + slot, uid=r.uid,
                                     tokens=len(r.out_tokens))

        alive = np.array([True] * len(wave) + [False] * (b - len(wave)))
        t_first = time.time()
        t_first_pc = time.perf_counter()
        for i, r in enumerate(wave):
            r.first_token_s = t_first
            r.decode_pc = t_first_pc
            self.metrics.record_first_token(t_first - r.arrival_s)
            self.metrics.record_token()
            if self.tracer.enabled and r.admit_pc is not None:
                self.tracer.complete("staging", r.admit_pc, t_first_pc,
                                     tid=TID_SLOT0 + i, uid=r.uid)
            r.emit(int(next_tok[i]))
            if (cfg.eos_id >= 0 and next_tok[i] == cfg.eos_id) or \
                    r.max_new_tokens == 1:
                alive[i] = False
                finish(r, i)

        for t in range(1, max_new):
            if not alive[:len(wave)].any():
                break
            ts0 = time.perf_counter()
            tok = jnp.asarray(next_tok[:, None])
            logits, cache = self._decode(self._decode_params, tok, cache,
                                         jnp.int32(bucket + t - 1))
            next_tok = self._sample(np.asarray(logits, np.float32))
            ts1 = time.perf_counter()
            self.tracer.complete("decode_step", ts0, ts1,
                                 live=int(alive[:len(wave)].sum()))
            self.metrics.record_step(int(alive[:len(wave)].sum()),
                                     ts1 - ts0)
            for i, r in enumerate(wave):
                if alive[i] and len(r.out_tokens) < r.max_new_tokens:
                    r.emit(int(next_tok[i]))
                    self.metrics.record_token()
                    if (cfg.eos_id >= 0 and next_tok[i] == cfg.eos_id) or \
                            len(r.out_tokens) >= r.max_new_tokens:
                        alive[i] = False
                        finish(r, i)

        for i, r in enumerate(wave):
            if not r.done:
                finish(r, i)
        dt = time.time() - t0
        self._wall_s += dt
        self.metrics.record_wall(dt)
        wave_span.__exit__(None, None, None)
        self.check_sentinels()
        self.metrics.maybe_snapshot()
        return wave

    # ------------------------------------------------------------------
    def stats(self, requests: List[Request]) -> Dict[str, float]:
        """Throughput over the *summed* sequential wave time (waves run one
        after another; the old max-latency denominator over-reported
        tokens/s whenever there was more than one wave)."""
        toks = sum(len(r.out_tokens) for r in requests)
        wall = self._wall_s or (max((r.latency_s for r in requests),
                                    default=0.0))
        return {"requests": len(requests), "generated_tokens": toks,
                "tokens_per_s": toks / wall if wall else 0.0,
                "wall_s": wall}
